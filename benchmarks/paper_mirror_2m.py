import os
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Paper-mirror cell: llama-8b at 2M tokens (batch 1), FPDT u=32 — the
configuration class of the paper's headline claim (8B @ 2M).  Lowers and
compiles train_step on the single-pod production mesh; records
memory/cost/collectives like the dry-run.

  PYTHONPATH=src python -m benchmarks.paper_mirror_2m
"""
import json
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_compilation_cache_dir", "/tmp/jax_cache")
jax.config.update("jax_persistent_cache_min_compile_time_secs", 1)

from repro.configs import ShapeConfig, get_config
from repro.core.parallel import ParallelContext
from repro.launch import steps as ST
from repro.launch.dryrun import parse_collectives
from repro.launch.mesh import dp_axes_of, make_production_mesh


def main():
    shape = ShapeConfig("train_2m", 2_097_152, 1, "train")
    mesh = make_production_mesh(multi_pod=False)
    par = ParallelContext(mesh=mesh, dp_axes=dp_axes_of(mesh),
                          attn_impl="xla_flash", offload_to_host=False)
    cfg = ST.tuned_config(get_config("llama-8b"), shape)  # u = 32 (64K chunks)
    print(f"llama-8b @ 2M tokens, FPDT u={cfg.fpdt_chunks}, "
          f"mlp_chunks={cfg.mlp_chunks}, remat={cfg.remat}")
    fn, args, in_sh, out_sh, donate = ST.build(cfg, par, shape)
    with mesh:
        compiled = (jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                            donate_argnums=donate).lower(*args).compile())
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    rec = {
        "cell": "llama-8b_train_2m_single", "chunks": cfg.fpdt_chunks,
        "temp_gib": ma.temp_size_in_bytes / 2**30,
        "args_gib": ma.argument_size_in_bytes / 2**30,
        "flops_text": float(ca.get("flops", 0)),
        "collectives": parse_collectives(compiled.as_text()),
    }
    os.makedirs("experiments/paper_mirror", exist_ok=True)
    with open("experiments/paper_mirror/llama8b_2m.json", "w") as f:
        json.dump(rec, f, indent=1)
    print(f"COMPILED: temp={rec['temp_gib']:.2f} GiB/device, "
          f"args={rec['args_gib']:.2f} GiB/device")
    print({k: v["count"] for k, v in rec["collectives"].items()})


if __name__ == "__main__":
    main()
