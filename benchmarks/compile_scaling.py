"""Compile-time / program-size scaling of the FPDT chunk pipeline in u.

The paper's 2M-token setting needs large chunk counts (u=32/u=64 at 64K
tokens per chunk).  The original Python-unrolled Fig. 7 backward emitted
O(u^2) chunk-pair kernels, so jaxpr/HLO size — and with it trace, lower,
and compile time — grew quadratically, capping practical u at toy scale.
The scan-compiled pipeline traces the chunk body once; this benchmark
measures both paths over a u sweep at fixed chunk length (so sequence
length grows with u, as in the paper's scaling runs) and reports:

  * traced jaxpr equation count (recursive, incl. scan/cond/while bodies)
  * StableHLO op count of the lowered module
  * trace+lower wall-clock

Emits name,value rows for benchmarks.run plus a JSON blob; the slow tier-1
regression test (tests/test_compile_scaling.py) asserts the scan path's
near-O(1) growth so unrolling never silently regresses.

Usage: python benchmarks/compile_scaling.py [--json out.json]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from typing import List

import jax
import jax.numpy as jnp

CQ = 8  # tokens per chunk: S = u * CQ grows with u, like the paper's sweep


def _subjaxprs(params):
    for v in params.values():
        stack = [v]
        while stack:
            x = stack.pop()
            if isinstance(x, (tuple, list)):
                stack.extend(x)
            elif type(x).__name__ == "ClosedJaxpr":
                yield x.jaxpr
            elif type(x).__name__ == "Jaxpr":
                yield x


def count_eqns(jaxpr) -> int:
    """Total equation count of a (Closed)Jaxpr including nested bodies —
    the trace-level proxy for program size (scan bodies count once)."""
    if hasattr(jaxpr, "jaxpr"):  # ClosedJaxpr
        jaxpr = jaxpr.jaxpr
    n = 0
    for eqn in jaxpr.eqns:
        n += 1
        for sub in _subjaxprs(eqn.params):
            n += count_eqns(sub)
    return n


def count_hlo_ops(lowered) -> int:
    """Assignment count in the lowered StableHLO text (loop bodies once) —
    the same heuristic the dry-run records as ``hlo_ops``."""
    from repro.launch.hlo import count_ops

    return count_ops(lowered.as_text())


def build(u: int, unroll: bool):
    from repro.configs import get_config, reduced
    from repro.core import fpdt
    from repro.core.parallel import ParallelContext
    from repro.models import layers as L

    cfg = dataclasses.replace(
        reduced(get_config("llama3.2-1b")), param_dtype="float32",
        fpdt_chunks=u, fpdt_offload=True, fpdt_unroll=unroll,
        block_q=CQ, block_k=CQ)
    par = ParallelContext(mesh=None, attn_impl="xla_flash")
    S = u * CQ
    key = jax.random.PRNGKey(0)
    p = L.init_attn(cfg, key, jnp.float32)
    x = jnp.zeros((1, S, cfg.d_model), jnp.float32)
    do = jnp.zeros((1, S, cfg.q_dim), jnp.float32)

    def f(x, p):
        o = fpdt.fpdt_attention(cfg, par, p, x, kind="local")
        return (o * do).sum()

    return jax.value_and_grad(f, argnums=(0, 1)), (x, p)


def measure(u: int, unroll: bool) -> dict:
    f, args = build(u, unroll)
    t0 = time.perf_counter()
    jaxpr = jax.make_jaxpr(f)(*args)
    trace_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    lowered = jax.jit(f).lower(*args)
    lower_s = time.perf_counter() - t0
    return {
        "u": u, "path": "unrolled" if unroll else "scan", "seq_len": u * CQ,
        "jaxpr_eqns": count_eqns(jaxpr),
        "hlo_ops": count_hlo_ops(lowered),
        "trace_s": round(trace_s, 3), "lower_s": round(lower_s, 3),
    }


def sweep(scan_us=(2, 4, 8, 16, 32, 64), unrolled_us=(2, 4, 8, 16)) -> List[dict]:
    recs = []
    for u in scan_us:
        recs.append(measure(u, unroll=False))
        print("{path:>8} u={u:<3d} S={seq_len:<5d} jaxpr_eqns={jaxpr_eqns:<6d} "
              "hlo_ops={hlo_ops:<6d} trace={trace_s}s lower={lower_s}s"
              .format(**recs[-1]))
    for u in unrolled_us:
        recs.append(measure(u, unroll=True))
        print("{path:>8} u={u:<3d} S={seq_len:<5d} jaxpr_eqns={jaxpr_eqns:<6d} "
              "hlo_ops={hlo_ops:<6d} trace={trace_s}s lower={lower_s}s"
              .format(**recs[-1]))
    return recs


def run() -> List[str]:
    """benchmarks.run entry: summarized growth factors."""
    recs = sweep(scan_us=(4, 32), unrolled_us=(4, 8))
    by = {(r["path"], r["u"]): r for r in recs}
    rows = ["bench,name,value,derived"]
    g = by[("scan", 32)]["hlo_ops"] / by[("scan", 4)]["hlo_ops"]
    rows.append(f"bench,fpdt_scan_hlo_growth_u4_to_u32,{g:.3f},x")
    g = by[("unrolled", 8)]["hlo_ops"] / by[("unrolled", 4)]["hlo_ops"]
    rows.append(f"bench,fpdt_unrolled_hlo_growth_u4_to_u8,{g:.3f},x")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    recs = sweep()
    scan = {r["u"]: r for r in recs if r["path"] == "scan"}
    print(f"\nscan-path growth u=4 -> u=32: "
          f"jaxpr x{scan[32]['jaxpr_eqns'] / scan[4]['jaxpr_eqns']:.2f}, "
          f"hlo x{scan[32]['hlo_ops'] / scan[4]['hlo_ops']:.2f}")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(recs, fh, indent=1)


if __name__ == "__main__":
    sys.path.insert(0, "src")
    main()
