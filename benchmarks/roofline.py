import os
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Roofline analysis per (arch x shape x mesh) — EXPERIMENTS.md §Roofline.

Terms (assignment formulas, TPU v5e constants):
    compute    = FLOPs / (chips * 197e12)
    memory     = HBM bytes / (chips * 819e9)
    collective = collective bytes per chip / 50e9

Sources:
  * compute/memory: the analytic model (benchmarks/flops_model.py) — exact
    closed form; XLA cost_analysis counts lax.scan bodies once, so raw
    compiled numbers under-report (the HLO-probe cross-check column shows
    this measured and corrected).
  * collective: PROBE-measured from the real compiled artifact — two
    scan-unrolled compiles (1 cycle and 2 cycles of the layer pattern)
    isolate the true per-cycle collective bytes (probe2 - probe1); total =
    outside + n_cycles * per_cycle.  This is the number §Perf hillclimbs.
  * capacity: per-device memory_analysis from the full-depth compile
    (experiments/dryrun/*.json).

Usage:
  python -m benchmarks.roofline --arch llama3.2-1b --shape train_4k [--multi]
  python -m benchmarks.roofline --all      # every runnable cell, single-pod
"""
import argparse
import dataclasses
import json
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_compilation_cache_dir", "/tmp/jax_cache")
jax.config.update("jax_persistent_cache_min_compile_time_secs", 1)

from repro.configs import ASSIGNED_ARCHS, SHAPES, get_config, shape_applicable
from repro.core.parallel import ParallelContext
from repro.launch import steps as ST
from repro.launch.mesh import dp_axes_of, make_production_mesh

from benchmarks import flops_model as FM


def _probe_cfg(cfg, n_cycles: int):
    """Scan-unrolled shallow config whose HLO costs scale with true depth."""
    from repro.models.transformer import pattern_of

    pat = pattern_of(cfg)
    return dataclasses.replace(
        cfg,
        num_layers=n_cycles * len(pat),
        scan_layers=False,
        loss_chunks=1,       # no loss scan -> loss counted exactly
        mlp_chunks=1,        # no FFN-chunk scan in probes
    )


def _probe_costs(cfg, par, shape, mesh, n_cycles: int, n_host_chunks=0):
    from repro.launch.dryrun import parse_collectives

    pc = _probe_cfg(cfg, n_cycles)
    fn, args, in_sh, out_sh, donate = ST.build(pc, par, shape, n_host_chunks=n_host_chunks)
    with mesh:
        compiled = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                           donate_argnums=donate).lower(*args).compile()
    ca = compiled.cost_analysis() or {}
    colls = parse_collectives(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll_bytes": sum(v["bytes"] for v in colls.values()),
        "colls": colls,
    }


def probe_collectives(arch: str, shape_name: str, multi_pod: bool,
                      chunks=None, offload=None):
    """(per-chip collective bytes, detail) for the full-depth model."""
    from repro.models.transformer import layout_of

    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    par = ParallelContext(mesh=mesh, dp_axes=dp_axes_of(mesh), attn_impl="xla_flash",
                          offload_to_host=False)
    cfg = ST.tuned_config(get_config(arch), shape, chunks=chunks, offload=offload)
    n_host = 8 if (shape.kind == "decode" and shape.seq_len >= 500_000
                   and cfg.family == "dense") else 0
    pat, n_cycles, tail = layout_of(cfg)
    p1 = _probe_costs(cfg, par, shape, mesh, 1, n_host)
    p2 = _probe_costs(cfg, par, shape, mesh, 2, n_host)
    per_cycle = {k: p2[k] - p1[k] for k in ("flops", "bytes", "coll_bytes")}
    outside = {k: p1[k] - per_cycle[k] for k in per_cycle}
    kinds = set(p1["colls"]) | set(p2["colls"])
    per_cycle_kinds = {
        k: {"bytes": p2["colls"].get(k, {}).get("bytes", 0) - p1["colls"].get(k, {}).get("bytes", 0),
            "count": p2["colls"].get(k, {}).get("count", 0) - p1["colls"].get(k, {}).get("count", 0)}
        for k in kinds
    }
    frac_tail = len(tail) / len(pat) if tail else 0.0
    total = {k: max(0.0, outside[k]) + per_cycle[k] * (n_cycles + frac_tail)
             for k in per_cycle}
    return total, {"per_cycle": per_cycle, "outside": outside,
                   "per_cycle_kinds": per_cycle_kinds,
                   "outside_kinds": p1["colls"],
                   "n_cycles": n_cycles, "probe1": p1, "probe2": p2}


def analyze_cell(arch: str, shape_name: str, multi_pod: bool = False,
                 chunks=None, offload=None, outdir="experiments/roofline"):
    shape = SHAPES[shape_name]
    chips = 512 if multi_pod else 256
    cfg = ST.tuned_config(get_config(arch), shape, chunks=chunks, offload=offload)
    probed, detail = probe_collectives(arch, shape_name, multi_pod, chunks, offload)
    terms = FM.terms(cfg, shape, chips, collective_bytes_per_chip=probed["coll_bytes"])
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single", "chips": chips,
        "chunks": cfg.fpdt_chunks, "offload": cfg.fpdt_offload,
        **{k: terms[k] for k in ("t_compute", "t_memory", "t_collective",
                                 "bottleneck", "roofline_frac", "useful_ratio")},
        "analytic_flops": terms["flops_total"],
        "hlo_flops_extrapolated": probed["flops"],
        "analytic_hbm_bytes": terms["hbm_bytes"],
        "hlo_bytes_extrapolated": probed["bytes"],
        "coll_bytes_per_chip": probed["coll_bytes"],
        "model_flops": terms["model_flops"],
        "probe_detail": {k: detail[k] for k in ("per_cycle", "outside", "per_cycle_kinds", "outside_kinds", "n_cycles")},
    }
    os.makedirs(outdir, exist_ok=True)
    suffix = "" if chunks is None else f"_u{chunks}" + ("off" if offload else "")
    with open(os.path.join(outdir, f"{arch}_{shape_name}_{rec['mesh']}{suffix}.json"), "w") as f:
        json.dump(rec, f, indent=1)
    print(f"{arch:28s} {shape_name:12s} {rec['mesh']:6s} "
          f"C={terms['t_compute']*1e3:9.2f}ms M={terms['t_memory']*1e3:9.2f}ms "
          f"X={terms['t_collective']*1e3:9.2f}ms -> {terms['bottleneck']:10s} "
          f"frac={terms['roofline_frac']:.2f} useful={terms['useful_ratio']:.2f}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--chunks", type=int, default=None)
    ap.add_argument("--offload", action="store_true", default=None)
    ap.add_argument("--out", default="experiments/roofline")
    args = ap.parse_args()
    if args.all:
        for a in ASSIGNED_ARCHS:
            for s in SHAPES:
                if shape_applicable(a, s):
                    try:
                        analyze_cell(a, s, args.multi, outdir=args.out)
                    except Exception as e:  # noqa: BLE001
                        print(f"{a:28s} {s:12s} FAILED {type(e).__name__}: {str(e)[:160]}")
    else:
        analyze_cell(args.arch, args.shape, args.multi, chunks=args.chunks,
                     offload=args.offload, outdir=args.out)


if __name__ == "__main__":
    main()
