"""Benchmark runner: one function per paper table/figure + kernel micro-bench.

  PYTHONPATH=src python -m benchmarks.run           # all, CSV to stdout
  PYTHONPATH=src python -m benchmarks.run --only table1 fig11

Roofline sweeps (compile-heavy) run separately:
  python -m repro.launch.dryrun --all     -> experiments/dryrun/
  python -m benchmarks.roofline --all     -> experiments/roofline/
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None)
    args = ap.parse_args()

    from benchmarks import compile_scaling
    from benchmarks import kernels_bench
    from benchmarks import paper_tables as PT
    from benchmarks import serve_bench

    suites = {
        "table1": PT.table1_max_context,
        "fig10": PT.fig10_latency,
        "fig11": PT.fig11_mfu,
        "fig12": PT.fig12_chunk_sweep,
        "table3": PT.table3_strategies,
        "table4": PT.table4_sparse,
        "kernels": kernels_bench.run,
        "compile_scaling": compile_scaling.run,
        "serve": serve_bench.run,
    }
    sel = args.only or list(suites)
    failures = 0
    for name in sel:
        try:
            for row in suites[name]():
                print(row)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},ERROR,{type(e).__name__}: {e}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
