"""Benchmark runner: one function per paper table/figure + kernel micro-bench.

  PYTHONPATH=src python -m benchmarks.run           # all, CSV to stdout
  PYTHONPATH=src python -m benchmarks.run --only table1 fig11
  PYTHONPATH=src python -m benchmarks.run --only serve --json BENCH_serve.json

``--json`` additionally writes the selected suites' rows as machine-
readable JSON (``{suite: [{name, value, derived}]}``), the format the
``BENCH_*.json`` perf-trajectory files use so future PRs can
regression-track numbers like serving tokens/s and p50/p95 inter-token
latency without parsing stdout.

Roofline sweeps (compile-heavy) run separately:
  python -m repro.launch.dryrun --all     -> experiments/dryrun/
  python -m benchmarks.roofline --all     -> experiments/roofline/
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def _row_to_record(row: str):
    """'bench,name,value,derived' -> {name, value, derived} (floats parsed);
    header rows return None."""
    parts = row.split(",")
    if len(parts) < 3 or parts[1] in ("name", "ERROR"):
        return None
    name, value = parts[1], parts[2]
    try:
        value = float(value)
    except ValueError:
        pass
    return {"name": name, "value": value,
            "derived": parts[3] if len(parts) > 3 else ""}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write suite results as JSON (BENCH_*.json "
                         "perf-trajectory format)")
    args = ap.parse_args()

    from benchmarks import compile_scaling
    from benchmarks import kernels_bench
    from benchmarks import paper_tables as PT
    from benchmarks import serve_bench

    suites = {
        "table1": PT.table1_max_context,
        "fig10": PT.fig10_latency,
        "fig11": PT.fig11_mfu,
        "fig12": PT.fig12_chunk_sweep,
        "table3": PT.table3_strategies,
        "table4": PT.table4_sparse,
        "kernels": kernels_bench.run,
        "compile_scaling": compile_scaling.run,
        "serve": serve_bench.run,
        "paged": serve_bench.run_paged,
        "serve_mesh": serve_bench.run_serve_mesh,
        "kv_store": serve_bench.run_kv_store,
        "slo": serve_bench.run_slo,
        "failover": serve_bench.run_failover,
        "obs": serve_bench.run_obs,
    }
    sel = args.only or list(suites)
    failures = 0
    results = {}
    for name in sel:
        records = results[name] = []  # always a list of {name, value, derived}
        try:
            for row in suites[name]():
                print(row)  # incremental — rows survive a later crash
                rec = _row_to_record(row)
                if rec:
                    records.append(rec)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},ERROR,{type(e).__name__}: {e}")
            records.append({"name": "ERROR", "value": f"{type(e).__name__}: {e}",
                            "derived": ""})
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(results, fh, indent=1)
            fh.write("\n")
        print(f"wrote {args.json}", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
