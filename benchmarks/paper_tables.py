"""Reproductions of every paper table/figure, one function each.

All return lists of CSV rows (also printed by benchmarks.run).  Memory/MFU
numbers come from the calibrated models (memory_model / perf_model) on the
paper's A100-80G hardware profile; deviations from the paper's published
numbers are reported inline — see EXPERIMENTS.md for the analysis.
"""
from __future__ import annotations

import sys
from typing import List

from repro.configs import get_config

from benchmarks import memory_model as MM
from benchmarks import perf_model as PM

K = 1024


def _fmt_len(S: int) -> str:
    return f"{S // (K * K)}M" if S >= K * K else f"{S // K}K"


def _parse_len(s: str) -> int:
    s = s.rstrip("+")
    return int(float(s[:-1]) * (K * K if s[-1] == "M" else K))


# ---------------------------------------------------------------- Table 1
PAPER_TABLE1 = {
    # (model, gpus, mem_gb): paper max len
    ("gpt-2.7b", 1, 40): "128K", ("gpt-2.7b", 2, 40): "512K",
    ("gpt-2.7b", 4, 40): "2M", ("gpt-2.7b", 8, 40): "4M",
    ("gpt-2.7b", 4, 80): "4M", ("gpt-2.7b", 8, 80): "8M+",
    ("llama-8b", 8, 40): "1M", ("llama-8b", 4, 80): "2M",
    ("llama-8b", 8, 80): "4M", ("llama-8b", 16, 80): "8M+",
    ("gpt-13b", 8, 40): "256K", ("gpt-13b", 4, 80): "512K",
    ("gpt-13b", 8, 80): "3M", ("gpt-13b", 16, 80): "4M",
    ("gpt-30b", 8, 80): "1M", ("gpt-30b", 16, 80): "3M", ("gpt-30b", 32, 80): "4M",
    ("llama-70b", 16, 80): "1M", ("llama-70b", 32, 80): "4M",
}


def table1_max_context() -> List[str]:
    rows = ["table1,model,gpus,mem_gb,ours,paper,log2_delta"]
    for (model, n, gb), paper in sorted(PAPER_TABLE1.items()):
        cfg = get_config(model)
        st = MM.Strategy(n=n, ulysses=True, zero=3, ac=True, oc=True,
                         fpdt_u=64, offload=True)
        ours = MM.max_seq_len(cfg, st, budget=gb * MM.GB)
        pv = _parse_len(paper)
        import math

        delta = round(math.log2(max(ours, 1) / pv), 1) if ours else float("nan")
        rows.append(f"table1,{model},{n},{gb},{_fmt_len(ours)},{paper},{delta}")
    return rows


# ---------------------------------------------------------------- Fig 11
def fig11_mfu() -> List[str]:
    """MFU vs sequence length: Megatron-SP vs Ulysses vs FPDT(+offload)."""
    rows = ["fig11,model,gpus,seq_len,strategy,mfu_pct,max_ok"]
    grid = [("gpt-2.7b", 4), ("llama-8b", 8), ("gpt-13b", 8), ("gpt-30b", 16)]
    for model, n in grid:
        cfg = get_config(model)
        for logS in range(17, 23):  # 128K .. 4M
            S = 1 << logS
            for strat in ("megatron-sp", "ulysses", "fpdt", "fpdt-offload"):
                if strat == "megatron-sp":
                    st = MM.Strategy(n=n, tp=n, ac=True, oc=True)
                    fits = MM.train_memory_gb(cfg, S, st) <= 80
                    r = PM.megatron_sp_step_time(cfg, S, n)
                elif strat == "ulysses":
                    st = MM.Strategy(n=n, ulysses=True, zero=3, ac=True, oc=True)
                    fits = MM.train_memory_gb(cfg, S, st) <= 80
                    r = PM.fpdt_step_time(cfg, S, n, 1, offload=False)
                else:
                    off = strat.endswith("offload")
                    u = max(1, S // 65536)
                    st = MM.Strategy(n=n, ulysses=True, zero=3, ac=True, oc=True,
                                     fpdt_u=u, offload=off)
                    fits = MM.train_memory_gb(cfg, S, st) <= 80
                    r = PM.fpdt_step_time(cfg, S, n, u, offload=off)
                rows.append(f"fig11,{model},{n},{_fmt_len(S)},{strat},"
                            f"{r['mfu'] * 100:.1f},{int(fits)}")
    return rows


# ---------------------------------------------------------------- Fig 12
def fig12_chunk_sweep() -> List[str]:
    """Fixed 256K global sequence; sweep chunk size (paper: 64K sweet spot)."""
    rows = ["fig12,model,gpus,chunk,mem_gb,mfu_pct"]
    grid = [("gpt-2.7b", 4), ("gpt-6.7b", 4), ("gpt-13b", 4), ("gpt-30b", 8)]
    S = 256 * K
    for model, n in grid:
        cfg = get_config(model)
        for chunk in (8 * K, 16 * K, 32 * K, 64 * K, 128 * K, 256 * K):
            u = S // chunk
            st = MM.Strategy(n=n, ulysses=True, zero=3, ac=True, oc=True,
                             fpdt_u=u, offload=u > 1)
            mem = MM.train_memory_gb(cfg, S, st)
            r = PM.fpdt_step_time(cfg, S, n, u, offload=u > 1)
            rows.append(f"fig12,{model},{n},{_fmt_len(chunk)},{mem:.1f},{r['mfu']*100:.1f}")
    return rows


# ---------------------------------------------------------------- Table 3
def table3_strategies() -> List[str]:
    """8B Llama x 8 GPUs strategy ablation."""
    rows = ["table3,strategy,ours_max,paper_max,ours_mem_gb,paper_mem_gb,ours_mfu,paper_mfu"]
    cfg = get_config("llama-8b")
    cases = [
        ("TP", MM.Strategy(n=8, tp=8), "32K", 64.3, 9.4),
        ("TP+AC", MM.Strategy(n=8, tp=8, ac=True), "128K", 61.2, 19.4),
        ("TP+AC+OC", MM.Strategy(n=8, tp=8, ac=True, oc=True), "512K", 78.7, 32.7),
        ("UL+ZeRO1", MM.Strategy(n=8, ulysses=True, zero=1), "64K", 58.9, 15.3),
        ("UL+ZeRO2", MM.Strategy(n=8, ulysses=True, zero=2), "64K", 54.5, 15.3),
        ("UL+ZeRO3", MM.Strategy(n=8, ulysses=True, zero=3), "64K", 52.3, 21.0),
        ("UL+AC+OC+ZeRO3", MM.Strategy(n=8, ulysses=True, zero=3, ac=True, oc=True),
         "512K", 60.1, 47.2),
        ("FPDT", MM.Strategy(n=8, ulysses=True, zero=3, ac=True, oc=True,
                             fpdt_u=64, offload=True), "4M", 68.0, 55.7),
    ]
    for name, st, paper_max, paper_mem, paper_mfu in cases:
        ours = MM.max_seq_len(cfg, st)
        mem = MM.train_memory_gb(cfg, ours, st)
        if st.fpdt_u > 1:
            u = max(1, ours // 65536)
            mfu = PM.fpdt_step_time(cfg, ours, 8, u, offload=True)["mfu"] * 100
        elif st.ulysses:
            mfu = PM.fpdt_step_time(cfg, ours, 8, 1, offload=False)["mfu"] * 100
        else:  # plain TP: all-reduce bound (paper's 9-30% rows)
            mfu = PM.megatron_tp_step_time(cfg, ours, 8)["mfu"] * 100
        rows.append(f"table3,{name},{_fmt_len(ours)},{paper_max},{mem:.1f},"
                    f"{paper_mem},{mfu:.1f},{paper_mfu}")
    return rows


# ---------------------------------------------------------------- Table 4
PAPER_TABLE4 = {
    ("gpt-2.7b", 0.5): 41.7, ("gpt-2.7b", 0.0): 38.4,
    ("llama-8b", 0.5): 40.6, ("llama-8b", 0.0): 47.6,
    ("gpt-13b", 0.5): 40.7, ("gpt-13b", 0.0): 46.1,
}


def table4_sparse() -> List[str]:
    """Block-sparse attention: MFU vs sparsity (256K seq, 64K chunks)."""
    rows = ["table4,model,gpus,sparsity,mfu_pct,paper_mfu"]
    grid = [("gpt-2.7b", 1), ("llama-8b", 4), ("gpt-13b", 4)]
    S = 256 * K
    for model, n in grid:
        cfg = get_config(model)
        for sp in (0.5, 0.4, 0.3, 0.2, 0.1, 0.0):
            r = PM.fpdt_step_time(cfg, S, n, 4, offload=True, sparsity=sp)
            paper = PAPER_TABLE4.get((model, sp), "")
            rows.append(f"table4,{model},{n},{sp},{r['mfu']*100:.1f},{paper}")
    return rows


# ---------------------------------------------------------------- Fig 10
def fig10_latency() -> List[str]:
    """Unit-op latency crossover (a2a / attention fwd/bwd / fetch) on the
    A100 profile: the chunk size where compute first covers the fetch."""
    rows = ["fig10,seq_chunk,t_a2a_ms,t_attn_fwd_ms,t_attn_bwd_ms,t_fetch_ms"]
    cfg = get_config("gpt-2.7b")
    n = 4
    for logc in range(13, 20):  # 8K .. 512K chunks
        c = 1 << logc
        r = PM.fpdt_step_time(cfg, c, n, 1, offload=True)
        rows.append(
            f"fig10,{_fmt_len(c)},{r['t_a2a_unit']*1e3:.3f},"
            f"{r['t_att_diag']*1e3:.3f},{2*r['t_att_diag']*1e3:.3f},"
            f"{r['t_fetch_unit']*1e3:.3f}"
        )
    return rows
