"""Program-size / throughput scaling of the scan-compiled decode engine.

The serving acceptance bar mirrors the training one
(`benchmarks/compile_scaling.py`): decode program size must be flat in
BOTH knobs that used to unroll —

  * ``n_host_chunks`` — the host-KV streaming loop is
    `runtime.placement.fori_double_buffered` (body traced once), where the
    retired generator-based path emitted one online-softmax merge per chunk;
  * generated-token count — the whole generation is one
    `runtime.decode_loop.decode_tokens` `lax.scan`, where the per-token
    Python loop re-dispatched (and on first use re-traced) per token.

For every cell this reports traced jaxpr equation count, StableHLO op
count of the lowered module, trace+lower wall-clock, and (post-compile)
ms/step and tokens/sec on the real machine.  Emits name,value rows for
``benchmarks.run`` plus a JSON blob; the measured table is committed in
``docs/serving.md``.

Usage: python benchmarks/serve_bench.py [--json out.json]
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import os
import sys
import time
from typing import List

import jax
import jax.numpy as jnp

B = 2           # batch rows
PROMPT = 16     # prefill length
CACHE_LEN = 64  # cache capacity: divisible by every chunk count below


@functools.lru_cache(maxsize=1)
def _setup():
    from repro.configs import get_config, reduced
    from repro.models import serve as SV
    from repro.models import transformer as T

    cfg = dataclasses.replace(reduced(get_config("llama3.2-1b")),
                              param_dtype="float32", remat="none")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, PROMPT), 0, cfg.vocab_size)
    logits, cache = SV.prefill_step(cfg, None, params, {"tokens": toks},
                                    max_len=CACHE_LEN)
    tok0 = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)[:, None]
    return cfg, params, cache, tok0


def measure(n_host_chunks: int, num_steps: int) -> dict:
    from benchmarks.compile_scaling import count_eqns, count_hlo_ops
    from repro.core.parallel import ParallelContext
    from repro.runtime import decode_loop as DL

    cfg, params, cache, tok0 = _setup()
    par = ParallelContext(mesh=None) if n_host_chunks else None

    def f(cache, tok, pos, key):
        return DL.decode_tokens(cfg, par, params, cache, tok, pos,
                                num_steps=num_steps,
                                n_host_chunks=n_host_chunks, key=key)

    args = (cache, tok0, jnp.full((B,), PROMPT, jnp.int32), jax.random.PRNGKey(2))
    t0 = time.perf_counter()
    jaxpr = jax.make_jaxpr(f)(*args)
    trace_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    lowered = jax.jit(f).lower(*args)
    lower_s = time.perf_counter() - t0
    compiled = lowered.compile()
    jax.block_until_ready(compiled(*args))  # warm-up
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        jax.block_until_ready(compiled(*args))
        best = min(best, time.perf_counter() - t0)
    return {
        "n_host_chunks": n_host_chunks, "num_steps": num_steps,
        "jaxpr_eqns": count_eqns(jaxpr), "hlo_ops": count_hlo_ops(lowered),
        "trace_s": round(trace_s, 3), "lower_s": round(lower_s, 3),
        "ms_per_step": round(best / num_steps * 1e3, 3),
        "tok_per_s": round(num_steps * B / best, 1),
    }


def sweep(chunk_sweep=(0, 2, 8, 32), gen_sweep=(2, 8, 32),
          fixed_gen=8, fixed_chunks=4) -> List[dict]:
    recs = []

    def show(r):
        print("chunks={n_host_chunks:<3d} steps={num_steps:<3d} "
              "jaxpr_eqns={jaxpr_eqns:<6d} hlo_ops={hlo_ops:<6d} "
              "trace={trace_s}s lower={lower_s}s "
              "ms/step={ms_per_step:<8} tok/s={tok_per_s}".format(**r))

    for c in chunk_sweep:
        recs.append(measure(c, fixed_gen))
        show(recs[-1])
    for g in gen_sweep:
        recs.append(measure(fixed_chunks, g))
        show(recs[-1])
    return recs


def run() -> List[str]:
    """benchmarks.run entry: summarized growth factors + throughput."""
    recs = sweep(chunk_sweep=(2, 32), gen_sweep=(2, 32), fixed_gen=8, fixed_chunks=4)
    by_c = {r["n_host_chunks"]: r for r in recs[:2]}
    by_g = {r["num_steps"]: r for r in recs[2:]}
    rows = ["bench,name,value,derived"]
    g = by_c[32]["hlo_ops"] / by_c[2]["hlo_ops"]
    rows.append(f"bench,decode_hlo_growth_chunks_2_to_32,{g:.3f},x")
    g = by_g[32]["hlo_ops"] / by_g[2]["hlo_ops"]
    rows.append(f"bench,decode_hlo_growth_gen_2_to_32,{g:.3f},x")
    rows.append(f"bench,decode_tok_per_s_u4_gen32,{by_g[32]['tok_per_s']},tok/s")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    recs = sweep()
    by_c = {r["n_host_chunks"]: r for r in recs[:4]}
    by_g = {r["num_steps"]: r for r in recs[4:]}
    print(f"\nhost-chunk growth 2 -> 32 (gen=8):  "
          f"jaxpr x{by_c[32]['jaxpr_eqns'] / by_c[2]['jaxpr_eqns']:.2f}, "
          f"hlo x{by_c[32]['hlo_ops'] / by_c[2]['hlo_ops']:.2f}")
    print(f"gen-length growth 2 -> 32 (u=4):    "
          f"jaxpr x{by_g[32]['jaxpr_eqns'] / by_g[2]['jaxpr_eqns']:.2f}, "
          f"hlo x{by_g[32]['hlo_ops'] / by_g[2]['hlo_ops']:.2f}")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(recs, fh, indent=1)


if __name__ == "__main__":
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)  # for `from benchmarks.compile_scaling import`
    sys.path.insert(0, os.path.join(_root, "src"))
    main()
