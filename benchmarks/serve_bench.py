"""Program-size / throughput scaling of the scan-compiled decode engine.

The serving acceptance bar mirrors the training one
(`benchmarks/compile_scaling.py`): decode program size must be flat in
BOTH knobs that used to unroll —

  * ``n_host_chunks`` — the host-KV streaming loop is
    `runtime.placement.fori_double_buffered` (body traced once), where the
    retired generator-based path emitted one online-softmax merge per chunk;
  * generated-token count — the whole generation is one
    `runtime.decode_loop.decode_tokens` `lax.scan`, where the per-token
    Python loop re-dispatched (and on first use re-traced) per token.

For every cell this reports traced jaxpr equation count, StableHLO op
count of the lowered module, trace+lower wall-clock, and (post-compile)
ms/step and tokens/sec on the real machine.  Emits name,value rows for
``benchmarks.run`` plus a JSON blob; the measured table is committed in
``docs/serving.md``.

Usage: python benchmarks/serve_bench.py [--json out.json]
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import os
import sys
import time
from typing import List

import jax
import jax.numpy as jnp

B = 2           # batch rows
PROMPT = 16     # prefill length
CACHE_LEN = 64  # cache capacity: divisible by every chunk count below


@functools.lru_cache(maxsize=1)
def _setup():
    from repro.configs import get_config, reduced
    from repro.models import serve as SV
    from repro.models import transformer as T

    cfg = dataclasses.replace(reduced(get_config("llama3.2-1b")),
                              param_dtype="float32", remat="none")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, PROMPT), 0, cfg.vocab_size)
    logits, cache = SV.prefill_step(cfg, None, params, {"tokens": toks},
                                    max_len=CACHE_LEN)
    tok0 = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)[:, None]
    return cfg, params, cache, tok0


def _measure_program(f, args, num_steps: int) -> dict:
    """Shared harness: trace/lower/compile `f(*args)` and time the hot path
    (min of 3 after a warm-up).  Both the decode-scan and the mixed-step
    benchmarks report through this so their numbers stay comparable."""
    import jax

    from benchmarks.compile_scaling import count_eqns, count_hlo_ops

    t0 = time.perf_counter()
    jaxpr = jax.make_jaxpr(f)(*args)
    trace_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    lowered = jax.jit(f).lower(*args)
    lower_s = time.perf_counter() - t0
    compiled = lowered.compile()
    jax.block_until_ready(compiled(*args))  # warm-up
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        jax.block_until_ready(compiled(*args))
        best = min(best, time.perf_counter() - t0)
    return {
        "jaxpr_eqns": count_eqns(jaxpr), "hlo_ops": count_hlo_ops(lowered),
        "trace_s": round(trace_s, 3), "lower_s": round(lower_s, 3),
        "ms_per_step": round(best / num_steps * 1e3, 3), "best_s": best,
    }


def measure(n_host_chunks: int, num_steps: int) -> dict:
    from repro.core.parallel import ParallelContext
    from repro.runtime import decode_loop as DL

    cfg, params, cache, tok0 = _setup()
    par = ParallelContext(mesh=None) if n_host_chunks else None

    def f(cache, tok, pos, key):
        return DL.decode_tokens(cfg, par, params, cache, tok, pos,
                                num_steps=num_steps,
                                n_host_chunks=n_host_chunks, key=key)

    args = (cache, tok0, jnp.full((B,), PROMPT, jnp.int32), jax.random.PRNGKey(2))
    r = _measure_program(f, args, num_steps)
    return {
        "n_host_chunks": n_host_chunks, "num_steps": num_steps,
        "jaxpr_eqns": r["jaxpr_eqns"], "hlo_ops": r["hlo_ops"],
        "trace_s": r["trace_s"], "lower_s": r["lower_s"],
        "ms_per_step": r["ms_per_step"],
        "tok_per_s": round(num_steps * B / r["best_s"], 1),
    }


def measure_mixed(cp: int, n_host_chunks: int, num_steps: int) -> dict:
    """Program size / wall-clock of the fused mixed-step segment
    (``runtime.decode_loop.mixed_segment``): one slot mid-prefill, one
    decoding — both `lax.cond` branches traced.  The acceptance bar is
    flatness in ALL THREE knobs: prefill chunk length, host-KV slab count,
    and steps per segment."""
    import jax
    import jax.numpy as jnp

    from repro.core.parallel import ParallelContext
    from repro.models import serve as SV
    from repro.runtime import decode_loop as DL

    cfg, params, _, _ = _setup()
    par = ParallelContext(mesh=None) if n_host_chunks else None
    b = 2
    P = 2 * cp
    S = P + 32  # divisible by 2 and 32 whenever cp is a multiple of 16
    if n_host_chunks:
        S = -(-S // n_host_chunks) * n_host_chunks
    cache = SV.init_cache(cfg, b, S)
    mode = jnp.asarray([DL.PREFILL, DL.DECODE], jnp.int32)
    tok = jnp.zeros((b, 1), jnp.int32)
    pos = jnp.asarray([0, PROMPT], jnp.int32)
    rem = jnp.full((b,), 16, jnp.int32)
    pfill = jnp.zeros((b,), jnp.int32)
    pend = jnp.zeros((b, P), jnp.int32)
    plen = jnp.asarray([P, PROMPT], jnp.int32)

    def f(cache, mode, tok, pos, key, rem, pfill, pend, plen):
        return DL.mixed_segment(cfg, par, params, cache, mode, tok, pos, key,
                                rem, pfill, pend, plen, num_steps=num_steps,
                                prefill_chunk=cp, n_host_chunks=n_host_chunks)

    args = (cache, mode, tok, pos, jax.random.PRNGKey(2), rem, pfill, pend, plen)
    r = _measure_program(f, args, num_steps)
    r.pop("best_s")
    return {"cp": cp, "n_host_chunks": n_host_chunks, "num_steps": num_steps, **r}


def mixed_sweep(cps=(64, 128, 256), chunk_sweep=(2, 32), gen_sweep=(2, 32),
                fixed_cp=64, fixed_chunks=2, fixed_gen=8) -> List[dict]:
    recs = []

    def show(r):
        print("mixed cp={cp:<4d} chunks={n_host_chunks:<3d} steps={num_steps:<3d} "
              "jaxpr_eqns={jaxpr_eqns:<6d} hlo_ops={hlo_ops:<6d} "
              "trace={trace_s}s lower={lower_s}s ms/step={ms_per_step}".format(**r))

    for cp in cps:
        recs.append(measure_mixed(cp, fixed_chunks, fixed_gen))
        show(recs[-1])
    for c in chunk_sweep:
        recs.append(measure_mixed(fixed_cp, c, fixed_gen))
        show(recs[-1])
    for g in gen_sweep:
        recs.append(measure_mixed(fixed_cp, fixed_chunks, g))
        show(recs[-1])
    return recs


def staggered_workload(blocking: bool = False, *, slots: int = 4,
                       requests: int = 12, bucket: int = 32, cp: int = 4,
                       gen: int = 24, seed: int = 0, warmup: bool = True) -> dict:
    """Staggered-arrival latency workload: more requests than slots, mixed
    prompt lengths, a stop token staggering finishes — so refills land
    while other slots are mid-decode.  ``segment=1`` makes every dispatch
    one mixed step, i.e. dispatch wall-clock IS the inter-token latency of
    the decoding slots.  Returns p50 steady / p95 refill-active latency,
    tokens/s, dispatch counts, and the engine's compiled-program set."""
    import numpy as np

    import jax

    from repro.runtime import decode_loop as DL

    cfg, params, _, _ = _setup()
    rng = np.random.default_rng(seed)
    lens = rng.integers(bucket // 4, bucket + 1, size=requests)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).tolist() for n in lens]
    stop = int(rng.integers(0, cfg.vocab_size))
    if blocking:
        eng = DL.BlockingServeEngine(cfg, params, slots=slots, bucket=bucket,
                                     max_new_tokens=gen, segment=1,
                                     stop_tokens=(stop,))
    else:
        eng = DL.ServeEngine(cfg, params, slots=slots, bucket=bucket,
                             max_new_tokens=gen, segment=1, prefill_chunk=cp,
                             stop_tokens=(stop,))
    if warmup:  # absorb compiles so latencies measure the hot path
        eng.generate(prompts, key=jax.random.PRNGKey(seed))
    programs_before = eng.compiled_programs() if not blocking else None
    t0 = time.perf_counter()
    outs = eng.generate(prompts, key=jax.random.PRNGKey(seed))
    wall = time.perf_counter() - t0
    steps = eng.last_stats["steps"]
    steady = [s["ms"] for s in steps if not s["prefilling"] and s["emitted"]]
    refill = [s["ms"] for s in steps if s["prefilling"] and s["emitted"]]
    total = sum(len(o) for o in outs)

    def pct(xs, q):
        return round(float(np.percentile(xs, q)), 3) if xs else float("nan")

    p50_steady, p95_steady = pct(steady, 50), pct(steady, 95)
    p50_refill, p95_refill = pct(refill, 50), pct(refill, 95)
    return {
        "engine": "blocking" if blocking else "fused",
        "slots": slots, "requests": requests, "bucket": bucket,
        "prefill_chunk": None if blocking else cp, "gen": gen,
        "tokens": total, "tok_per_s": round(total / wall, 1),
        "p50_steady_ms": p50_steady, "p95_steady_ms": p95_steady,
        "p50_refill_ms": p50_refill, "p95_refill_ms": p95_refill,
        # p95 vs p50 is the ISSUE's stall bar; on a shared/noisy host the
        # p50-based factor is the stable signal (OS jitter puts even the
        # steady-state p95 far above the steady-state p50)
        "refill_over_steady": round(p95_refill / p50_steady, 3),
        "stall_factor_p50": round(p50_refill / p50_steady, 3),
        "refill_steps": len(refill), "steady_steps": len(steady),
        "dispatches": eng.last_stats["dispatches"],
        "programs_before": programs_before,
        "programs": eng.compiled_programs() if not blocking else None,
    }


def sweep(chunk_sweep=(0, 2, 8, 32), gen_sweep=(2, 8, 32),
          fixed_gen=8, fixed_chunks=4) -> List[dict]:
    recs = []

    def show(r):
        print("chunks={n_host_chunks:<3d} steps={num_steps:<3d} "
              "jaxpr_eqns={jaxpr_eqns:<6d} hlo_ops={hlo_ops:<6d} "
              "trace={trace_s}s lower={lower_s}s "
              "ms/step={ms_per_step:<8} tok/s={tok_per_s}".format(**r))

    for c in chunk_sweep:
        recs.append(measure(c, fixed_gen))
        show(recs[-1])
    for g in gen_sweep:
        recs.append(measure(fixed_chunks, g))
        show(recs[-1])
    return recs


def run() -> List[str]:
    """benchmarks.run entry: summarized growth factors + throughput + the
    staggered-arrival scheduler workload (fused vs blocking baseline)."""
    recs = sweep(chunk_sweep=(2, 32), gen_sweep=(2, 32), fixed_gen=8, fixed_chunks=4)
    by_c = {r["n_host_chunks"]: r for r in recs[:2]}
    by_g = {r["num_steps"]: r for r in recs[2:]}
    rows = ["bench,name,value,derived"]
    g = by_c[32]["hlo_ops"] / by_c[2]["hlo_ops"]
    rows.append(f"bench,decode_hlo_growth_chunks_2_to_32,{g:.3f},x")
    g = by_g[32]["hlo_ops"] / by_g[2]["hlo_ops"]
    rows.append(f"bench,decode_hlo_growth_gen_2_to_32,{g:.3f},x")
    rows.append(f"bench,decode_tok_per_s_u4_gen32,{by_g[32]['tok_per_s']},tok/s")
    mixed = mixed_sweep()
    by_cp = {r["cp"]: r for r in mixed[:3]}
    by_mc = {r["n_host_chunks"]: r for r in mixed[3:5]}
    by_mg = {r["num_steps"]: r for r in mixed[5:]}
    g = by_cp[256]["hlo_ops"] / by_cp[64]["hlo_ops"]
    rows.append(f"bench,mixed_hlo_growth_cp_64_to_256,{g:.3f},x")
    g = by_mc[32]["hlo_ops"] / by_mc[2]["hlo_ops"]
    rows.append(f"bench,mixed_hlo_growth_chunks_2_to_32,{g:.3f},x")
    g = by_mg[32]["hlo_ops"] / by_mg[2]["hlo_ops"]
    rows.append(f"bench,mixed_hlo_growth_gen_2_to_32,{g:.3f},x")
    for r in (staggered_workload(blocking=False), staggered_workload(blocking=True)):
        e = r["engine"]
        rows.append(f"bench,serve_{e}_tok_per_s,{r['tok_per_s']},tok/s")
        rows.append(f"bench,serve_{e}_p50_steady_ms,{r['p50_steady_ms']},ms")
        rows.append(f"bench,serve_{e}_p95_steady_ms,{r['p95_steady_ms']},ms")
        rows.append(f"bench,serve_{e}_p50_refill_ms,{r['p50_refill_ms']},ms")
        rows.append(f"bench,serve_{e}_p95_refill_ms,{r['p95_refill_ms']},ms")
        rows.append(f"bench,serve_{e}_refill_over_steady,{r['refill_over_steady']},x")
        rows.append(f"bench,serve_{e}_stall_factor_p50,{r['stall_factor_p50']},x")
        rows.append(f"bench,serve_{e}_dispatches,{r['dispatches']},count")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    recs = sweep()
    by_c = {r["n_host_chunks"]: r for r in recs[:4]}
    by_g = {r["num_steps"]: r for r in recs[4:]}
    print(f"\nhost-chunk growth 2 -> 32 (gen=8):  "
          f"jaxpr x{by_c[32]['jaxpr_eqns'] / by_c[2]['jaxpr_eqns']:.2f}, "
          f"hlo x{by_c[32]['hlo_ops'] / by_c[2]['hlo_ops']:.2f}")
    print(f"gen-length growth 2 -> 32 (u=4):    "
          f"jaxpr x{by_g[32]['jaxpr_eqns'] / by_g[2]['jaxpr_eqns']:.2f}, "
          f"hlo x{by_g[32]['hlo_ops'] / by_g[2]['hlo_ops']:.2f}")
    print()
    mixed = mixed_sweep()
    by_cp = {r["cp"]: r for r in mixed[:3]}
    by_mc = {r["n_host_chunks"]: r for r in mixed[3:5]}
    by_mg = {r["num_steps"]: r for r in mixed[5:]}
    print(f"\nmixed-step growth cp 64 -> 256:     "
          f"jaxpr x{by_cp[256]['jaxpr_eqns'] / by_cp[64]['jaxpr_eqns']:.2f}, "
          f"hlo x{by_cp[256]['hlo_ops'] / by_cp[64]['hlo_ops']:.2f}")
    print(f"mixed-step growth chunks 2 -> 32:   "
          f"jaxpr x{by_mc[32]['jaxpr_eqns'] / by_mc[2]['jaxpr_eqns']:.2f}, "
          f"hlo x{by_mc[32]['hlo_ops'] / by_mc[2]['hlo_ops']:.2f}")
    print(f"mixed-step growth gen 2 -> 32:      "
          f"jaxpr x{by_mg[32]['jaxpr_eqns'] / by_mg[2]['jaxpr_eqns']:.2f}, "
          f"hlo x{by_mg[32]['hlo_ops'] / by_mg[2]['hlo_ops']:.2f}")
    print("\nstaggered-arrival workload (segment=1, per-step latencies):")
    stag = [staggered_workload(blocking=False), staggered_workload(blocking=True)]
    for r in stag:
        print(f"  {r['engine']:<9s} tok/s={r['tok_per_s']:<8} "
              f"steady p50/p95={r['p50_steady_ms']}/{r['p95_steady_ms']} ms  "
              f"refill-active p50/p95={r['p50_refill_ms']}/{r['p95_refill_ms']} ms "
              f"(p50 stall x{r['stall_factor_p50']})  dispatches={r['dispatches']}")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump({"decode": recs, "mixed_step": mixed, "staggered": stag},
                      fh, indent=1)


if __name__ == "__main__":
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)  # for `from benchmarks.compile_scaling import`
    sys.path.insert(0, os.path.join(_root, "src"))
    main()
