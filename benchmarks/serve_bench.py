"""Program-size / throughput scaling of the scan-compiled decode engine.

The serving acceptance bar mirrors the training one
(`benchmarks/compile_scaling.py`): decode program size must be flat in
BOTH knobs that used to unroll —

  * ``n_host_chunks`` — the host-KV streaming loop is
    `runtime.placement.fori_double_buffered` (body traced once), where the
    retired generator-based path emitted one online-softmax merge per chunk;
  * generated-token count — the whole generation is one
    `runtime.decode_loop.decode_tokens` `lax.scan`, where the per-token
    Python loop re-dispatched (and on first use re-traced) per token.

For every cell this reports traced jaxpr equation count, StableHLO op
count of the lowered module, trace+lower wall-clock, and (post-compile)
ms/step and tokens/sec on the real machine.  Emits name,value rows for
``benchmarks.run`` plus a JSON blob; the measured table is committed in
``docs/serving.md``.

Usage: python benchmarks/serve_bench.py [--json out.json]
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import os
import sys
import time
from typing import List

import jax
import jax.numpy as jnp

B = 2           # batch rows
PROMPT = 16     # prefill length
CACHE_LEN = 64  # cache capacity: divisible by every chunk count below


@functools.lru_cache(maxsize=1)
def _setup():
    from repro.configs import get_config, reduced
    from repro.models import serve as SV
    from repro.models import transformer as T

    cfg = dataclasses.replace(reduced(get_config("llama3.2-1b")),
                              param_dtype="float32", remat="none")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, PROMPT), 0, cfg.vocab_size)
    logits, cache = SV.prefill_step(cfg, None, params, {"tokens": toks},
                                    max_len=CACHE_LEN)
    tok0 = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)[:, None]
    return cfg, params, cache, tok0


def _measure_program(f, args, num_steps: int) -> dict:
    """Shared harness: trace/lower/compile `f(*args)` and time the hot path
    (min of 3 after a warm-up).  Both the decode-scan and the mixed-step
    benchmarks report through this so their numbers stay comparable."""
    import jax

    from benchmarks.compile_scaling import count_eqns, count_hlo_ops

    t0 = time.perf_counter()
    jaxpr = jax.make_jaxpr(f)(*args)
    trace_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    lowered = jax.jit(f).lower(*args)
    lower_s = time.perf_counter() - t0
    compiled = lowered.compile()
    jax.block_until_ready(compiled(*args))  # warm-up
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        jax.block_until_ready(compiled(*args))
        best = min(best, time.perf_counter() - t0)
    return {
        "jaxpr_eqns": count_eqns(jaxpr), "hlo_ops": count_hlo_ops(lowered),
        "trace_s": round(trace_s, 3), "lower_s": round(lower_s, 3),
        "ms_per_step": round(best / num_steps * 1e3, 3), "best_s": best,
    }


def measure(n_host_chunks: int, num_steps: int) -> dict:
    from repro.core.parallel import ParallelContext
    from repro.runtime import decode_loop as DL

    cfg, params, cache, tok0 = _setup()
    par = ParallelContext(mesh=None) if n_host_chunks else None

    def f(cache, tok, pos, key):
        return DL.decode_tokens(cfg, par, params, cache, tok, pos,
                                num_steps=num_steps,
                                n_host_chunks=n_host_chunks, key=key)

    args = (cache, tok0, jnp.full((B,), PROMPT, jnp.int32), jax.random.PRNGKey(2))
    r = _measure_program(f, args, num_steps)
    return {
        "n_host_chunks": n_host_chunks, "num_steps": num_steps,
        "jaxpr_eqns": r["jaxpr_eqns"], "hlo_ops": r["hlo_ops"],
        "trace_s": r["trace_s"], "lower_s": r["lower_s"],
        "ms_per_step": r["ms_per_step"],
        "tok_per_s": round(num_steps * B / r["best_s"], 1),
    }


def measure_mixed(cp: int, n_host_chunks: int, num_steps: int) -> dict:
    """Program size / wall-clock of the fused mixed-step segment
    (``runtime.decode_loop.mixed_segment``): one slot mid-prefill, one
    decoding — both `lax.cond` branches traced.  The acceptance bar is
    flatness in ALL THREE knobs: prefill chunk length, host-KV slab count,
    and steps per segment."""
    import jax
    import jax.numpy as jnp

    from repro.core.parallel import ParallelContext
    from repro.models import serve as SV
    from repro.runtime import decode_loop as DL

    cfg, params, _, _ = _setup()
    par = ParallelContext(mesh=None) if n_host_chunks else None
    b = 2
    P = 2 * cp
    S = P + 32  # divisible by 2 and 32 whenever cp is a multiple of 16
    if n_host_chunks:
        S = -(-S // n_host_chunks) * n_host_chunks
    cache = SV.init_cache(cfg, b, S)
    mode = jnp.asarray([DL.PREFILL, DL.DECODE], jnp.int32)
    tok = jnp.zeros((b, 1), jnp.int32)
    pos = jnp.asarray([0, PROMPT], jnp.int32)
    rem = jnp.full((b,), 16, jnp.int32)
    pfill = jnp.zeros((b,), jnp.int32)
    pend = jnp.zeros((b, P), jnp.int32)
    plen = jnp.asarray([P, PROMPT], jnp.int32)

    def f(cache, mode, tok, pos, key, rem, pfill, pend, plen):
        return DL.mixed_segment(cfg, par, params, cache, mode, tok, pos, key,
                                rem, pfill, pend, plen, num_steps=num_steps,
                                prefill_chunk=cp, n_host_chunks=n_host_chunks)

    args = (cache, mode, tok, pos, jax.random.PRNGKey(2), rem, pfill, pend, plen)
    r = _measure_program(f, args, num_steps)
    r.pop("best_s")
    return {"cp": cp, "n_host_chunks": n_host_chunks, "num_steps": num_steps, **r}


def mixed_sweep(cps=(64, 128, 256), chunk_sweep=(2, 32), gen_sweep=(2, 32),
                fixed_cp=64, fixed_chunks=2, fixed_gen=8) -> List[dict]:
    recs = []

    def show(r):
        print("mixed cp={cp:<4d} chunks={n_host_chunks:<3d} steps={num_steps:<3d} "
              "jaxpr_eqns={jaxpr_eqns:<6d} hlo_ops={hlo_ops:<6d} "
              "trace={trace_s}s lower={lower_s}s ms/step={ms_per_step}".format(**r))

    for cp in cps:
        recs.append(measure_mixed(cp, fixed_chunks, fixed_gen))
        show(recs[-1])
    for c in chunk_sweep:
        recs.append(measure_mixed(fixed_cp, c, fixed_gen))
        show(recs[-1])
    for g in gen_sweep:
        recs.append(measure_mixed(fixed_cp, fixed_chunks, g))
        show(recs[-1])
    return recs


def prefill_overhead(cp: int, num_steps: int = 8, slots: int = 4) -> dict:
    """ROADMAP PR-4 open item: what does the fused chunk program cost when
    exactly ONE slot prefills, versus the all-decode fast path the same
    batch takes when nobody does?  ``lax.cond`` picks the branch at run
    time from the same compiled segment, so the two cells below time the
    same program down its two paths — the measured ratio is the overhead a
    per-slot grouping (separate prefill/decode sub-batch programs) would
    have to beat."""
    import jax
    import jax.numpy as jnp

    from repro.runtime import decode_loop as DL

    cfg, params, _, _ = _setup()
    from repro.models import serve as SV

    b = slots
    P = (num_steps + 1) * cp  # the prefilling slot stays PREFILL throughout
    S = P + 32
    pend = jnp.zeros((b, P), jnp.int32)

    def f(cache, mode, tok, pos, key, rem, pfill, plen):
        return DL.mixed_segment(cfg, None, params, cache, mode, tok, pos, key,
                                rem, pfill, pend, plen, num_steps=num_steps,
                                prefill_chunk=cp)

    def args_for(n_prefill):
        cache = SV.init_cache(cfg, b, S)
        mode = jnp.asarray([DL.PREFILL] * n_prefill
                           + [DL.DECODE] * (b - n_prefill), jnp.int32)
        tok = jnp.zeros((b, 1), jnp.int32)
        pos = jnp.full((b,), PROMPT, jnp.int32).at[:n_prefill].set(0)
        rem = jnp.full((b,), num_steps + P, jnp.int32)
        pfill = jnp.zeros((b,), jnp.int32)
        plen = jnp.full((b,), P, jnp.int32)
        return cache, mode, tok, pos, jax.random.PRNGKey(2), rem, pfill, plen

    dec = _measure_program(f, args_for(0), num_steps)
    pf = _measure_program(f, args_for(1), num_steps)
    return {"cp": cp, "slots": slots,
            "decode_ms_per_step": dec["ms_per_step"],
            "one_prefill_ms_per_step": pf["ms_per_step"],
            "overhead_x": round(pf["ms_per_step"]
                                / max(dec["ms_per_step"], 1e-9), 3)}


def measure_paged(n_pages: int, num_steps: int, page_size: int = 16,
                  n_host_chunks: int = 0) -> dict:
    """Program size / wall-clock of the PAGED mixed-step segment (one slot
    mid-prefill, one decoding, K/V gathered through the page table).  The
    acceptance bar: flat in ``n_pages`` — the pool only changes array
    dimensions, never the program."""
    import jax
    import jax.numpy as jnp

    from repro.core.parallel import ParallelContext
    from repro.models import serve as SV
    from repro.runtime import decode_loop as DL
    from repro.runtime import paged as PG

    cfg, params, _, _ = _setup()
    par = ParallelContext(mesh=None) if n_host_chunks else None
    b, cp = 2, 16
    P = 2 * cp
    max_pages = -(-(P + 32) // page_size)
    cache = SV.init_paged_cache(cfg, b, n_pages, page_size)
    mgr = PG.PagedCacheManager(n_pages, page_size, use_radix=False)
    mgr.begin(b, max_pages)
    mgr.admit(0, list(range(P)), 32)
    mgr.admit(1, list(range(PROMPT)), 32)
    table = jnp.asarray(mgr.table)
    mode = jnp.asarray([DL.PREFILL, DL.DECODE], jnp.int32)
    tok = jnp.zeros((b, 1), jnp.int32)
    pos = jnp.asarray([0, PROMPT], jnp.int32)
    rem = jnp.full((b,), 16, jnp.int32)
    pfill = jnp.zeros((b,), jnp.int32)
    pend = jnp.zeros((b, P), jnp.int32)
    plen = jnp.asarray([P, PROMPT], jnp.int32)

    def f(cache, mode, tok, pos, key, rem, pfill, pend, plen, table):
        return DL.mixed_segment(cfg, par, params, cache, mode, tok, pos, key,
                                rem, pfill, pend, plen, num_steps=num_steps,
                                prefill_chunk=cp, n_host_chunks=n_host_chunks,
                                table=table)

    args = (cache, mode, tok, pos, jax.random.PRNGKey(2), rem, pfill, pend,
            plen, table)
    r = _measure_program(f, args, num_steps)
    r.pop("best_s")
    return {"n_pages": n_pages, "page_size": page_size,
            "n_host_chunks": n_host_chunks, "num_steps": num_steps, **r}


def shared_prefix_workload(*, prefix_len: int = 1024, requests: int = 8,
                           suffix: int = 32, slots: int = 2, gen: int = 16,
                           cp: int = 64, page_size: int = 16, seed: int = 0,
                           segment: int = 1, dense_baseline: bool = True
                           ) -> dict:
    """The acceptance workload: ``requests`` prompts sharing a
    ``prefix_len``-token system prompt with distinct suffixes.  The paged
    engine (radix on) maps the shared pages copy-free, so every request
    after the pipelined first wave prefills only its suffix; the dense
    engine recomputes the prefix per request.  ``n_pages`` is the
    dense-EQUAL budget (slots x ceil(capacity / page_size)), so tok/s and
    p50/p95 inter-token latency compare at equal memory."""
    import numpy as np

    import jax

    from repro.runtime import decode_loop as DL
    from repro.runtime import paged as PG

    cfg, params, _, _ = _setup()
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab_size, size=prefix_len).tolist()
    prompts = [shared + rng.integers(0, cfg.vocab_size, size=suffix).tolist()
               for _ in range(requests)]
    bucket = prefix_len + suffix
    kw = dict(slots=slots, bucket=bucket, max_new_tokens=gen, segment=segment,
              prefill_chunk=cp)
    out = {"prefix_len": prefix_len, "requests": requests, "slots": slots,
           "page_size": page_size, "prefill_chunk": cp, "gen": gen}

    def timed(eng):
        eng.generate(prompts[:1], key=jax.random.PRNGKey(seed))  # compile
        t0 = time.perf_counter()
        outs = eng.generate(prompts, key=jax.random.PRNGKey(seed))
        wall = time.perf_counter() - t0
        steps = [s["ms"] for s in eng.last_stats["steps"] if s["emitted"]]
        toks = sum(len(o) for o in outs)
        return outs, {"tok_per_s": round(toks / wall, 1),
                      "p50_ms": round(float(np.percentile(steps, 50)), 3),
                      "p95_ms": round(float(np.percentile(steps, 95)), 3)}

    paged = PG.PagedServeEngine(cfg, params, page_size=page_size, **kw)
    # absorb every compile BEFORE snapshotting the program set: a tiny
    # identical-prompt triple (disjoint tokens, so the measured hit stats
    # stay first-serve) exercises the COW copy, then timed()'s own warm-up
    # covers the segment at workload shapes — after this, re-runs compile
    # NOTHING (the bounded-program-set assertion in tests/test_paged.py)
    wrng = np.random.default_rng(seed + 1)
    w = wrng.integers(0, cfg.vocab_size, size=2 * page_size).tolist()
    paged.generate([w] * 3, key=jax.random.PRNGKey(seed))
    paged.generate(prompts[:1], key=jax.random.PRNGKey(seed))
    programs_before = paged.compiled_programs()
    paged_out, pstats = timed(paged)
    st = paged.last_stats
    out.update({f"paged_{k}": v for k, v in pstats.items()})
    out["hit_rate"] = round(st["prefix_hit_tokens"]
                            / max(st["prompt_tokens"], 1), 3)
    out["prefilled_tokens"] = st["prefilled_tokens"]
    out["prompt_tokens"] = st["prompt_tokens"]
    out["pages_peak"] = st["pages_peak"]
    out["dense_equiv_pages"] = slots * -(-st["capacity"] // page_size)
    out["n_pages"] = paged.n_pages
    out["programs_before"] = programs_before
    out["programs"] = paged.compiled_programs()
    if dense_baseline:
        dense_out, dstats = timed(DL.ServeEngine(cfg, params, **kw))
        out.update({f"dense_{k}": v for k, v in dstats.items()})
        out["outputs_match"] = paged_out == dense_out
    return out


def restart_reuse_workload(*, prefix_len: int = 192, requests: int = 6,
                           suffix: int = 16, slots: int = 2, gen: int = 16,
                           cp: int = 16, page_size: int = 16,
                           spill_pages: int = 64, seed: int = 0) -> dict:
    """The kv-store acceptance workload: serve ``requests`` prompts sharing
    a ``prefix_len``-token system prompt, persist the prefix cache
    (``save_kv_store``), then serve the SAME shape of workload from a
    FRESH engine three ways — cold (no store: the restart penalty),
    restored (``restore_kv_store``: every request's shared prefix is a
    radix hit promoted from the spill tier), and the first engine's own
    in-process re-run as the ceiling.  Outputs must match between cold and
    restored runs (the promoted pages hold bit-identical KV)."""
    import tempfile

    import numpy as np

    import jax

    from repro.runtime import paged as PG

    cfg, params, _, _ = _setup()
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab_size, size=prefix_len).tolist()
    prompts = [shared + rng.integers(0, cfg.vocab_size, size=suffix).tolist()
               for _ in range(requests)]
    kw = dict(slots=slots, bucket=prefix_len + suffix, max_new_tokens=gen,
              segment=1, prefill_chunk=cp, page_size=page_size,
              spill_pages=spill_pages)

    def fresh():
        eng = PG.PagedServeEngine(cfg, params, **kw)
        # absorb compiles on DISJOINT tokens so the measured runs are hot
        # but their radix state stays untouched by warm-up prefixes
        w = np.random.default_rng(seed + 1).integers(
            0, cfg.vocab_size, size=2 * page_size).tolist()
        eng.generate([w] * 2, key=jax.random.PRNGKey(seed))
        return eng

    def timed(eng):
        t0 = time.perf_counter()
        outs = eng.generate(prompts, key=jax.random.PRNGKey(seed))
        wall = time.perf_counter() - t0
        st = eng.last_stats
        return outs, {
            "tok_per_s": round(sum(len(o) for o in outs) / wall, 1),
            "hit_rate": round(st["prefix_hit_tokens"]
                              / max(st["prompt_tokens"], 1), 3),
            "prefilled_tokens": st["prefilled_tokens"],
            "spill_promotes": st["spill_promotes"],
        }

    store = os.path.join(tempfile.mkdtemp(prefix="kv_store_bench"), "kv.npz")
    first = fresh()
    cold_out, cold = timed(first)           # restart penalty baseline
    saved = first.save_kv_store(store)

    restored_eng = fresh()
    n_restored = restored_eng.restore_kv_store(store)
    restored_out, restored = timed(restored_eng)

    return {
        "prefix_len": prefix_len, "requests": requests,
        "page_size": page_size, "spill_pages": spill_pages,
        "saved_pages": saved, "restored_pages": n_restored,
        "cold": cold, "restored": restored,
        "outputs_match": restored_out == cold_out,
        "programs": restored_eng.compiled_programs(),
        "store_bytes": os.path.getsize(store),
    }


def run_kv_store() -> List[str]:
    """benchmarks.run entry for the ``kv_store`` suite: the restart-reuse
    workload — a fresh engine restored from a persisted prefix cache must
    re-serve a shared system prompt as radix hits (> 90% of prompt
    tokens), at a measured tok/s against the cold-restart baseline."""
    r = restart_reuse_workload()
    print(f"kv-store: saved={r['saved_pages']} pages "
          f"({r['store_bytes']} bytes), restored={r['restored_pages']}; "
          f"cold hit={r['cold']['hit_rate']} tok/s={r['cold']['tok_per_s']} "
          f"vs restored hit={r['restored']['hit_rate']} "
          f"tok/s={r['restored']['tok_per_s']} "
          f"(promotes={r['restored']['spill_promotes']}, "
          f"match={r['outputs_match']})")
    rows = ["bench,name,value,derived"]
    rows.append(f"bench,kv_store_saved_pages,{r['saved_pages']},pages")
    rows.append(f"bench,kv_store_restored_pages,{r['restored_pages']},pages")
    rows.append(f"bench,kv_store_bytes,{r['store_bytes']},bytes")
    for mode in ("cold", "restored"):
        m = r[mode]
        rows.append(f"bench,kv_store_{mode}_tok_per_s,{m['tok_per_s']},tok/s")
        rows.append(f"bench,kv_store_{mode}_hit_rate,{m['hit_rate']},fraction")
        rows.append(f"bench,kv_store_{mode}_prefilled_tokens,"
                    f"{m['prefilled_tokens']},count")
    rows.append(f"bench,kv_store_restored_promotes,"
                f"{r['restored']['spill_promotes']},count")
    rows.append(f"bench,kv_store_outputs_match,{int(r['outputs_match'])},bool")
    for k, v in r["programs"].items():
        rows.append(f"bench,kv_store_programs_{k},{v},count")
    return rows


# ---------------------------------------------------------------------------
# SLO scheduling: heavy-tailed traffic simulator + goodput-under-SLO
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TrafficTier:
    """One QoS class of the simulated tenant mix.  Lengths are the UNIQUE
    tail appended to a shared prefix; SLOs are in dispatch steps (the
    deterministic clock of ``SLOPagedServeEngine``), ``inf`` = no bound."""
    name: str
    priority: int
    share: float
    tail_lo: int
    tail_hi: int
    ttft_slo: float
    itl_slo: float
    prefill_chunks: int = 0


@dataclasses.dataclass(frozen=True)
class SimRequest:
    """One simulated request: a concrete token stream plus its arrival
    step and the QoS contract inherited from its tier."""
    idx: int
    arrival: int
    tokens: tuple
    prefix_id: int
    tier: str
    priority: int
    ttft_slo: float
    itl_slo: float
    prefill_chunks: int


DEFAULT_TIERS = (
    TrafficTier("interactive", 0, 0.7, 4, 24, 10.0, 8.0),
    TrafficTier("batch", 1, 0.3, 64, 160, float("inf"), float("inf"), 2),
)


def traffic_trace(*, seed: int = 0, n_requests: int = 24, vocab: int = 256,
                  n_prefixes: int = 4, zipf_a: float = 1.1,
                  prefix_len: int = 8, rate: float = 0.2,
                  burst_p: float = 0.25, burst_k: int = 3,
                  tail_alpha: float = 2.0, tiers=DEFAULT_TIERS):
    """Deterministic heavy-tailed multi-tenant trace.

    * **Zipf prompt sharing** — each request opens with one of
      ``n_prefixes`` shared prefixes drawn with weight ``1/rank^zipf_a``
      (the radix tree's reason to exist: a few system prompts dominate);
    * **Poisson + burst arrivals** — exponential inter-arrival gaps at
      ``rate`` requests/step, and with probability ``burst_p`` a gap
      delivers a burst of ``burst_k`` simultaneous requests;
    * **heavy-tailed lengths** — the unique tail is
      ``tail_lo + Pareto(tail_alpha)``-scaled, clipped to the tier's
      ``tail_hi`` (mixed short interactive / long batch contexts);
    * **tiers** — requests are assigned to ``tiers`` by share, inheriting
      priority, TTFT/ITL SLOs (in dispatch steps), and prefill budgets.

    Everything flows from one ``numpy.random.default_rng(seed)`` (PCG64 —
    stable across platforms and processes), so the same seed yields a
    byte-identical trace anywhere: FIFO-vs-SLO comparisons replay the
    exact same offered load.  Arrivals are non-decreasing integers.
    """
    import numpy as np

    rng = np.random.default_rng(seed)
    prefixes = [rng.integers(0, vocab, size=prefix_len).tolist()
                for _ in range(n_prefixes)]
    w = np.array([1.0 / (k + 1) ** zipf_a for k in range(n_prefixes)])
    w /= w.sum()
    shares = np.array([t.share for t in tiers], float)
    shares /= shares.sum()
    reqs, t, i = [], 0.0, 0
    while i < n_requests:
        t += rng.exponential(1.0 / rate)
        k = burst_k if rng.random() < burst_p else 1
        for _ in range(min(k, n_requests - i)):
            tier = tiers[int(rng.choice(len(tiers), p=shares))]
            pid = int(rng.choice(n_prefixes, p=w))
            span = max(tier.tail_hi - tier.tail_lo, 1)
            tail_len = tier.tail_lo + min(
                int(rng.pareto(tail_alpha) * 0.25 * span), span)
            tail = rng.integers(0, vocab, size=tail_len).tolist()
            reqs.append(SimRequest(
                idx=i, arrival=int(t), tokens=tuple(prefixes[pid] + tail),
                prefix_id=pid, tier=tier.name, priority=tier.priority,
                ttft_slo=tier.ttft_slo, itl_slo=tier.itl_slo,
                prefill_chunks=tier.prefill_chunks))
            i += 1
    return reqs


def _slo_eval(trace, stats, outs, wall_s: float) -> dict:
    """Goodput-under-SLO from the engine's step-indexed per-request stats:
    a request is GOOD iff it emitted, its TTFT (first-emit step − arrival
    step) met the tier's TTFT SLO, and its worst inter-token gap met the
    ITL SLO.  Goodput = good tokens / total dispatch steps — deterministic
    given the trace (wall-clock figures ride along as informational)."""
    import numpy as np

    steps = max(stats["dispatches"], 1)
    good = good_tokens = 0
    ttft_by_tier: dict = {}
    for r, rs in zip(trace, stats["requests"]):
        ttft = (rs["first_emit"] - r.arrival
                if rs["first_emit"] is not None else float("inf"))
        ttft_by_tier.setdefault(r.tier, []).append(ttft)
        if (rs["n_emitted"] > 0 and ttft <= r.ttft_slo
                and rs["max_gap"] <= r.itl_slo):
            good += 1
            good_tokens += rs["n_emitted"]
    total_tokens = sum(len(o) for o in outs)
    return {
        "goodput": round(good_tokens / steps, 4),
        "good_requests": good, "good_tokens": good_tokens,
        "total_tokens": total_tokens, "steps": stats["dispatches"],
        "preemptions": stats["preemptions"],
        "prefill_pauses": stats["prefill_pauses"],
        "deferrals": stats["deferrals"],
        "tok_per_s": round(total_tokens / max(wall_s, 1e-9), 1),
        "p95_ttft": {tier: round(float(np.percentile(v, 95)), 1)
                     if np.isfinite(v).all() else float("inf")
                     for tier, v in ttft_by_tier.items()},
    }


def _slo_warmup(eng, cfg, page_size: int, seed: int) -> None:
    """Absorb every compile on DISJOINT warm-up tokens (identical per
    engine): a preempting pair exercises segment, reset and the
    full-cover COW copy; force-demoting the warm pages to the spill
    tier and re-serving them compiles the promote scatter.  After this
    the measured run compiles NOTHING, and the radix state the trace
    sees is untouched by warm-up prefixes (disjoint tokens — the
    measured hit stats stay first-serve)."""
    import numpy as np

    import jax

    from repro.runtime import decode_loop as DL

    wrng = np.random.default_rng(seed + 99)
    wp = wrng.integers(0, cfg.vocab_size, size=3 * page_size).tolist()
    warm = [DL.Request(tokens=tuple(wp), priority=1, arrival=0),
            DL.Request(tokens=tuple(wp), priority=0, arrival=2)]
    eng.generate(warm, key=jax.random.PRNGKey(seed))
    if eng.kv.radix is not None and eng.kv.spill is not None:
        eng.kv.radix.evict(len(wp) // page_size)
    eng.generate(warm, key=jax.random.PRNGKey(seed))


def slo_workload(*, seed: int = 0, n_requests: int = 24, slots: int = 2,
                 gen: int = 12, cp: int = 8, page_size: int = 4,
                 spill_pages: int = 32, prefill_budget: int = 2,
                 trace_kw: dict = None) -> dict:
    """The SLO acceptance workload: replay ONE seeded heavy-tailed trace
    through ``SLOPagedServeEngine`` under both admission policies (fresh
    engine + fresh radix per policy, identical warm-up) and compare
    goodput-under-SLO.  FIFO serves in arrival order with no preemption —
    a burst of tight-TTFT interactive requests queues behind long batch
    contexts; the SLO policy queue-jumps them and preempts batch slots
    through the radix/spill publish-release path.  Outputs must match
    byte-for-byte across policies (greedy sampling: preemption is
    lossless)."""
    import numpy as np

    import jax

    from repro.runtime import decode_loop as DL
    from repro.runtime import paged as PG

    cfg, params, _, _ = _setup()
    trace = traffic_trace(seed=seed, n_requests=n_requests,
                          vocab=cfg.vocab_size, **(trace_kw or {}))
    dl_reqs = [DL.Request(tokens=r.tokens, priority=r.priority,
                          arrival=r.arrival, itl_slo=r.itl_slo,
                          prefill_chunks=r.prefill_chunks, tier=r.tier)
               for r in trace]
    longest = max(len(r.tokens) for r in trace)
    kw = dict(slots=slots, bucket=longest + gen, max_new_tokens=gen,
              segment=1, prefill_chunk=cp, page_size=page_size,
              spill_pages=spill_pages, prefill_budget=prefill_budget)
    out = {"seed": seed, "n_requests": n_requests, "slots": slots,
           "gen": gen, "prefill_chunk": cp, "page_size": page_size,
           "longest_prompt": longest,
           "tiers": {t.name: dataclasses.asdict(t) for t in DEFAULT_TIERS}}
    outs_by_policy = {}
    for policy in ("fifo", "slo"):
        eng = PG.SLOPagedServeEngine(cfg, params, policy=policy, **kw)
        _slo_warmup(eng, cfg, page_size, seed)
        programs_before = dict(eng.compiled_programs())
        t0 = time.perf_counter()
        outs = eng.generate(dl_reqs, key=jax.random.PRNGKey(seed))
        wall = time.perf_counter() - t0
        outs_by_policy[policy] = outs
        out[policy] = _slo_eval(trace, eng.last_stats, outs, wall)
        out[policy]["programs_before"] = programs_before
        out[policy]["programs"] = dict(eng.compiled_programs())
    out["outputs_match"] = outs_by_policy["fifo"] == outs_by_policy["slo"]
    out["programs"] = out["slo"]["programs"]
    return out


def run_slo() -> List[str]:
    """benchmarks.run entry for the ``slo`` suite: FIFO vs SLO-aware
    scheduling on the same seeded heavy-tailed trace.  The acceptance
    claims (checked against the committed ``BENCH_slo.json`` by
    ``tests/test_bench_schema.py``): SLO-aware goodput >= FIFO goodput,
    preemptions actually happened, outputs identical across policies, and
    the compiled-program set still bounded at one each of
    {segment, reset, copy, promote}."""
    r = slo_workload()
    for p in ("fifo", "slo"):
        m = r[p]
        print(f"{p:>5s}: goodput={m['goodput']} tok/step "
              f"({m['good_requests']}/{r['n_requests']} good, "
              f"{m['good_tokens']}/{m['total_tokens']} tokens, "
              f"{m['steps']} steps)  preempts={m['preemptions']} "
              f"pauses={m['prefill_pauses']} defers={m['deferrals']} "
              f"p95_ttft={m['p95_ttft']}")
    print(f"outputs_match={r['outputs_match']} programs={r['programs']}")
    rows = ["bench,name,value,derived"]
    for p in ("fifo", "slo"):
        m = r[p]
        rows.append(f"bench,slo_goodput_{p},{m['goodput']},tok/step")
        rows.append(f"bench,slo_good_requests_{p},{m['good_requests']},count")
        rows.append(f"bench,slo_good_tokens_{p},{m['good_tokens']},count")
        rows.append(f"bench,slo_steps_{p},{m['steps']},count")
        rows.append(f"bench,slo_preemptions_{p},{m['preemptions']},count")
        rows.append(f"bench,slo_prefill_pauses_{p},{m['prefill_pauses']},count")
        rows.append(f"bench,slo_tok_per_s_{p},{m['tok_per_s']},tok/s")
        ttft = m["p95_ttft"].get("interactive", float("inf"))
        if ttft != float("inf"):
            rows.append(f"bench,slo_interactive_p95_ttft_{p},{ttft},steps")
    rows.append(f"bench,slo_requests,{r['n_requests']},count")
    rows.append(f"bench,slo_outputs_match,{int(r['outputs_match'])},bool")
    for k, v in r["programs"].items():
        rows.append(f"bench,slo_programs_{k},{v},count")
    return rows


def obs_workload(*, seed: int = 0, repeats: int = 4) -> dict:
    """Telemetry-overhead acceptance workload: the slo trace replayed
    through two fresh ``SLOPagedServeEngine``s — tracing OFF vs tracing
    ON — identical warm-up, best-of-``repeats`` wall clock each.  Event
    recording is a couple of dict appends next to a jitted dispatch, so
    the measured overhead must stay under 5% (the
    ``tests/test_bench_schema.py`` acceptance bar).  The traced engine's
    first measured run also feeds the trace-vs-scheduler cross-check:
    per-request summaries reconstructed from lifecycle spans alone
    (``telemetry.request_summaries``) must agree with the engine's own
    ``last_stats["requests"]`` accounting on first-emit step, token
    count and preemptions.  Tok/s derives from the registry's
    ``emitted_tokens`` counter, not a parallel tally."""
    import jax

    from repro.runtime import decode_loop as DL
    from repro.runtime import paged as PG

    cfg, params, _, _ = _setup()
    n_requests, gen, cp, page_size = 24, 12, 8, 4
    trace = traffic_trace(seed=seed, n_requests=n_requests,
                          vocab=cfg.vocab_size)
    dl_reqs = [DL.Request(tokens=r.tokens, priority=r.priority,
                          arrival=r.arrival, itl_slo=r.itl_slo,
                          prefill_chunks=r.prefill_chunks, tier=r.tier)
               for r in trace]
    longest = max(len(r.tokens) for r in trace)
    kw = dict(slots=2, bucket=longest + gen, max_new_tokens=gen,
              segment=1, prefill_chunk=cp, page_size=page_size,
              spill_pages=32, prefill_budget=2)
    out = {"seed": seed, "repeats": repeats, "n_requests": n_requests}
    engines = {}
    for mode in ("untraced", "traced"):
        eng = PG.SLOPagedServeEngine(cfg, params, policy="slo", **kw)
        eng.telemetry.set_tracing(False)  # warm-up stays out of the trace
        _slo_warmup(eng, cfg, page_size, seed)
        engines[mode] = eng
    engines["traced"].telemetry.set_tracing(True)
    # interleave the modes (flipping who goes first each round) so
    # process-level warm-up and drift hit both evenly — a sequential
    # all-of-one-then-all-of-the-other sweep systematically favors
    # whichever runs second
    best = {m: float("inf") for m in engines}
    emitted = {m: 0 for m in engines}
    traced_runs = 0
    for i in range(repeats):
        order = ("untraced", "traced") if i % 2 == 0 \
            else ("traced", "untraced")
        for mode in order:
            eng = engines[mode]
            tok0 = eng.telemetry.registry.value("emitted_tokens")
            t0 = time.perf_counter()
            eng.generate(dl_reqs, key=jax.random.PRNGKey(seed))
            wall = time.perf_counter() - t0
            best[mode] = min(best[mode], wall)
            emitted[mode] = \
                eng.telemetry.registry.value("emitted_tokens") - tok0
            if mode == "traced":
                traced_runs += 1
                if traced_runs == 1:
                    # cross-check while the trace holds exactly one run
                    summ = eng.telemetry.request_summaries()
                    st = eng.last_stats
                    ok = len(summ) >= n_requests
                    for ridx, rs in enumerate(st["requests"]):
                        s = summ.get(ridx)
                        ok = ok and s is not None \
                            and s["first_emit"] == rs["first_emit"] \
                            and s["n_emitted"] == rs["n_emitted"] \
                            and s["preemptions"] == rs["preemptions"]
                    out["summary_consistent"] = ok
                    out["preemptions"] = st["preemptions"]
                    out["trace_events"] = \
                        len(eng.telemetry.tracer.events)
    for mode, eng in engines.items():
        out[mode] = {"tok_per_s": round(emitted[mode] / best[mode], 1),
                     "best_s": best[mode], "emitted": emitted[mode]}
        out[f"programs_{mode}"] = dict(eng.compiled_programs())
        out[f"alerts_{mode}"] = eng.telemetry.alerts()
    out["programs"] = out["programs_traced"]
    out["overhead_pct"] = round(
        (out["traced"]["best_s"] - out["untraced"]["best_s"])
        / out["untraced"]["best_s"] * 100, 2)
    return out


def run_obs() -> List[str]:
    """benchmarks.run entry for the ``obs`` suite: telemetry overhead +
    trace fidelity.  Acceptance claims (checked against the committed
    ``BENCH_obs.json`` by ``tests/test_bench_schema.py``): tracing costs
    < 5% tok/s, the compiled-program set is unchanged by tracing (still
    <= 1 each of {segment, reset, copy, promote}, zero alerts), and
    per-request summaries reconstructed from the trace match the
    scheduler's own accounting."""
    r = obs_workload()
    for mode in ("untraced", "traced"):
        print(f"{mode:>9s}: {r[mode]['tok_per_s']} tok/s "
              f"({r[mode]['emitted']} tokens, best of {r['repeats']}) "
              f"programs={r[f'programs_{mode}']} "
              f"alerts={r[f'alerts_{mode}']}")
    print(f"overhead={r['overhead_pct']}% trace_events={r['trace_events']} "
          f"summary_consistent={r['summary_consistent']}")
    rows = ["bench,name,value,derived"]
    for mode in ("untraced", "traced"):
        rows.append(f"bench,obs_tok_per_s_{mode},{r[mode]['tok_per_s']},tok/s")
    rows.append(f"bench,obs_overhead_pct,{r['overhead_pct']},pct")
    rows.append(f"bench,obs_trace_events,{r['trace_events']},count")
    rows.append(f"bench,obs_preemptions,{r['preemptions']},count")
    rows.append(f"bench,obs_summary_consistent,"
                f"{int(r['summary_consistent'])},bool")
    rows.append(f"bench,obs_alerts,{r['alerts_traced']},count")
    for k, v in r["programs"].items():
        rows.append(f"bench,obs_programs_{k},{v},count")
    return rows


def measure_mesh_segment(data: int, model: int, num_steps: int = 4,
                         page_size: int = 8, devices=None) -> dict:
    """Program size / wall-clock of the SHARDED paged mixed-step segment on
    a (data, model) device mesh (``launch.mesh.serve_mesh`` +
    ``runtime.decode_loop.segment_shardings``).  The acceptance bar: the
    traced program is identical at every mesh width (NamedShardings are
    shape-free), and the partitioned HLO stays ~flat — sharding moves data,
    not program structure.  Must run under a forced multi-device platform
    (see ``_mesh_worker_main``)."""
    import jax
    import jax.numpy as jnp

    from repro.launch.mesh import serve_mesh
    from repro.models import serve as SV
    from repro.runtime import decode_loop as DL
    from repro.runtime import paged as PG

    from benchmarks.compile_scaling import count_eqns, count_hlo_ops

    cfg, params, _, _ = _setup()
    par = serve_mesh(data, model, devices)
    b, cp, n_pages = 2, 8, 16
    P = 2 * cp
    max_pages = -(-(P + 16) // page_size)
    cache = SV.init_paged_cache(cfg, b, n_pages, page_size)
    mgr = PG.PagedCacheManager(n_pages, page_size, use_radix=False)
    mgr.begin(b, max_pages)
    mgr.admit(0, list(range(P)), 16)
    mgr.admit(1, list(range(8)), 16)
    table = jnp.asarray(mgr.table)
    mode = jnp.asarray([DL.PREFILL, DL.DECODE], jnp.int32)
    tok = jnp.zeros((b, 1), jnp.int32)
    pos = jnp.asarray([0, 8], jnp.int32)
    rem = jnp.full((b,), 8, jnp.int32)
    pfill = jnp.zeros((b,), jnp.int32)
    pend = jnp.zeros((b, P), jnp.int32)
    plen = jnp.asarray([P, 8], jnp.int32)

    def f(cache, mode, tok, pos, key, rem, pfill, pend, plen, table):
        return DL.mixed_segment(cfg, par, params, cache, mode, tok, pos, key,
                                rem, pfill, pend, plen, num_steps=num_steps,
                                prefill_chunk=cp, table=table)

    args = (cache, mode, tok, pos, jax.random.PRNGKey(2), rem, pfill, pend,
            plen, table)
    in_sh, out_sh = DL.segment_shardings(cfg, par, cache, table=True)
    t0 = time.perf_counter()
    jaxpr = jax.make_jaxpr(f)(*args)
    trace_s = time.perf_counter() - t0
    jf = jax.jit(f, in_shardings=in_sh, out_shardings=out_sh)
    t0 = time.perf_counter()
    lowered = jf.lower(*args)
    lower_s = time.perf_counter() - t0
    compiled = lowered.compile()
    jax.block_until_ready(compiled(*args))
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        jax.block_until_ready(compiled(*args))
        best = min(best, time.perf_counter() - t0)
    return {"data": data, "model": model,
            "jaxpr_eqns": count_eqns(jaxpr), "hlo_ops": count_hlo_ops(lowered),
            "trace_s": round(trace_s, 3), "lower_s": round(lower_s, 3),
            "ms_per_step": round(best / num_steps * 1e3, 3)}


def mesh_routing_workload(policy: str, *, replicas: int = 2, data: int = 1,
                          model: int = 2, tenants: int = 2,
                          requests: int = 12, prefix_len: int = 48,
                          suffix: int = 8, gen: int = 8, page_size: int = 8,
                          seed: int = 0) -> dict:
    """Shared-prefix multi-tenant workload over sharded replicas behind the
    router: tok/s and aggregate radix hit rate, ``affine`` vs the
    locality-shredding ``rr`` baseline.  Fresh engines per policy so each
    run starts with empty radix trees; arrival order is shuffled so rr
    cannot accidentally align with the tenant cycle."""
    import numpy as np

    import jax

    from repro.launch.mesh import serve_mesh
    from repro.launch.router import ReplicaRouter
    from repro.runtime import paged as PG

    cfg, params, _, _ = _setup()
    rng = np.random.default_rng(seed)
    pfx = [rng.integers(0, cfg.vocab_size, size=prefix_len).tolist()
           for _ in range(tenants)]
    prompts = [pfx[i % tenants]
               + rng.integers(0, cfg.vocab_size, size=suffix).tolist()
               for i in range(requests)]
    sessions = [f"tenant-{i % tenants}" for i in range(requests)]
    order = rng.permutation(requests)
    prompts = [prompts[i] for i in order]
    sessions = [sessions[i] for i in order]
    per = data * model
    devs = jax.devices()

    class Rep:
        def __init__(self, r):
            self.par = serve_mesh(data, model,
                                  devices=devs[r * per:(r + 1) * per])
            self.engine = PG.PagedServeEngine(
                cfg, params, par=self.par, slots=2,
                bucket=prefix_len + suffix, max_new_tokens=gen, segment=2,
                prefill_chunk=page_size, page_size=page_size)

        def generate(self, ps):
            with self.par.mesh:
                return self.engine.generate(ps)

        @property
        def last_stats(self):
            return self.engine.last_stats

    router = ReplicaRouter([Rep(r) for r in range(replicas)], policy=policy)
    router.generate(prompts[:1], sessions[:1])  # absorb compile
    t0 = time.perf_counter()
    outs = router.generate(prompts, sessions)
    wall = time.perf_counter() - t0
    st = router.last_stats
    pt = sum(r.get("prompt_tokens", 0) for r in st["per_replica"])
    hit = sum(r.get("prefix_hit_tokens", 0) for r in st["per_replica"])
    return {"policy": policy, "replicas": replicas, "requests": requests,
            "tok_per_s": round(sum(len(o) for o in outs) / wall, 1),
            "prefix_hit_rate": round(hit / max(pt, 1), 3),
            "spilled": st["spilled"]}


def _mesh_worker_main():
    """Subprocess body for the ``serve_mesh`` suite (the parent pytest /
    bench process keeps ONE visible device; the spawn env forces 8).
    Prints one ``MESHSWEEP {json}`` marker line the parent parses."""
    assert jax.device_count() >= 8, jax.device_count()
    out = {"widths": [], "replica_cells": [], "routing": []}
    # model-axis width: 1 (degenerate mesh) -> 2 (kv heads shard) -> 4
    # (kv=2 < 4: in-page sequence fallback) — program size must stay flat
    for m in (1, 2, 4):
        r = measure_mesh_segment(1, m, devices=jax.devices()[:m])
        print("mesh (1,{model}) jaxpr_eqns={jaxpr_eqns} hlo_ops={hlo_ops} "
              "ms/step={ms_per_step}".format(**r))
        out["widths"].append(r)
    # replica count: the SAME (1,2) program built on disjoint device
    # slices — per-replica program size is constant by construction, and
    # this measures it rather than asserting it
    for r_i in range(4):
        devs = jax.devices()[r_i * 2:(r_i + 1) * 2]
        r = measure_mesh_segment(1, 2, devices=devs)
        out["replica_cells"].append(r)
    print("replica cells hlo_ops:",
          [c["hlo_ops"] for c in out["replica_cells"]])
    for policy in ("affine", "rr"):
        r = mesh_routing_workload(policy)
        print("routing policy={policy} tok/s={tok_per_s} "
              "hit={prefix_hit_rate}".format(**r))
        out["routing"].append(r)
    print("MESHSWEEP " + json.dumps(out))


def run_serve_mesh() -> List[str]:
    """benchmarks.run entry for the ``serve_mesh`` suite: spawns the
    8-device worker subprocess (tests/test_fpdt_mesh.py pattern) and
    summarizes program-size flatness across model-axis width and replica
    count, plus routed-vs-round-robin tok/s and prefix-hit."""
    import subprocess

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.pathsep.join(
        [root, os.path.join(root, "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    r = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--mesh-worker"],
        capture_output=True, text=True, timeout=3000, env=env)
    marker = [ln for ln in r.stdout.splitlines()
              if ln.startswith("MESHSWEEP ")]
    if r.returncode != 0 or not marker:
        print(r.stdout[-2000:])
        print(r.stderr[-2000:])
        return ["bench,name,value,derived", "bench,ERROR,1,mesh worker failed"]
    out = json.loads(marker[0][len("MESHSWEEP "):])
    rows = ["bench,name,value,derived"]
    by_w = {c["model"]: c for c in out["widths"]}
    g = by_w[4]["jaxpr_eqns"] / by_w[1]["jaxpr_eqns"]
    rows.append(f"bench,serve_mesh_jaxpr_growth_model_1_to_4,{g:.3f},x")
    g = by_w[4]["hlo_ops"] / by_w[1]["hlo_ops"]
    rows.append(f"bench,serve_mesh_hlo_growth_model_1_to_4,{g:.3f},x")
    cells = [c["hlo_ops"] for c in out["replica_cells"]]
    g = max(cells) / min(cells)
    rows.append(f"bench,serve_mesh_hlo_growth_replicas_1_to_4,{g:.3f},x")
    for r_ in out["routing"]:
        p = r_["policy"]
        rows.append(f"bench,serve_mesh_{p}_tok_per_s,{r_['tok_per_s']},tok/s")
        rows.append(f"bench,serve_mesh_{p}_prefix_hit_rate,"
                    f"{r_['prefix_hit_rate']},fraction")
    for c in out["widths"]:
        rows.append(f"bench,serve_mesh_ms_per_step_model{c['model']},"
                    f"{c['ms_per_step']},ms")
    return rows


# ---------------------------------------------------------------------------
# failover: seeded fault schedule — completion + goodput vs the abort baseline
# ---------------------------------------------------------------------------


def failover_workload(*, replicas: int = 2, tenants: int = 4,
                      rounds: int = 2, prefix_len: int = 48, suffix: int = 8,
                      gen: int = 8, page_size: int = 8, slots: int = 2,
                      spill_pages: int = 64, seed: int = 0) -> dict:
    """The failover acceptance workload: per-tenant shared-prefix sessions
    over router-fronted engine replicas, run under a seeded fault schedule
    in four scenarios —

    * **nofault**: the reference run (and the output oracle);
    * **abort**: the same mid-workload permanent crash under the legacy
      ``failover=False`` contract — the crashed round is thrown away
      whole, measuring what brittleness costs;
    * **failover**: crash + re-home through the shared KV store — every
      request must complete with outputs identical to nofault, and the
      re-homed sessions must recover their prefixes from the dead
      replica's published pages (``recovered_prefix_tokens > 0``);
    * **rejoin**: the crashed replica comes back as a FRESH engine (a
      restart loses device state), rejoins, and serves its returning
      sessions warm from its own published cache.

    The fault (``raise`` on the victim's 2nd dispatch) is deterministic:
    round 1 warms every radix tree and publishes to the store, round 2
    crashes the victim mid-workload."""
    import tempfile

    import numpy as np

    from repro.launch.faults import Fault, FaultyReplica
    from repro.launch.kvstore import SharedKVStore
    from repro.launch.router import ReplicaFailed, ReplicaRouter
    from repro.runtime import paged as PG

    cfg, params, _, _ = _setup()
    rng = np.random.default_rng(seed)
    pfx = [rng.integers(0, cfg.vocab_size, size=prefix_len).tolist()
           for _ in range(tenants)]
    prompts = [pfx[i % tenants]
               + rng.integers(0, cfg.vocab_size, size=suffix).tolist()
               for i in range(tenants)]
    sessions = [f"tenant-{i % tenants}" for i in range(tenants)]

    def quiet(msg):
        pass

    def engine():
        return PG.PagedServeEngine(
            cfg, params, slots=slots, bucket=prefix_len + suffix,
            max_new_tokens=gen, segment=2, prefill_chunk=page_size,
            page_size=page_size, spill_pages=spill_pages)

    def run(rt, n=rounds):
        """n identical rounds; a round that aborts (legacy ReplicaFailed)
        loses ALL its outputs — that asymmetry IS the measurement."""
        outs, served, wall = [], 0, 0.0
        for _ in range(n):
            t0 = time.perf_counter()
            try:
                o = rt.generate(prompts, sessions=sessions)
                served += len(o)
            except ReplicaFailed:
                o = None
            wall += time.perf_counter() - t0
            outs.append(o)
        return outs, served, wall

    total = rounds * len(prompts)
    fault = Fault("raise", 1)  # dispatch 0 = round 1 OK, dies in round 2

    # nofault — also fixes the victim: homes are construction-independent
    ref_rt = ReplicaRouter([engine() for _ in range(replicas)], warn=quiet)
    ref_outs, ref_served, ref_wall = run(ref_rt)
    victim = ref_rt.home_of(prompts[0], sessions[0])

    # abort baseline: same crash, legacy failover=False contract
    ab = [engine() for _ in range(replicas)]
    ab[victim] = FaultyReplica(ab[victim], [fault])
    _, ab_served, ab_wall = run(ReplicaRouter(ab, failover=False,
                                              warn=quiet))

    # crash + failover through the shared store
    store = SharedKVStore(tempfile.mkdtemp(prefix="failover_bench"))
    fo_eng = [engine() for _ in range(replicas)]
    fo = list(fo_eng)
    fo[victim] = FaultyReplica(fo_eng[victim], [fault])
    fo_rt = ReplicaRouter(fo, max_retries=1, kv_store=store, warn=quiet)
    fo_outs, fo_served, fo_wall = run(fo_rt)
    fo_stats = dict(fo_rt.last_stats["failover"])

    # rejoin: restarted process behind the same seat — fresh engine, warm
    # only through its own published store file
    fo_eng[victim] = engine()
    fo[victim].inner = fo_eng[victim]
    fo[victim].heal()
    rejoin_restored = fo_rt.rejoin(victim)
    _, rj_served, rj_wall = run(fo_rt, n=1)
    rj_row = fo_rt.last_stats["per_replica"][victim]
    rj_hit = rj_row.get("prefix_hit_tokens", 0) / max(
        rj_row.get("prompt_tokens", 1), 1)

    survivor = fo_eng[1 - victim] if replicas == 2 else \
        fo_eng[(victim + 1) % replicas]
    return {
        "replicas": replicas, "tenants": tenants, "rounds": rounds,
        "requests_total": total, "victim": victim,
        "nofault": {"served": ref_served, "completion": ref_served / total,
                    "goodput": round(ref_served / ref_wall, 2)},
        "abort": {"served": ab_served, "completion": ab_served / total,
                  "goodput": round(ab_served / ab_wall, 2)},
        "failover": {"served": fo_served, "completion": fo_served / total,
                     "goodput": round(fo_served / fo_wall, 2),
                     **fo_stats},
        "rejoin": {"served": rj_served,
                   "completion": rj_served / len(prompts),
                   "restored_pages": rejoin_restored,
                   "hit_rate": round(rj_hit, 3)},
        "outputs_match": fo_outs == ref_outs,
        "programs": survivor.compiled_programs(),
    }


def run_failover() -> List[str]:
    """benchmarks.run entry for the ``failover`` suite: completion rate
    and goodput under a seeded fault schedule — fault-free vs the legacy
    abort-everything baseline vs crash+failover (token-identical, shared-
    store recovery) vs crash+rejoin."""
    r = failover_workload()
    fo = r["failover"]
    print(f"failover: victim=replica{r['victim']}; completion "
          f"nofault={r['nofault']['completion']:.2f} "
          f"abort={r['abort']['completion']:.2f} "
          f"failover={fo['completion']:.2f} "
          f"rejoin={r['rejoin']['completion']:.2f}; "
          f"deaths={fo['deaths']} rehomed={fo['rehomed_requests']} "
          f"recovered_prefix={fo['recovered_prefix_tokens']} "
          f"(pages={fo['recovered_pages']}); match={r['outputs_match']}")
    rows = ["bench,name,value,derived"]
    rows.append(f"bench,failover_requests_total,{r['requests_total']},count")
    for mode in ("nofault", "abort", "failover"):
        m = r[mode]
        rows.append(f"bench,failover_{mode}_completion_rate,"
                    f"{m['completion']:.3f},fraction")
        rows.append(f"bench,failover_{mode}_goodput,{m['goodput']},req/s")
    rows.append(f"bench,failover_deaths,{fo['deaths']},count")
    rows.append(f"bench,failover_retries,{fo['retries']},count")
    rows.append(f"bench,failover_rehomed_requests,"
                f"{fo['rehomed_requests']},count")
    rows.append(f"bench,failover_rehomed_sessions,"
                f"{fo['rehomed_sessions']},count")
    rows.append(f"bench,failover_recovered_prefix_tokens,"
                f"{fo['recovered_prefix_tokens']},count")
    rows.append(f"bench,failover_recovered_pages,"
                f"{fo['recovered_pages']},count")
    rows.append(f"bench,failover_outputs_match,{int(r['outputs_match'])},bool")
    rows.append(f"bench,failover_rejoin_completion_rate,"
                f"{r['rejoin']['completion']:.3f},fraction")
    rows.append(f"bench,failover_rejoin_restored_pages,"
                f"{r['rejoin']['restored_pages']},pages")
    rows.append(f"bench,failover_rejoin_hit_rate,"
                f"{r['rejoin']['hit_rate']},fraction")
    for k, v in r["programs"].items():
        rows.append(f"bench,failover_programs_{k},{v},count")
    return rows


def staggered_workload(blocking: bool = False, *, slots: int = 4,
                       requests: int = 12, bucket: int = 32, cp: int = 4,
                       gen: int = 24, seed: int = 0, warmup: bool = True) -> dict:
    """Staggered-arrival latency workload: more requests than slots, mixed
    prompt lengths, a stop token staggering finishes — so refills land
    while other slots are mid-decode.  ``segment=1`` makes every dispatch
    one mixed step, i.e. dispatch wall-clock IS the inter-token latency of
    the decoding slots.  Returns p50 steady / p95 refill-active latency,
    tokens/s, dispatch counts, and the engine's compiled-program set."""
    import numpy as np

    import jax

    from repro.runtime import decode_loop as DL

    cfg, params, _, _ = _setup()
    rng = np.random.default_rng(seed)
    lens = rng.integers(bucket // 4, bucket + 1, size=requests)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).tolist() for n in lens]
    stop = int(rng.integers(0, cfg.vocab_size))
    if blocking:
        eng = DL.BlockingServeEngine(cfg, params, slots=slots, bucket=bucket,
                                     max_new_tokens=gen, segment=1,
                                     stop_tokens=(stop,))
    else:
        eng = DL.ServeEngine(cfg, params, slots=slots, bucket=bucket,
                             max_new_tokens=gen, segment=1, prefill_chunk=cp,
                             stop_tokens=(stop,))
    if warmup:  # absorb compiles so latencies measure the hot path
        eng.generate(prompts, key=jax.random.PRNGKey(seed))
    programs_before = eng.compiled_programs() if not blocking else None
    t0 = time.perf_counter()
    outs = eng.generate(prompts, key=jax.random.PRNGKey(seed))
    wall = time.perf_counter() - t0
    steps = eng.last_stats["steps"]
    steady = [s["ms"] for s in steps if not s["prefilling"] and s["emitted"]]
    refill = [s["ms"] for s in steps if s["prefilling"] and s["emitted"]]
    total = sum(len(o) for o in outs)

    def pct(xs, q):
        return round(float(np.percentile(xs, q)), 3) if xs else float("nan")

    p50_steady, p95_steady = pct(steady, 50), pct(steady, 95)
    p50_refill, p95_refill = pct(refill, 50), pct(refill, 95)
    return {
        "engine": "blocking" if blocking else "fused",
        "slots": slots, "requests": requests, "bucket": bucket,
        "prefill_chunk": None if blocking else cp, "gen": gen,
        "tokens": total, "tok_per_s": round(total / wall, 1),
        "p50_steady_ms": p50_steady, "p95_steady_ms": p95_steady,
        "p50_refill_ms": p50_refill, "p95_refill_ms": p95_refill,
        # p95 vs p50 is the ISSUE's stall bar; on a shared/noisy host the
        # p50-based factor is the stable signal (OS jitter puts even the
        # steady-state p95 far above the steady-state p50)
        "refill_over_steady": round(p95_refill / p50_steady, 3),
        "stall_factor_p50": round(p50_refill / p50_steady, 3),
        "refill_steps": len(refill), "steady_steps": len(steady),
        "dispatches": eng.last_stats["dispatches"],
        "programs_before": programs_before,
        "programs": eng.compiled_programs() if not blocking else None,
    }


def sweep(chunk_sweep=(0, 2, 8, 32), gen_sweep=(2, 8, 32),
          fixed_gen=8, fixed_chunks=4) -> List[dict]:
    recs = []

    def show(r):
        print("chunks={n_host_chunks:<3d} steps={num_steps:<3d} "
              "jaxpr_eqns={jaxpr_eqns:<6d} hlo_ops={hlo_ops:<6d} "
              "trace={trace_s}s lower={lower_s}s "
              "ms/step={ms_per_step:<8} tok/s={tok_per_s}".format(**r))

    for c in chunk_sweep:
        recs.append(measure(c, fixed_gen))
        show(recs[-1])
    for g in gen_sweep:
        recs.append(measure(fixed_chunks, g))
        show(recs[-1])
    return recs


def run_paged() -> List[str]:
    """benchmarks.run entry for the ``paged`` suite: program-size flatness
    in ``n_pages``, the shared-system-prompt workload (paged vs dense at
    equal memory: tok/s, p50/p95 inter-token latency, prefix-hit rate,
    peak pages), and the PR-4 one-slot-prefill overhead sweep."""
    rows = ["bench,name,value,derived"]
    sizes = (32, 512)
    sized = {n: measure_paged(n, 8) for n in sizes}
    for n in sizes:
        print("paged n_pages={n_pages:<4d} jaxpr_eqns={jaxpr_eqns:<6d} "
              "hlo_ops={hlo_ops:<6d} ms/step={ms_per_step}".format(**sized[n]))
    g = sized[512]["hlo_ops"] / sized[32]["hlo_ops"]
    rows.append(f"bench,paged_hlo_growth_npages_32_to_512,{g:.3f},x")
    g = sized[512]["jaxpr_eqns"] / sized[32]["jaxpr_eqns"]
    rows.append(f"bench,paged_jaxpr_growth_npages_32_to_512,{g:.3f},x")
    r = shared_prefix_workload()
    print(f"shared-prefix: hit_rate={r['hit_rate']} "
          f"prefilled={r['prefilled_tokens']}/{r['prompt_tokens']} "
          f"pages_peak={r['pages_peak']}/{r['dense_equiv_pages']} "
          f"paged tok/s={r['paged_tok_per_s']} vs dense {r['dense_tok_per_s']} "
          f"match={r['outputs_match']}")
    rows.append(f"bench,paged_prefix_hit_rate,{r['hit_rate']},fraction")
    rows.append(f"bench,paged_prefilled_tokens,{r['prefilled_tokens']},count")
    rows.append(f"bench,paged_prompt_tokens,{r['prompt_tokens']},count")
    rows.append(f"bench,paged_pages_peak,{r['pages_peak']},pages")
    rows.append(f"bench,paged_dense_equiv_pages,{r['dense_equiv_pages']},pages")
    for e in ("paged", "dense"):
        rows.append(f"bench,{e}_sharedprefix_tok_per_s,{r[f'{e}_tok_per_s']},tok/s")
        rows.append(f"bench,{e}_sharedprefix_p50_ms,{r[f'{e}_p50_ms']},ms")
        rows.append(f"bench,{e}_sharedprefix_p95_ms,{r[f'{e}_p95_ms']},ms")
    rows.append(f"bench,paged_outputs_match_dense,{int(r['outputs_match'])},bool")
    for cp in (64, 128, 256):
        o = prefill_overhead(cp)
        print(f"prefill-overhead cp={cp:<4d} decode={o['decode_ms_per_step']} "
              f"ms/step one-prefill={o['one_prefill_ms_per_step']} ms/step "
              f"(x{o['overhead_x']})")
        rows.append(f"bench,prefill_overhead_cp{cp},{o['overhead_x']},x")
    return rows


def run() -> List[str]:
    """benchmarks.run entry: summarized growth factors + throughput + the
    staggered-arrival scheduler workload (fused vs blocking baseline)."""
    recs = sweep(chunk_sweep=(2, 32), gen_sweep=(2, 32), fixed_gen=8, fixed_chunks=4)
    by_c = {r["n_host_chunks"]: r for r in recs[:2]}
    by_g = {r["num_steps"]: r for r in recs[2:]}
    rows = ["bench,name,value,derived"]
    g = by_c[32]["hlo_ops"] / by_c[2]["hlo_ops"]
    rows.append(f"bench,decode_hlo_growth_chunks_2_to_32,{g:.3f},x")
    g = by_g[32]["hlo_ops"] / by_g[2]["hlo_ops"]
    rows.append(f"bench,decode_hlo_growth_gen_2_to_32,{g:.3f},x")
    rows.append(f"bench,decode_tok_per_s_u4_gen32,{by_g[32]['tok_per_s']},tok/s")
    mixed = mixed_sweep()
    by_cp = {r["cp"]: r for r in mixed[:3]}
    by_mc = {r["n_host_chunks"]: r for r in mixed[3:5]}
    by_mg = {r["num_steps"]: r for r in mixed[5:]}
    g = by_cp[256]["hlo_ops"] / by_cp[64]["hlo_ops"]
    rows.append(f"bench,mixed_hlo_growth_cp_64_to_256,{g:.3f},x")
    g = by_mc[32]["hlo_ops"] / by_mc[2]["hlo_ops"]
    rows.append(f"bench,mixed_hlo_growth_chunks_2_to_32,{g:.3f},x")
    g = by_mg[32]["hlo_ops"] / by_mg[2]["hlo_ops"]
    rows.append(f"bench,mixed_hlo_growth_gen_2_to_32,{g:.3f},x")
    for r in (staggered_workload(blocking=False), staggered_workload(blocking=True)):
        e = r["engine"]
        rows.append(f"bench,serve_{e}_tok_per_s,{r['tok_per_s']},tok/s")
        rows.append(f"bench,serve_{e}_p50_steady_ms,{r['p50_steady_ms']},ms")
        rows.append(f"bench,serve_{e}_p95_steady_ms,{r['p95_steady_ms']},ms")
        rows.append(f"bench,serve_{e}_p50_refill_ms,{r['p50_refill_ms']},ms")
        rows.append(f"bench,serve_{e}_p95_refill_ms,{r['p95_refill_ms']},ms")
        rows.append(f"bench,serve_{e}_refill_over_steady,{r['refill_over_steady']},x")
        rows.append(f"bench,serve_{e}_stall_factor_p50,{r['stall_factor_p50']},x")
        rows.append(f"bench,serve_{e}_dispatches,{r['dispatches']},count")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None)
    ap.add_argument("--mesh-worker", action="store_true",
                    help="internal: run the forced-8-device mesh sweep "
                         "body (spawned by run_serve_mesh)")
    args = ap.parse_args()
    if args.mesh_worker:
        return _mesh_worker_main()
    recs = sweep()
    by_c = {r["n_host_chunks"]: r for r in recs[:4]}
    by_g = {r["num_steps"]: r for r in recs[4:]}
    print(f"\nhost-chunk growth 2 -> 32 (gen=8):  "
          f"jaxpr x{by_c[32]['jaxpr_eqns'] / by_c[2]['jaxpr_eqns']:.2f}, "
          f"hlo x{by_c[32]['hlo_ops'] / by_c[2]['hlo_ops']:.2f}")
    print(f"gen-length growth 2 -> 32 (u=4):    "
          f"jaxpr x{by_g[32]['jaxpr_eqns'] / by_g[2]['jaxpr_eqns']:.2f}, "
          f"hlo x{by_g[32]['hlo_ops'] / by_g[2]['hlo_ops']:.2f}")
    print()
    mixed = mixed_sweep()
    by_cp = {r["cp"]: r for r in mixed[:3]}
    by_mc = {r["n_host_chunks"]: r for r in mixed[3:5]}
    by_mg = {r["num_steps"]: r for r in mixed[5:]}
    print(f"\nmixed-step growth cp 64 -> 256:     "
          f"jaxpr x{by_cp[256]['jaxpr_eqns'] / by_cp[64]['jaxpr_eqns']:.2f}, "
          f"hlo x{by_cp[256]['hlo_ops'] / by_cp[64]['hlo_ops']:.2f}")
    print(f"mixed-step growth chunks 2 -> 32:   "
          f"jaxpr x{by_mc[32]['jaxpr_eqns'] / by_mc[2]['jaxpr_eqns']:.2f}, "
          f"hlo x{by_mc[32]['hlo_ops'] / by_mc[2]['hlo_ops']:.2f}")
    print(f"mixed-step growth gen 2 -> 32:      "
          f"jaxpr x{by_mg[32]['jaxpr_eqns'] / by_mg[2]['jaxpr_eqns']:.2f}, "
          f"hlo x{by_mg[32]['hlo_ops'] / by_mg[2]['hlo_ops']:.2f}")
    print("\nstaggered-arrival workload (segment=1, per-step latencies):")
    stag = [staggered_workload(blocking=False), staggered_workload(blocking=True)]
    for r in stag:
        print(f"  {r['engine']:<9s} tok/s={r['tok_per_s']:<8} "
              f"steady p50/p95={r['p50_steady_ms']}/{r['p95_steady_ms']} ms  "
              f"refill-active p50/p95={r['p50_refill_ms']}/{r['p95_refill_ms']} ms "
              f"(p50 stall x{r['stall_factor_p50']})  dispatches={r['dispatches']}")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump({"decode": recs, "mixed_step": mixed, "staggered": stag},
                      fh, indent=1)


if __name__ == "__main__":
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)  # for `from benchmarks.compile_scaling import`
    sys.path.insert(0, os.path.join(_root, "src"))
    main()
