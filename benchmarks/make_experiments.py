"""Render the §Dry-run and §Roofline sections of EXPERIMENTS.md from the
experiments/{dryrun,roofline}/*.json sweeps.

  PYTHONPATH=src python -m benchmarks.make_experiments > experiments/SECTIONS.md
"""
from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import ASSIGNED_ARCHS, SHAPES, shape_applicable

GIB = 2**30


def _load(d):
    out = {}
    for f in glob.glob(os.path.join(d, "*.json")):
        r = json.load(open(f))
        key = (r["arch"], r["shape"], r["mesh"], os.path.basename(f))
        out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def dryrun_section() -> str:
    recs = _load("experiments/dryrun")
    lines = [
        "### Per-cell dry-run (lower + compile on the production meshes)",
        "",
        "| arch | shape | mesh | status | compile (s) | temp/device (GiB) | host temp (GiB) | collectives (kinds, HLO-text counts¹) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    n_ok = n_fail = 0
    for a in ASSIGNED_ARCHS:
        for s in SHAPES:
            if not shape_applicable(a, s):
                continue
            for mesh in ("single", "multi"):
                r = recs.get((a, s, mesh))
                if r is None:
                    lines.append(f"| {a} | {s} | {mesh} | MISSING | | | | |")
                    n_fail += 1
                    continue
                if not r["ok"]:
                    lines.append(f"| {a} | {s} | {mesh} | **FAIL** {r['error'][:60]} | | | | |")
                    n_fail += 1
                    continue
                n_ok += 1
                m = r["memory"]
                coll = ", ".join(f"{k}×{v['count']}" for k, v in sorted(r["collectives"].items()))
                lines.append(
                    f"| {a} | {s} | {mesh} | OK | {r['compile_s']} | "
                    f"{m['temp_bytes']/GIB:.2f} | {m['host_temp_bytes']/GIB:.2f} | {coll} |"
                )
    lines += [
        "",
        f"**{n_ok} cells compiled, {n_fail} failed/missing.** "
        "¹ Counts are per HLO text occurrence — lax.scan bodies appear once; "
        "true per-step collective bytes are extrapolated in §Roofline.",
        "",
        "Skipped cells (assignment rule: `long_500k` needs sub-quadratic attention):",
        "granite-moe-1b-a400m, llama4-maverick-400b-a17b, musicgen-medium, yi-34b,",
        "qwen1.5-4b, mistral-nemo-12b, internvl2-2b (pure full attention) — noted in",
        "DESIGN.md §Shape/cell policy.  llama3.2-1b × long_500k runs as an EXTRA",
        "cell (FPDT host-streamed KV decode), beyond the assignment's requirement.",
    ]
    return "\n".join(lines)


def roofline_section() -> str:
    recs = _load("experiments/roofline")
    lines = [
        "### Roofline terms per cell (single-pod 16x16 = 256 chips, TPU v5e)",
        "",
        "compute = FLOPs/(chips·197e12); memory = HBM bytes/(chips·819e9);",
        "collective = HLO-measured bytes/chip / 50e9 (probe-extrapolated, see",
        "benchmarks/roofline.py).  `useful` = MODEL_FLOPS (6·N·D, 6·N_active·D",
        "for MoE) / total FLOPs.",
        "",
        "| arch | shape | u | compute (ms) | memory (ms) | collective (ms) | bottleneck | roofline frac | useful | note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for a in ASSIGNED_ARCHS:
        for s in SHAPES:
            if not shape_applicable(a, s):
                continue
            r = recs.get((a, s, "single"))
            if r is None:
                lines.append(f"| {a} | {s} | | | | | MISSING | | | |")
                continue
            note = ""
            if r["useful_ratio"] > 1.0:
                note = "6·N·D counts embeddings the fwd never multiplies"
            lines.append(
                f"| {a} | {s} | {r['chunks']} | {r['t_compute']*1e3:.2f} | "
                f"{r['t_memory']*1e3:.2f} | {r['t_collective']*1e3:.2f} | "
                f"{r['bottleneck']} | {r['roofline_frac']:.2f} | "
                f"{r['useful_ratio']:.2f} | {note} |"
            )
    # dominant-term summary + one-sentence movers
    lines += ["", "Dominant-term notes (what would move the bottleneck down):", ""]
    movers = {
        "collective": "ZeRO weight all-gathers dominate at short sequence: raise "
        "tokens/chip (data-axis microbatching), cache gathered weights across "
        "fwd/remat (remat policy), or quantize gathers (int8 weights on wire).",
        "memory": "decode is weight-read bound: multi-token speculative decode, "
        "weight quantization, or batch growth amortize the HBM sweep.",
        "compute": "already compute-bound: reduce non-useful FLOPs (causal "
        "block pruning, remat policy that skips attention recompute).",
    }
    seen = set()
    for r in recs.values():
        if r["mesh"] == "single" and r["bottleneck"] not in seen:
            seen.add(r["bottleneck"])
            lines.append(f"* **{r['bottleneck']}** — {movers[r['bottleneck']]}")
    return "\n".join(lines)


if __name__ == "__main__":
    print(dryrun_section())
    print()
    print(roofline_section())
