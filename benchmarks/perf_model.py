"""Pipeline performance model: FPDT's chunk schedule as a discrete-event
simulation (the paper's Figs 8-10 reasoning, made executable).

Per (q-chunk i, kv-chunk j<=i) pair the backward-dominant schedule overlaps
  * attention compute on the MXU/SM          t_att(pair)
  * host->device KV fetch on PCIe/host link  t_fetch(chunk)   [offload only]
  * the per-chunk all-to-all on NVLink/ICI   t_a2a(chunk)
with a double buffer: pair (i, j+1)'s fetch is issued while (i, j) computes;
effective time per pair = max(t_att, t_fetch_next, t_a2a_amortized).  GPU
starving (Fig 8) emerges when chunks are too small; HBM waste (Fig 9) is the
memory model's domain (benchmarks/memory_model.py).

Hardware profiles: A100-80G node (paper: NVLink 300 GB/s algo bw, PCIe gen4
~25 GB/s effective, 312 TFLOP/s bf16) and TPU v5e (ICI ~50 GB/s/link x 2
usable, host link ~32 GB/s, 197 TFLOP/s bf16).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs import ModelConfig

BYTES = 2


@dataclasses.dataclass(frozen=True)
class HW:
    name: str
    peak: float  # FLOP/s bf16
    net_bw: float  # intra-group collective bandwidth per device
    host_bw: float  # host<->device per device
    hbm: float  # bytes


A100 = HW("a100", 312e12, 250e9, 25e9, 80 * 1024**3)
V5E = HW("v5e", 197e12, 100e9, 32e9, 16 * 1024**3)


def fpdt_step_time(cfg: ModelConfig, S: int, n: int, u: int, *,
                   offload: bool, hw: HW = A100, sparsity: float = 0.0,
                   mfu_eff: float = 0.62, attn_eff: float = 0.75) -> Dict[str, float]:
    """Per-layer-normalized training step time for the attention pipeline +
    token-wise compute.  attn_eff: flash-attention kernel efficiency at long
    sequence (FA2 on A100 ~0.7-0.75); mfu_eff: dense matmul efficiency."""
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    c = S // u  # global chunk length
    tok_c = c / n  # per-device token share of a chunk (Ulysses)
    eff_peak = hw.peak * mfu_eff
    att_peak = hw.peak * attn_eff

    # per-chunk unit times (seconds, per device)
    t_a2a = 3 * tok_c * d * BYTES * (n - 1) / n / hw.net_bw
    t_fetch = 2 * c * kvd / n * BYTES / hw.host_bw  # k+v of one chunk (head-sharded)
    keep = 1.0 - sparsity

    def t_att_pair(full: bool) -> float:
        # q chunk (c rows, qd/n heads-dim) x kv chunk (c keys)
        flops = 4 * c * c * qd / n * (0.5 if not full else keep)
        return flops / att_peak

    # ---- forward pipeline over pairs (i attends j<=i)
    t_fwd = 0.0
    for i in range(u):
        t_fwd += t_a2a
        for j in range(i + 1):
            ta = t_att_pair(full=(j < i))
            tf = t_fetch if (offload and j < i) else 0.0
            t_fwd += max(ta, tf)
    # ---- backward (Fig 7): 2x attention flops per pair + dq/dkv a2a
    t_bwd = 0.0
    for j in range(u):
        t_bwd += t_fetch if offload else 0.0
        for i in range(j, u):
            ta = 2 * t_att_pair(full=(j < i))
            tf = t_fetch if (offload and i < u - 1) else 0.0
            t_bwd += max(ta, tf)
        t_bwd += t_a2a  # dk/dv return
    # ---- token-wise compute (proj, mlp, norms), fwd+bwd+remat = 4 passes
    n_mats = 3 if cfg.mlp_act == "swiglu" else 2
    tok = S / n
    flops_tok = 2 * tok * (d * (qd + 2 * kvd) + qd * d + n_mats * d * (cfg.d_ff or 4 * d))
    t_tok = 4 * flops_tok / eff_peak

    t_total = t_fwd * 2 + t_bwd + t_tok  # fwd + remat-fwd + bwd
    # useful flops for MFU: fwd + 2x bwd, causal-corrected attention, no remat
    useful = 3 * (flops_tok + 4 * (S * (S + 1) / 2) * qd / n)
    return {
        "t_step_per_layer": t_total,
        "mfu": useful / (t_total * hw.peak),
        "t_fwd": t_fwd, "t_bwd": t_bwd, "t_tok": t_tok,
        "t_a2a_unit": t_a2a, "t_fetch_unit": t_fetch,
        "t_att_diag": t_att_pair(False), "t_att_full": t_att_pair(True),
    }


def megatron_sp_step_time(cfg: ModelConfig, S: int, n: int, *, hw: HW = A100,
                          mfu_eff: float = 0.62) -> Dict[str, float]:
    """Megatron-SP: TP attention + sequence-parallel norm regions.
    Communication: 4 all-gathers + 4 reduce-scatters of the FULL sequence
    hidden per layer (fwd+bwd), volume independent of n (the paper's point:
    it scales with S, not S/n)."""
    d, qd = cfg.d_model, cfg.q_dim
    eff_peak = hw.peak * mfu_eff
    t_comm = 8 * S * d * BYTES * (n - 1) / n / hw.net_bw * 3 / 2
    n_mats = 3 if cfg.mlp_act == "swiglu" else 2
    flops = (2 * S * (d * (qd + 2 * cfg.kv_dim) + qd * d + n_mats * d * (cfg.d_ff or 4 * d))
             + 4 * (S * (S + 1) / 2) * qd) / n
    t_comp = 4 * flops / eff_peak
    useful = 3 * flops
    return {"t_step_per_layer": t_comp + t_comm,
            "mfu": useful / ((t_comp + t_comm) * hw.peak)}


def megatron_tp_step_time(cfg: ModelConfig, S: int, n: int, *, hw: HW = A100,
                          mfu_eff: float = 0.62) -> Dict[str, float]:
    """Plain tensor parallel (paper Table 3 "TP." rows): two all-reduces of
    the full [S, d] hidden per layer per direction -> comm volume
    ~8 x S x d x 2(n-1)/n bytes per layer per pass, sequence NOT sharded."""
    d, qd = cfg.d_model, cfg.q_dim
    eff_peak = hw.peak * mfu_eff
    ar = 2 * S * d * BYTES * 2 * (n - 1) / n / hw.net_bw  # one all-reduce
    t_comm = ar * 2 * 3  # 2 per layer x (fwd + bwd + remat-fwd)
    n_mats = 3 if cfg.mlp_act == "swiglu" else 2
    flops = (2 * S * (d * (qd + 2 * cfg.kv_dim) + qd * d + n_mats * d * (cfg.d_ff or 4 * d))
             + 4 * (S * (S + 1) / 2) * qd) / n
    t_comp = 4 * flops / eff_peak
    useful = 3 * flops
    return {"t_step_per_layer": t_comp + t_comm,
            "mfu": useful / ((t_comp + t_comm) * hw.peak)}
