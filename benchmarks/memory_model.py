"""Per-device memory model for long-context training (paper Tables 1/3, Fig 12).

Components (bytes, batch=1 as in the paper's evaluation):
  model states  — DeepSpeed convention: bf16 params (2N) + bf16 grads (2N) +
                  fp32 master/m/v (12N); ZeRO-1 shards the 12N, ZeRO-2 also
                  grads, ZeRO-3 everything; Megatron-TP divides all by tp.
  checkpointed activations — with AC: one saved input per layer
                  [1, S_local, d]; OC moves them to host (0 device bytes).
  working set   — the live-tensor peak of ONE transformer block
                  (paper Table 2), which FPDT divides by the chunk count:
      baseline Ulysses fwd:  hidden(1) + qkv(3) + a2a recv(3) + attn io(4)
      baseline bwd:          ~2x fwd + flash bwd inputs (8)  [Table 2 row 2]
      FPDT(u):               the same but on S/u tokens; without offload the
                             pipeline still holds all u KV chunks (2 x S);
                             with offload only 2 chunk-sized KV tiles + the
                             double buffer live on device.
  logits spike  — chunked loss bounds it to ~2 hidden-sized chunks (§5.4).

Calibration anchors (paper Table 3, 8B Llama3 x 8 GPUs): TP -> 32K/64.3G,
TP+AC+OC -> 512K/78.7G, UL+ZeRO3+AC+OC -> 512K/60.1G, FPDT -> 4M/68.0G.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

from repro.configs import ModelConfig

GB = 1024 ** 3
A100 = 80 * GB
BYTES = 2


@dataclasses.dataclass(frozen=True)
class Strategy:
    n: int  # GPUs
    tp: int = 1  # Megatron tensor(+sequence) parallel degree
    ulysses: bool = False  # sequence parallel over all n
    zero: int = 0  # 0/1/2/3 (ZeRO stage across the n GPUs)
    ac: bool = False  # activation checkpointing
    oc: bool = False  # AC offloaded to host
    fpdt_u: int = 1  # sequence chunks
    offload: bool = False  # FPDT KV offload to host


def model_state_bytes(cfg: ModelConfig, st: Strategy) -> float:
    N = cfg.num_params()
    p, g, o = 2 * N, 2 * N, 12 * N
    if st.tp > 1:
        p, g, o = p / st.tp, g / st.tp, o / st.tp
    if st.zero >= 1:
        o = o / st.n
    if st.zero >= 2:
        g = g / st.n
    if st.zero >= 3:
        p = p / st.n
    return p + g + o


SPIKE = 1.25  # transient allocator/bucket spike multiplier (calibrated)


def activation_bytes(cfg: ModelConfig, S: int, st: Strategy) -> Dict[str, float]:
    d, L = cfg.d_model, cfg.num_layers
    seq_sharded = st.ulysses or st.fpdt_u > 1  # plain TP keeps full sequences
    sp = st.n if seq_sharded else 1
    tok = S / sp * d * BYTES  # one hidden tensor, local view
    tp = st.tp if st.tp > 1 else 1

    # --- checkpointed activations (saved layer inputs)
    if st.ac:
        saved = 0.0 if st.oc else L * tok
    else:
        # all intermediate tensors of every layer stay live for backward:
        # ~2 full hidden + ~12 head/ffn-sharded tensors per layer (Table 2)
        saved = L * tok * (2 + 12 / tp) if tp > 1 else L * tok * 14

    # --- working set of one block (paper Table 2 rows; backward dominates)
    u = max(1, st.fpdt_u)
    chunk_tok = tok / u
    if tp > 1 and not seq_sharded:
        work_bwd = tok * (2 + (6 + 8 + 3) / tp)  # hidden/dhidden + sharded qkv/flash/dffn
    else:
        q = 3 * chunk_tok       # qkv of the current chunk
        recv = 3 * chunk_tok    # async all-to-all receive buffers
        flash = 8 * chunk_tok   # flash bwd inputs q,k,v,o,do,dq,dk,dv
        if u > 1 and not st.offload:
            kv_all = 2 * tok    # all u KV chunks resident on device
        elif u > 1:
            kv_all = 4 * chunk_tok  # double-buffered single KV chunk
        else:
            kv_all = 2 * tok
        work_bwd = 2 * tok + q + recv + flash + kv_all
    # MLP chunks (2u) + chunked logits (~2 hidden chunks)
    ffn = (cfg.d_ff or cfg.d_inner) / d * chunk_tok / (2 * tp)
    logits = 2 * tok / max(1, u)
    peak = (work_bwd + ffn + logits) * SPIKE
    return {"saved": saved, "peak_block": peak, "total": saved + peak}


HOST_PER_GPU = 256 * GB  # paper: 1 TB host / 4-GPU node


def host_bytes(cfg: ModelConfig, S: int, st: Strategy) -> float:
    """Host-memory footprint per GPU: offloaded checkpoints + offloaded KV
    (+ ZeRO-Offload optimizer states when used)."""
    d, L = cfg.d_model, cfg.num_layers
    sp = st.n if (st.ulysses or st.fpdt_u > 1) else 1
    h = 0.0
    if st.oc:
        h += L * S / sp * d * BYTES  # offloaded layer inputs
    if st.offload:
        h += 2 * S * cfg.kv_dim * BYTES / st.n * L  # idle KV chunks, all layers
    return h


def train_memory_gb(cfg: ModelConfig, S: int, st: Strategy,
                    opt_on_host: bool = False) -> float:
    ms = model_state_bytes(cfg, st)
    if opt_on_host:  # ZeRO-Offload: fp32 states live in host memory
        N = cfg.num_params()
        ms = (2 * N + 2 * N) / (st.n if st.zero >= 3 else st.tp or 1)
    act = activation_bytes(cfg, S, st)["total"]
    frag = 1.5 * GB  # allocator fragmentation + workspace (calibrated)
    return (ms + act + frag) / GB


def max_seq_len(cfg: ModelConfig, st: Strategy, budget: float = A100) -> int:
    """Largest power-of-two sequence fitting device AND host budgets.
    Falls back to ZeRO-Offload (optimizer states on host) when the model
    states alone exceed the device budget (the paper's small-n cells)."""
    opt_on_host = model_state_bytes(cfg, st) > 0.9 * budget
    best = 0
    for logS in range(12, 24):  # 4K .. 8M
        S = 1 << logS
        stu = st
        if st.fpdt_u > 1:
            stu = dataclasses.replace(st, fpdt_u=max(1, min(st.fpdt_u, S // 65536)))
        dev_ok = train_memory_gb(cfg, S, stu, opt_on_host) * GB <= budget
        host = host_bytes(cfg, S, stu)
        if opt_on_host:
            host += 12 * cfg.num_params() / stu.n
        if dev_ok and host <= HOST_PER_GPU:
            best = S
    return best
