"""Kernel micro-benchmarks (CPU wall-clock; interpret-mode Pallas is a
correctness vehicle here — TPU timing comes from the roofline model).

Emits name,us_per_call,derived rows for benchmarks.run.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np


def _time(f, *args, iters=3) -> float:
    f(*args)  # compile
    jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> List[str]:
    from repro.kernels.flash_attention import ops as FA
    from repro.kernels.linear_scan import ops as LS

    rng = np.random.default_rng(0)
    rows = ["bench,name,us_per_call,derived"]
    b, h, s, d = 1, 4, 256, 64
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    for impl in ("xla_flash", "ref"):
        f = jax.jit(lambda q, k, v, impl=impl: FA.flash_attention(q, k, v, impl=impl,
                                                                  block_q=64, block_k=64))
        us = _time(f, q, k, v)
        flops = 4 * b * h * s * s * d / 2
        rows.append(f"bench,flash_attn_{impl}_{s},{us:.0f},{flops / (us * 1e-6) / 1e9:.1f}GFLOPs")
    a = jnp.asarray(rng.uniform(0.5, 0.99, (2, 512, 64)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 512, 64)), jnp.float32)
    for impl in ("xla",):
        f = jax.jit(lambda a, x, impl=impl: LS.linear_scan(a, x, impl=impl))
        us = _time(f, a, x)
        rows.append(f"bench,linear_scan_{impl}_512,{us:.0f},{2*512*64*2/(us*1e-6)/1e6:.1f}Melem/s")

    # FPDT chunk pipeline fwd+bwd: scan-compiled loops vs the unrolled
    # oracle (same math — the delta is loop overhead vs program size)
    import dataclasses

    from repro.configs import get_config, reduced
    from repro.core import fpdt as FP
    from repro.core.parallel import ParallelContext
    from repro.models import layers as L

    cfg0 = dataclasses.replace(reduced(get_config("llama3.2-1b")),
                               param_dtype="float32", block_q=16, block_k=16)
    p = L.init_attn(cfg0, jax.random.PRNGKey(0), jnp.float32)
    u, S = 8, 128
    xh = jnp.asarray(rng.standard_normal((1, S, cfg0.d_model)), jnp.float32)
    par = ParallelContext(mesh=None, attn_impl="xla_flash")
    for unroll in (False, True):
        cfgu = dataclasses.replace(cfg0, fpdt_chunks=u, fpdt_offload=True,
                                   fpdt_unroll=unroll)
        f = jax.jit(jax.grad(
            lambda x, c=cfgu: FP.fpdt_attention(c, par, p, x, kind="local").sum()))
        us = _time(f, xh)
        name = "unrolled" if unroll else "scan"
        rows.append(f"bench,fpdt_grad_u{u}_{name},{us:.0f},S{S}")
    return rows
