"""Analytic FLOPs / HBM-traffic / collective models per (arch x shape).

Why analytic: XLA's cost_analysis counts while-loop (lax.scan) bodies ONCE,
so compiled-artifact numbers need per-cycle extrapolation; the analytic model
is exact closed-form math over the known dims, causal-aware, and
MoE-capacity-aware.  benchmarks/roofline.py cross-checks it against HLO
probes (scan-unrolled 1-cycle/2-cycle compiles) and uses HLO-parsed numbers
for the collective term (the real GSPMD artifact we iterate on in §Perf).

All quantities are GLOBAL per step; divide by chip count for per-device.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

from repro.configs import ModelConfig, ShapeConfig

# TPU v5e
PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link (assignment constant)
BYTES = 2  # bf16 activations/params


# ---------------------------------------------------------------------------
# forward FLOPs
# ---------------------------------------------------------------------------


def _attn_layer_fwd(cfg: ModelConfig, B: int, S: int, window: int = 0,
                    kv_len: int = 0) -> Dict[str, float]:
    """One attention layer, forward. kv_len>0 => decode (S new tokens vs cache)."""
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    proj = 2 * B * S * d * (qd + 2 * kvd) + 2 * B * S * qd * d  # qkv + o
    if kv_len:  # decode: every new token attends kv_len keys (QK^T + PV)
        att = 4 * B * S * kv_len * qd
    elif window and window < S:  # banded local attention
        att = 4 * B * S * window * qd
    else:  # causal full: sum_i (i+1) = S(S+1)/2 attended positions
        att = 4 * B * (S * (S + 1) / 2) * qd
    return {"proj": proj, "attention": att}


def _mlp_layer_fwd(cfg: ModelConfig, B: int, S: int) -> float:
    n_mats = 3 if cfg.mlp_act == "swiglu" else 2
    if cfg.num_experts:
        cap_mult = cfg.experts_per_token * cfg.moe_capacity_factor
        router = 2 * B * S * cfg.d_model * cfg.num_experts
        return router + n_mats * 2 * B * S * cfg.d_model * cfg.d_ff * cap_mult
    return n_mats * 2 * B * S * cfg.d_model * cfg.d_ff


def _ssm_layer_fwd(cfg: ModelConfig, B: int, S: int) -> float:
    d, di, ds, dtr = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank_actual
    t = B * S
    f = 2 * t * d * 2 * di  # in_proj
    f += 2 * t * cfg.d_conv * di  # conv
    f += 2 * t * di * (dtr + 2 * ds)  # x_proj
    f += 2 * t * dtr * di  # dt_proj
    f += 10 * t * di * ds  # discretize + scan + C contraction
    f += 6 * t * di  # D, gating
    f += 2 * t * di * d  # out_proj
    return f


def _rglru_layer_fwd(cfg: ModelConfig, B: int, S: int) -> float:
    d, di = cfg.d_model, cfg.d_inner
    t = B * S
    f = 2 * t * d * di * 2  # y + gate branches
    f += 2 * t * cfg.d_conv * di
    f += 2 * t * di * di * 2  # r/i gate projections
    f += 12 * t * di  # gates, scan, sqrt
    f += 2 * t * di * d  # out
    return f


def _head_fwd(cfg: ModelConfig, B: int, S: int) -> float:
    return 2 * B * S * cfg.d_model * cfg.padded_vocab


def fwd_flops(cfg: ModelConfig, B: int, S: int, *, kv_len: int = 0,
              with_loss: bool = True) -> Dict[str, float]:
    """Global forward FLOPs by component."""
    out = {"proj": 0.0, "attention": 0.0, "mlp": 0.0, "ssm": 0.0, "rglru": 0.0}
    for kind in cfg.layer_kinds():
        if kind in ("attn", "local_attn"):
            w = cfg.window if kind == "local_attn" else 0
            a = _attn_layer_fwd(cfg, B, S, window=w, kv_len=kv_len)
            out["proj"] += a["proj"]
            out["attention"] += a["attention"]
            out["mlp"] += _mlp_layer_fwd(cfg, B, S)
        elif kind == "ssm":
            out["ssm"] += _ssm_layer_fwd(cfg, B, S)
        elif kind == "rglru":
            out["rglru"] += _rglru_layer_fwd(cfg, B, S)
            out["mlp"] += _mlp_layer_fwd(cfg, B, S)
    if with_loss:
        out["head"] = _head_fwd(cfg, B, S)
    return out


def step_flops(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, float]:
    """Global FLOPs for the cell's step function."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        f = fwd_flops(cfg, B, S)
        total_fwd = sum(f.values())
        mult = 4.0 if cfg.remat != "none" else 3.0  # fwd + 2x bwd (+ remat fwd)
        return {"total": total_fwd * mult, "fwd": total_fwd, "by_comp": f, "mult": mult}
    if shape.kind == "prefill":
        f = fwd_flops(cfg, B, S, with_loss=False)
        f["head"] = 2 * B * cfg.d_model * cfg.padded_vocab  # last position only
        return {"total": sum(f.values()), "fwd": sum(f.values()), "by_comp": f, "mult": 1.0}
    # decode: one token against a cache of length S
    f = fwd_flops(cfg, B, 1, kv_len=S, with_loss=False)
    f["head"] = 2 * B * cfg.d_model * cfg.padded_vocab
    return {"total": sum(f.values()), "fwd": sum(f.values()), "by_comp": f, "mult": 1.0}


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """The assignment's MODEL_FLOPS: 6*N*D (dense) / 6*N_active*D (MoE)."""
    n = cfg.num_active_params() if cfg.num_experts else cfg.num_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2 * n * tokens  # forward-only
    else:
        return 2 * n * shape.global_batch  # one token per sequence
    return 6 * n * tokens


# ---------------------------------------------------------------------------
# HBM traffic model (deployment: Pallas flash kernels, remat, ZeRO)
# ---------------------------------------------------------------------------


def step_hbm_bytes(cfg: ModelConfig, shape: ShapeConfig, chips: int) -> float:
    """Global HBM bytes per step (so per-chip = /chips).

    Model: weights are read from HBM once per pass after the ZeRO gather
    (fwd, remat-fwd, bwd => 3x for train, 1x inference); optimizer state
    read+write; activations ~6 tensor r/w per layer; attention KV streamed
    once per query chunk pair (flash); KV-cache read for decode; embedding
    and logits traffic."""
    B, S = shape.global_batch, shape.seq_len
    N = cfg.num_params()
    tok = B * (1 if shape.kind == "decode" else S)
    d = cfg.d_model
    passes = 3 if shape.kind == "train" else 1
    w = N * BYTES * passes
    if shape.kind == "train":
        sd = 2 if cfg.opt_state_dtype == "bfloat16" else 4
        w += N * (2 * sd * 2 + BYTES * 2)  # m,v read+write; p read+write; grads
    act = 0.0
    for kind in cfg.layer_kinds():
        per_tok = {"attn": 8, "local_attn": 8, "ssm": 10, "rglru": 10}[kind] * d * BYTES
        act += tok * per_tok * (2 if shape.kind == "train" else 1)
        if kind in ("attn", "local_attn"):
            # flash attention KV streaming: each q block reads the allowed KV band
            if shape.kind == "decode":
                act += B * S * cfg.kv_dim * 2 * BYTES  # read whole cache
            else:
                eff = min(cfg.window, S) if kind == "local_attn" and cfg.window else S
                nq = max(1, S // max(cfg.block_q, 1))
                frac = 0.5 if eff == S else eff / S
                act += B * nq * (eff * frac if eff == S else eff) * cfg.kv_dim * 2 * BYTES
    logits_tok = B if shape.kind != "train" else tok
    act += logits_tok * cfg.padded_vocab * 4  # fp32 logits write+read once
    return w + act


# ---------------------------------------------------------------------------
# roofline terms
# ---------------------------------------------------------------------------


def terms(cfg: ModelConfig, shape: ShapeConfig, chips: int,
          collective_bytes_per_chip: float = 0.0) -> Dict[str, float]:
    fl = step_flops(cfg, shape)
    hbm = step_hbm_bytes(cfg, shape, chips)
    t_compute = fl["total"] / chips / PEAK_FLOPS
    t_memory = hbm / chips / HBM_BW
    t_coll = collective_bytes_per_chip / ICI_BW
    mf = model_flops(cfg, shape)
    return {
        "flops_total": fl["total"],
        "hbm_bytes": hbm,
        "t_compute": t_compute,
        "t_memory": t_memory,
        "t_collective": t_coll,
        "bottleneck": max(
            [("compute", t_compute), ("memory", t_memory), ("collective", t_coll)],
            key=lambda kv: kv[1],
        )[0],
        "model_flops": mf,
        "useful_ratio": mf / fl["total"] if fl["total"] else 0.0,
        "roofline_frac": max(t_compute, 1e-30)
        / max(t_compute, t_memory, t_coll, 1e-30),
    }
