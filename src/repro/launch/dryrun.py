import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init).  For every cell this driver:
  1. builds the production mesh ((16,16) single-pod / (2,16,16) multi-pod),
  2. jit-lowers the cell's step function over ShapeDtypeStruct inputs with
     the production in/out shardings,
  3. .compile()s it (sharding mismatches, OOM-at-compile, unsupported
     collectives all fail HERE),
  4. records memory_analysis / cost_analysis / per-collective byte counts
     (parsed from the compiled HLO) into experiments/dryrun/*.json for the
     roofline analysis (EXPERIMENTS.md reads these).

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k --mesh both
  python -m repro.launch.dryrun --all
"""
import argparse
import json
import re
import time
import traceback

import jax

jax.config.update("jax_compilation_cache_dir", "/tmp/jax_cache")
jax.config.update("jax_persistent_cache_min_compile_time_secs", 1)

from repro.configs import ASSIGNED_ARCHS, SHAPES, get_config, shape_applicable
from repro.core.parallel import ParallelContext
from repro.launch import steps as ST
from repro.launch.hlo import count_ops
from repro.launch.mesh import dp_axes_of, make_production_mesh
from repro.runtime.placement import PlacementPolicy

COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\s*=?\s*.*?"
)
SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
    "pred": 1, "f64": 8, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2,
}


def _op_output_bytes(line: str) -> int:
    """Bytes of the op's output (shape text between '=' and the op name)."""
    try:
        rhs = line.split("=", 1)[1]
    except IndexError:
        return 0
    rhs = rhs.lstrip()
    if rhs.startswith("("):  # tuple-shaped output: take up to the matching ')'
        head = rhs[: rhs.index(")") + 1]
    else:  # cut at the op call's '(' so operand shapes aren't counted
        head = rhs.split("(", 1)[0]
    total = 0
    for dt, dims in SHAPE_RE.findall(head):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str):
    """Sum output bytes per collective kind (done-ops skipped: counted at start)."""
    out = {}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(
            r".*= \S+ (all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
            r"(?:-start)?\(", ls)
        if not m:
            # tuple-shaped lhs: "%x = (f32[..],..) all-gather-start(..."
            m = re.match(
                r".*\) (all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
                r"(?:-start)?\(", ls)
        if not m:
            continue
        if "-done" in ls.split("=")[1][:40]:
            continue
        kind = m.group(1)
        b = _op_output_bytes(ls)
        d = out.setdefault(kind, {"count": 0, "bytes": 0})
        d["count"] += 1
        d["bytes"] += b
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, chunks=None, offload=None,
             outdir: str = "experiments/dryrun") -> dict:
    from repro.configs import SHAPES

    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    # NOTE: offload disabled for the big-mesh dry-run: XLA:CPU's SPMD
    # partitioner rejects annotate_device_placement custom-calls produced by
    # in-graph host offload at this scale ("side-effect ops cannot be
    # replicated") — a backend limitation, not a sharding bug; the offload
    # path compiles+runs at the 8-device mesh (tests) and in the host-KV
    # decode cells.  Chunking semantics are unchanged ("FPDT w. chunking").
    # The disable is expressed through the placement policy (the single
    # layer that owns memory-kind decisions), not ad-hoc flags downstream.
    pol = PlacementPolicy.probe(mesh.devices.flat[0], offload_enabled=False)
    par = ParallelContext(mesh=mesh, dp_axes=dp_axes_of(mesh), attn_impl="xla_flash",
                          offload_to_host=False, placement=pol)
    cfg = ST.tuned_config(get_config(arch), shape, chunks=chunks, offload=offload)
    n_host_chunks = 0
    if shape.kind == "decode" and shape.seq_len >= 500_000 and cfg.family in ("dense",):
        # EXTRA cell: FPDT host-streamed KV decode.  --chunks sweeps the
        # host-KV chunk count here (the decode-side analogue of u; the
        # scan-compiled decode keeps program size flat in it).
        n_host_chunks = chunks if chunks else 8
        if shape.seq_len % n_host_chunks:
            # _decode_attention silently falls back to on-device attention
            # for non-dividing chunk counts — that would record numbers for
            # the wrong program under this cell's label
            raise ValueError(
                f"--chunks {n_host_chunks} does not divide the decode cache "
                f"length {shape.seq_len}; the host-streamed path requires "
                f"equal slabs")
    rec = {
        "arch": arch, "shape": shape_name, "mesh": "multi" if multi_pod else "single",
        "kind": shape.kind, "chunks": cfg.fpdt_chunks, "offload": cfg.fpdt_offload,
        "n_host_chunks": n_host_chunks,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "params": cfg.num_params(), "active_params": cfg.num_active_params(),
    }
    t0 = time.time()
    try:
        fn, args, in_sh, out_sh, donate = ST.build(cfg, par, shape, n_host_chunks=n_host_chunks)
        with mesh:
            jf = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
            lowered = jf.lower(*args)
            rec["lower_s"] = round(time.time() - t0, 1)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 1)
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "host_temp_bytes": ma.host_temp_size_in_bytes,
            "host_argument_bytes": ma.host_argument_size_in_bytes,
        }
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):  # older jax: one dict per program
            ca = ca[0] if ca else {}
        rec["cost"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        }
        hlo_text = compiled.as_text()
        rec["collectives"] = parse_collectives(hlo_text)
        # program size: the scan-compiled FPDT/layer loops must keep this
        # ~flat in fpdt_chunks and depth (see benchmarks/compile_scaling.py)
        rec["hlo_ops"] = count_ops(hlo_text)
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 - report, don't crash the sweep
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    os.makedirs(outdir, exist_ok=True)
    suffix = "" if chunks is None else f"_u{chunks}" + ("off" if offload else "")
    fn_out = os.path.join(outdir, f"{arch}_{shape_name}_{rec['mesh']}{suffix}.json")
    with open(fn_out, "w") as f:
        json.dump(rec, f, indent=1)
    status = "OK " if rec["ok"] else "FAIL"
    print(f"[{status}] {arch:28s} {shape_name:12s} {rec['mesh']:6s} "
          f"lower={rec.get('lower_s','-')}s compile={rec.get('compile_s','-')}s "
          f"temp={rec.get('memory',{}).get('temp_bytes',0)/2**30:.2f}GiB"
          + ("" if rec["ok"] else f"  {rec['error'][:150]}"))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--chunks", type=int, default=None)
    ap.add_argument("--offload", action="store_true", default=None)
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    cells = []
    if args.all:
        for a in ASSIGNED_ARCHS:
            for s in SHAPES:
                if shape_applicable(a, s):
                    cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells = [(args.arch, args.shape)]

    fails = 0
    for a, s in cells:
        for mp in meshes:
            rec = run_cell(a, s, mp, chunks=args.chunks, offload=args.offload, outdir=args.out)
            fails += 0 if rec["ok"] else 1
    print(f"\n{len(cells) * len(meshes) - fails} ok, {fails} failed")
    raise SystemExit(1 if fails else 0)


if __name__ == "__main__":
    main()
