"""HLO/StableHLO text analysis shared by the dry-run driver and the
compile-scaling benchmark — one definition of the program-size heuristic so
the two recorded numbers stay comparable."""
from __future__ import annotations


def count_ops(hlo_text: str) -> int:
    """Assignment count in an (Stable)HLO module text — the program-size
    proxy the scan-compiled pipelines are measured by (loop/branch bodies
    are printed once, so this is ~flat in chunk count and depth)."""
    return sum(1 for line in hlo_text.splitlines() if " = " in line)
