"""ShapeDtypeStruct stand-ins for every model input (dry-run currency).

``input_specs(cfg, shape)`` returns the exact input pytree each step kind
consumes — weak-type-correct, shardable, no device allocation:
  train   -> {"tokens"/"frame_embeds"/"patch_embeds", "labels"}
  prefill -> same minus labels
  decode  -> (cache, inp, pos): one new token against a seq_len KV cache
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig, ShapeConfig
from repro.models import serve as SV

F = jax.ShapeDtypeStruct


def _fwd_batch_specs(cfg: ModelConfig, B: int, S: int, with_labels: bool) -> Dict[str, Any]:
    emb_dt = jnp.dtype(cfg.param_dtype)
    out: Dict[str, Any] = {}
    if cfg.frontend == "audio_frames":
        out["frame_embeds"] = F((B, S, cfg.d_model), emb_dt)
    elif cfg.frontend == "vision_patches":
        out["patch_embeds"] = F((B, cfg.num_patches, cfg.d_model), emb_dt)
        out["tokens"] = F((B, S - cfg.num_patches), jnp.int32)
    else:
        out["tokens"] = F((B, S), jnp.int32)
    if with_labels:
        ls = S if cfg.frontend != "vision_patches" else S - cfg.num_patches
        out["labels"] = F((B, ls), jnp.int32)
    return out


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Returns (kind, specs) where specs matches the step function inputs."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return "train", {"batch": _fwd_batch_specs(cfg, B, S, with_labels=True)}
    if shape.kind == "prefill":
        return "prefill", {"batch": _fwd_batch_specs(cfg, B, S, with_labels=False)}
    # decode: one token against a cache of length S
    cache = jax.eval_shape(lambda: SV.init_cache(cfg, B, S))
    if cfg.frontend == "audio_frames":
        inp = {"frame_embeds": F((B, 1, cfg.d_model), jnp.dtype(cfg.param_dtype))}
    else:
        inp = {"tokens": F((B, 1), jnp.int32)}
    return "decode", {"cache": cache, "inp": inp, "pos": F((), jnp.int32)}


def params_spec(cfg: ModelConfig):
    from repro.models import transformer as T

    return jax.eval_shape(lambda: T.init_params(cfg, jax.random.PRNGKey(0)))


def opt_spec(cfg: ModelConfig, oc, params_shape):
    from repro.optim import adamw

    return jax.eval_shape(lambda: adamw.init(oc, params_shape))
