"""Deterministic fault injection for the replica serve tier.

Failover code that is only exercised by real outages is untested code.
This module turns every failure mode the router must survive into a
reproducible fixture: a :class:`FaultyReplica` wraps any replica-shaped
object (``generate(prompts) -> outputs``) and executes a scripted
*fault plan* — raise on the Nth dispatch, stall past a deadline, fail
transiently then recover — with zero randomness, so a test or benchmark
that seeds its workload gets the exact same crash at the exact same
dispatch every run.

Fault kinds
-----------
* ``raise``     permanent: every dispatch from ``at_dispatch`` on raises
                :class:`FaultInjected` until :meth:`FaultyReplica.heal`
                (models a crashed process — it stays down).
* ``transient`` dispatches ``[at_dispatch, at_dispatch + count)`` raise,
                later ones succeed (models a blip: OOM-retry, dropped
                connection, preempted node coming back).
* ``hang``      dispatches in the same window *succeed* but only after
                sleeping ``hang_s`` seconds — paired with the router's
                ``dispatch_timeout`` this is a deterministic stand-in
                for a stalled replica (the result arrives too late and
                is discarded; no threads, no races).

Everything else (``last_stats``, ``save_kv_store``, ...) passes through
to the wrapped replica untouched, so a ``FaultyReplica`` drops into any
router seat a real engine occupies.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, List, Sequence

__all__ = ["Fault", "FaultInjected", "FaultyReplica", "parse_fault_plan"]

_KINDS = ("raise", "transient", "hang")


class FaultInjected(RuntimeError):
    """The error a scripted fault raises — distinguishable from real bugs,
    and naming the dispatch it fired on so traces are self-explaining."""

    def __init__(self, kind: str, dispatch: int):
        self.kind = kind
        self.dispatch = dispatch
        super().__init__(f"injected {kind} fault on dispatch {dispatch}")


@dataclass(frozen=True)
class Fault:
    """One scripted failure.

    ``at_dispatch`` counts the wrapper's ``generate`` calls from 0; the
    fault window is ``[at_dispatch, at_dispatch + count)`` for transient
    and hang faults, and ``[at_dispatch, heal)`` for permanent raises.
    """

    kind: str
    at_dispatch: int
    count: int = 1
    hang_s: float = 0.0

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(expected one of {_KINDS})")
        if self.at_dispatch < 0 or self.count < 1:
            raise ValueError("fault needs at_dispatch >= 0 and count >= 1")

    def fires_at(self, dispatch: int) -> bool:
        if self.kind == "raise":
            return dispatch >= self.at_dispatch
        return self.at_dispatch <= dispatch < self.at_dispatch + self.count


class FaultyReplica:
    """Wrap a replica with a fault plan; duck-types as the replica itself."""

    def __init__(self, inner: Any, faults: Sequence[Fault] = (),
                 name: str = ""):
        self.inner = inner
        self.faults = list(faults)
        self.name = name
        self.dispatches = 0    # generate() calls seen (fired or not)
        self.injected = 0      # faults actually raised
        self.hung = 0          # hang windows actually slept
        self.healed = False

    def heal(self) -> None:
        """Clear permanent faults — the replica 'process' came back."""
        self.healed = True

    def generate(self, prompts, *args, **kwargs):
        n = self.dispatches
        self.dispatches += 1
        if not self.healed:
            for f in self.faults:
                if not f.fires_at(n):
                    continue
                if f.kind == "hang":
                    self.hung += 1
                    time.sleep(f.hang_s)
                    break  # slow but successful — fall through to inner
                self.injected += 1
                raise FaultInjected(f.kind, n)
        return self.inner.generate(prompts, *args, **kwargs)

    def __getattr__(self, attr):  # last_stats, save_kv_store, engine, ...
        return getattr(self.inner, attr)

    def __repr__(self):
        tag = self.name or type(self.inner).__name__
        return (f"FaultyReplica({tag}, faults={len(self.faults)}, "
                f"dispatches={self.dispatches}, injected={self.injected})")


def parse_fault_plan(spec: str) -> Dict[int, List[Fault]]:
    """Parse a CLI fault plan into per-replica fault lists.

    Grammar: ``;``-separated items, each ``R:KIND@N[xC][~S]`` — replica
    ``R`` gets a ``KIND`` fault at dispatch ``N``, repeated for ``C``
    consecutive dispatches (default 1), hanging ``S`` seconds when
    ``KIND`` is ``hang``.  Examples::

        1:raise@2                 replica 1 dies permanently on dispatch 2
        0:transient@1x2           replica 0 blips on dispatches 1 and 2
        2:hang@0~0.2;1:raise@3    two replicas, two fault modes
    """
    plan: Dict[int, List[Fault]] = {}
    for item in filter(None, (s.strip() for s in spec.split(";"))):
        try:
            rep, rest = item.split(":", 1)
            kind, rest = rest.split("@", 1)
            hang_s = 0.0
            if "~" in rest:
                rest, secs = rest.split("~", 1)
                hang_s = float(secs)
            count = 1
            if "x" in rest:
                rest, cnt = rest.split("x", 1)
                count = int(cnt)
            fault = Fault(kind=kind.strip(), at_dispatch=int(rest),
                          count=count, hang_s=hang_s)
        except (ValueError, IndexError) as e:
            raise ValueError(
                f"bad fault-plan item {item!r} (expected R:KIND@N[xC][~S], "
                f"e.g. '1:raise@2' or '0:hang@0~0.2'): {e}") from e
        plan.setdefault(int(rep), []).append(fault)
    return plan
