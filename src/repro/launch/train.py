"""Training CLI.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --reduced \
      --steps 100 --batch 8 --seq 256 [--chunks 4 --offload] [--resume auto]

On this CPU container use --reduced (the full configs are exercised through
the dry-run); on a real TPU fleet drop --reduced and point --mesh at the
production topology.
"""
from __future__ import annotations

import argparse
import dataclasses
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--chunks", type=int, default=None, help="FPDT u")
    ap.add_argument("--offload", action="store_true")
    ap.add_argument("--remat", default=None, choices=[None, "none", "full", "offload"])
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--mesh", default="none", choices=["none", "host8"],
                    help="host8: 8 fake CPU devices, (2 data, 4 model)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default=None, help="'auto' or a step number")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--trace-out", default="",
                    help="write per-step train.step spans as Chrome "
                         "trace-event JSON (Perfetto-viewable)")
    ap.add_argument("--metrics-out", default="",
                    help="write the step-timing metrics registry as "
                         "Prometheus text exposition")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.mesh == "host8":
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

    import jax
    import jax.numpy as jnp

    from repro.checkpoint.manager import CheckpointManager
    from repro.configs import ShapeConfig, get_config, reduced
    from repro.core.parallel import ParallelContext
    from repro.data.pipeline import CheckpointableIterator, make_batch_fn
    from repro.models import transformer as T
    from repro.optim import adamw
    from repro.runtime.placement import default_policy
    from repro.runtime.train_loop import TrainConfig, TrainLoop, make_train_step

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    over = {}
    if args.chunks:
        over.update(fpdt_chunks=args.chunks, mlp_chunks=2 * args.chunks)
    if args.offload:
        over["fpdt_offload"] = True
    if args.remat:
        over["remat"] = args.remat
    if over:
        cfg = dataclasses.replace(cfg, **over)

    pol = default_policy()  # probe the backend's memory kinds once
    par = None
    mesh_cm = None
    if args.mesh == "host8":
        from repro.launch.mesh import make_compat_mesh

        mesh = make_compat_mesh((2, 4), ("data", "model"))
        par = ParallelContext.for_mesh(mesh, attn_impl="pallas", placement=pol)
        mesh_cm = mesh

    params = T.init_params(cfg, jax.random.PRNGKey(args.seed))
    oc = adamw.OptConfig(lr=args.lr, warmup_steps=min(100, args.steps // 10 + 1),
                         total_steps=args.steps, state_dtype=cfg.opt_state_dtype)
    opt_state = adamw.init(oc, params)
    tc = TrainConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                     log_every=args.log_every, grad_accum=args.grad_accum,
                     compress_grads=args.compress_grads)
    step_fn = jax.jit(make_train_step(cfg, par, oc, tc), donate_argnums=(0, 1))
    bf = make_batch_fn(cfg, ShapeConfig("cli", args.seq, args.batch, "train"))
    data = CheckpointableIterator(bf)
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    start = 0
    if mgr and args.resume:
        step = mgr.latest_step() if args.resume == "auto" else int(args.resume)
        if step is not None:
            (restored, extra) = mgr.restore(step, {"params": params, "opt": opt_state})
            params, opt_state = restored["params"], restored["opt"]
            start = step
            print(f"[resume] restored step {step}")

    def put(b):
        return {k: pol.put(jnp.asarray(v)) for k, v in b.items()}

    loop = TrainLoop(cfg, par, oc, tc, step_fn, data, mgr)
    ctx = mesh_cm if mesh_cm is not None else _null()
    with ctx:
        loop.run(params, opt_state, start_step=start, put_batch=put)
    if args.trace_out or args.metrics_out:
        from repro.runtime import telemetry as TM

        if args.trace_out:
            doc = TM.write_chrome_trace(args.trace_out, loop.telemetry)
            print(f"[telemetry] wrote {len(doc['traceEvents'])} trace "
                  f"events to {args.trace_out}")
        if args.metrics_out:
            TM.write_prometheus(args.metrics_out, loop.telemetry)
            print(f"[telemetry] wrote metrics registry to {args.metrics_out}")
        h = loop.telemetry.registry.histogram("train_step_ms").summary()
        print(f"[telemetry] train_step_ms: p50 {h['p50']:.1f} "
              f"p95 {h['p95']:.1f} mean {h['mean']:.1f} over "
              f"{h['count']} steps")


class _null:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
