"""Step functions lowered by the dry-run, the trainer, and the server.

``build(cfg, par, shape)`` returns (step_fn, arg_specs, in_shardings,
out_shardings, donate) ready for jax.jit().lower().
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ModelConfig, ShapeConfig
from repro.core.parallel import ParallelContext
from repro.launch import shardings as SH
from repro.launch import specs as SP
from repro.models import serve as SV
from repro.models import transformer as T
from repro.optim import adamw


def tuned_config(cfg: ModelConfig, shape: ShapeConfig, chunks: Optional[int] = None,
                 offload: Optional[bool] = None) -> ModelConfig:
    """Apply the paper's default chunking policy to a cell.

    Chunk size 64K tokens (paper §5.3 sweet spot): u = max(1, S/65536);
    FFN chunks = 2*u (§5.4); offload on when u > 1."""
    S = shape.seq_len
    u = chunks if chunks is not None else max(1, S // 65536)
    while S % u:
        u -= 1
    off = offload if offload is not None else (u > 1)
    # §Perf B4 epilogue: dropping remat cut X 669->562 ms and C by 25% on
    # llama3.2-1b train_4k, but the compiled temp memory rose 2.8 -> 19.1
    # GiB/device — over v5e's 16 GiB.  NOT adopted on this mesh; remat
    # stays on (the dry-run's memory_analysis is the capacity gate).
    mlp_chunks = max(1, 2 * u) if u > 1 else 1
    if cfg.num_experts and shape.kind == "train":
        # GShard dispatch position tensors scale with tokens x k x E: chunk
        # the MoE FFN (paper §5.4) to bound the live set (granite: temp
        # 35.5 -> fits; llama4: 39.8 -> fits)
        mlp_chunks = max(mlp_chunks, 8)
    return dataclasses.replace(
        cfg, fpdt_chunks=u, fpdt_offload=off, mlp_chunks=mlp_chunks,
    )


def build(cfg: ModelConfig, par: ParallelContext, shape: ShapeConfig,
          oc: Optional[adamw.OptConfig] = None, n_host_chunks: int = 0):
    kind, arg_specs = SP.input_specs(cfg, shape)
    pspec = SP.params_spec(cfg)
    pshard = SH.param_shardings(cfg, par, pspec)

    if kind == "train":
        oc = oc or adamw.OptConfig(state_dtype=cfg.opt_state_dtype)
        ospec = SP.opt_spec(cfg, oc, pspec)
        oshard = SH.opt_shardings(cfg, par, ospec, pspec)
        bshard = SH.batch_shardings(cfg, par, arg_specs["batch"])

        def train_step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: T.loss_fn(cfg, par, p, batch), has_aux=True
            )(params)
            # force gradients onto the optimizer-state sharding (ZeRO-1 mode:
            # one reduce-scatter instead of a full all-reduce)
            grads = jax.tree.map(
                lambda g, sh: jax.lax.with_sharding_constraint(g, sh),
                grads, oshard.m)
            params, opt_state, om = adamw.apply(oc, params, grads, opt_state)
            metrics = {**metrics, **om}
            return params, opt_state, metrics

        args = (pspec, ospec, arg_specs["batch"])
        in_sh = (pshard, oshard, bshard)
        out_sh = (pshard, oshard, None)
        return train_step, args, in_sh, out_sh, (0, 1)

    if kind == "prefill":
        bshard = SH.batch_shardings(cfg, par, arg_specs["batch"])
        cache_spec = jax.eval_shape(lambda: SV.init_cache(cfg, shape.global_batch, shape.seq_len))
        cshard = SV.cache_shardings(cfg, par, cache_spec)

        def prefill(params, batch):
            return SV.prefill_step(cfg, par, params, batch, max_len=shape.seq_len)

        args = (pspec, arg_specs["batch"])
        return prefill, args, (pshard, bshard), (None, cshard), ()

    # decode
    cshard = SV.cache_shardings(cfg, par, arg_specs["cache"])
    if n_host_chunks:  # FPDT-for-inference: cache lives in host memory
        # host-placement custom-calls reject PARTIAL replication: the cache
        # must be sharded across every mesh axis -> shard S over all axes.
        # Memory kinds come from the placement policy: on a backend with no
        # pinned-host pool these become plain device-resident shardings.
        all_axes = tuple(par.mesh.axis_names)
        ndev = par.mesh.size

        on_host = par.offload_active  # capable backend AND context opted in

        def host_spec(path, leaf):
            names = [str(getattr(pp, "key", getattr(pp, "name", ""))) for pp in path]
            stacked = names[0] != "tail"
            lead = (None,) if stacked else ()
            off = 1 if stacked else 0
            sdim = leaf.shape[off + 1] if leaf.ndim - off >= 2 else 0
            if sdim and sdim % ndev == 0:
                rest = (None,) * (leaf.ndim - off - 2)
                return par.pol.ns(par.mesh, *lead, None, all_axes, *rest,
                                  on_host=on_host)
            return par.pol.ns(par.mesh, on_host=on_host)

        cshard = jax.tree_util.tree_map_with_path(host_spec, arg_specs["cache"])
    ishard = SH.batch_shardings(cfg, par, arg_specs["inp"])

    def serve_step(cache, inp, pos, params):
        logits, cache = SV.decode_step(cfg, par, params, cache, inp, pos,
                                       n_host_chunks=n_host_chunks)
        if n_host_chunks and par.offload_active:
            # re-offload the updated cache with an *internal* device_put
            # (out_shardings memory kinds are unsupported for SPMD outputs)
            cache = jax.tree.map(
                lambda x, sh: par.pol.put(
                    jax.lax.with_sharding_constraint(
                        x, NamedSharding(par.mesh, sh.spec)), sh),
                cache, cshard,
            )
        return logits, cache

    args = (arg_specs["cache"], arg_specs["inp"], arg_specs["pos"], pspec)
    in_sh = (cshard, ishard, NamedSharding(par.mesh, P()), pshard)
    out_sh = (None, None if n_host_chunks else cshard)
    return serve_step, args, in_sh, out_sh, (0,)
