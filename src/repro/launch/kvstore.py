"""Shared prefix-cache store across serve replicas.

PR 7 gave a single engine a persistent prefix cache: ``save_kv_store``
walks the radix tree and writes every cached page payload to one npz;
``restore_kv_store`` loads it into the spill tier, where pages promote
back to device on their first prefix hit.  This module points that
machinery *sideways*: replicas behind the router publish their prefix
caches into one shared directory (one npz per replica — writers never
contend), and on replica death the router restores the dead replica's
file into the survivors.  Re-homed requests then resume against radix
entries that already hold their context — a warm promote instead of a
cold prefill — which is what makes failover cheap at long context.

Publishing is best-effort by design: the store is a cache of recoverable
state, never the source of truth, so a failed save/restore degrades to
recompute (a cold prefill on the survivor) rather than an error.  The
one crash-consistency fact it leans on: a ``PagedServeEngine`` whose
``generate`` raised mid-workload still has a consistent radix tree +
pool (``_admit``/``_dispatch`` sync at every mutation), so even the
*dead* replica's cache can be published post-mortem from the same
process — the in-process analogue of reading a crashed peer's store.
"""
from __future__ import annotations

import os
from typing import Any, Dict, List, Optional, Sequence

from repro.runtime import telemetry as TM

__all__ = ["SharedKVStore"]


class SharedKVStore:
    """One npz prefix-cache file per replica under a shared root dir."""

    def __init__(self, root: str,
                 telemetry: Optional[TM.Telemetry] = None):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.published_pages: Dict[int, int] = {}  # replica -> pages in file
        self.errors = 0  # swallowed best-effort failures (for stats only)
        self.telemetry = telemetry if telemetry is not None \
            else TM.Telemetry(component="kvstore")

    def _event(self, kind: str, replica: int, pages: int) -> None:
        self.telemetry.registry.counter(f"{kind.replace('.', '_')}").inc()
        self.telemetry.registry.counter("kvstore_pages_moved").inc(pages)
        self.telemetry.event(kind, replica=replica, pages=pages)

    def path(self, replica: int) -> str:
        return os.path.join(self.root, f"replica{int(replica)}.npz")

    def publish(self, replica: int, engine: Any) -> int:
        """Persist ``engine``'s prefix cache as replica ``replica``'s file.

        Returns pages written (0 when the engine has nothing cached or
        the save failed — best-effort either way)."""
        try:
            n = int(engine.save_kv_store(self.path(replica)))
        except Exception:
            self.errors += 1
            self.telemetry.registry.counter("kvstore_errors").inc()
            return 0
        self.published_pages[replica] = n
        self._event("kvstore.publish", replica, n)
        return n

    def recover(self, dead: int, survivors: Sequence[Any]) -> int:
        """Restore the dead replica's published cache into every survivor.

        Restore is idempotent (live radix entries win over restored
        ones), so survivors that already share prefixes with the dead
        replica lose nothing.  Returns total pages restored across
        survivors (0 when the dead replica never published)."""
        p = self.path(dead)
        if not os.path.exists(p):
            return 0
        total = 0
        for eng in survivors:
            try:
                total += int(eng.restore_kv_store(p))
            except Exception:
                self.errors += 1
                self.telemetry.registry.counter("kvstore_errors").inc()
        self._event("kvstore.recover", dead, total)
        return total

    def restore_self(self, replica: int, engine: Any) -> int:
        """Rejoin path: load a replica's own published file back into it
        (a rejoining replica is typically a fresh, cold engine)."""
        p = self.path(replica)
        if not os.path.exists(p):
            return 0
        try:
            n = int(engine.restore_kv_store(p))
        except Exception:
            self.errors += 1
            self.telemetry.registry.counter("kvstore_errors").inc()
            return 0
        self._event("kvstore.restore_self", replica, n)
        return n

    def __repr__(self):
        return (f"SharedKVStore({self.root!r}, "
                f"published={dict(self.published_pages)})")
