"""Serving CLI: batched prefill + scan-compiled multi-token decode.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
      --batch 4 --prompt-len 64 --gen 32 [--host-kv-chunks 8] \
      [--temperature 0.8 --top-k 40]

The whole generation is ONE jitted ``runtime.decode_loop.decode_tokens``
call (a ``lax.scan`` over steps), so there is a single dispatch for the
entire decode and program size is flat in ``--gen`` and
``--host-kv-chunks``.  ``--per-token`` keeps the legacy one-jitted-call-
per-token loop for A/B timing (and is the only mode for the audio-frame
frontend, which feeds embeddings instead of token ids).

``--engine`` switches to the continuous-batching ``ServeEngine`` (the
fused mixed-step scheduler: chunked prefill interleaved with decode, see
``docs/serving.md``): ``--requests`` mixed-length prompts over ``--batch``
slots, ``--prefill-chunk`` tokens streamed into a refilling slot per step
while the others decode.  ``--blocking`` runs the stop-the-world refill
baseline instead for A/B.  ``--paged`` swaps the dense per-slot cache for
the slot-shared paged pool with radix prefix reuse
(``runtime/paged.py``): ``--page-size`` tokens per page, ``--n-pages``
physical pages (0 = dense-equivalent), ``--shared-prefix`` prepends a
common system prompt to every request to exercise the radix hits, and
the run reports prefix-hit and page-occupancy stats.  ``--spill-pages N``
adds the host-resident spill tier (evicted radix pages demote instead of
dropping) and ``--kv-store PATH`` persists the prefix cache across runs:
restored at startup when the file exists, saved after the workload — a
restarted server re-serves a shared system prompt as radix hits.
``--sched slo`` (with ``--paged``) swaps in the SLO-aware scheduler
(``runtime/paged.py::SLOPagedServeEngine``): short prompts become the
priority-0 interactive tier, long ones best-effort batch; low-priority
slots are preempted via the radix/spill publish-release path and
``--prefill-budget N`` caps prefill chunks per burst.  ``--sched fifo``
runs the same engine in arrival-order mode for A/B.

``--mesh AxB`` shards each engine over an (A data, B model) device mesh
(paged pool kv-heads over ``model`` per ``models/serve.py``), ``--replicas
N`` runs N such engines on disjoint device slices behind the
session-affine router (``launch/router.py``; ``--router rr`` is the
locality-shredding baseline), with per-replica request/prefix-hit stats.
On CPU the device count is forced automatically (train.py's host8
pattern).

The router survives replica failure by default: ``--fault-plan
"1:raise@2"`` injects a deterministic crash (``launch/faults.py``) to
watch it happen, ``--retry``/``--dispatch-timeout`` tune the
suspect-state retry budget and the stall deadline, and
``--shared-kv-store DIR`` gives replicas a shared prefix-cache
directory so a dead replica's published pages restore into survivors
and its re-homed sessions resume warm (``prefix_hit_tokens > 0``
instead of a cold prefill).  Failover stats (deaths, retries, re-homed
sessions, recovered prefix tokens) print alongside the per-replica
ones.
"""
from __future__ import annotations

import argparse
import dataclasses
import time


class _MeshReplica:
    """One sharded engine + its mesh, entered around every dispatch — the
    router stays framework-free and replicas stay self-contained."""

    def __init__(self, engine, par):
        self.engine, self.par = engine, par

    def generate(self, prompts):
        with self.par.mesh:
            return self.engine.generate(prompts)

    # prefix-cache persistence proxies: the shared KV store publishes /
    # restores through the replica, and page reads touch mesh-sharded
    # arrays, so they run under the replica's mesh like generate()
    def save_kv_store(self, path):
        with self.par.mesh:
            return self.engine.save_kv_store(path)

    def restore_kv_store(self, path):
        with self.par.mesh:
            return self.engine.restore_kv_store(path)

    @property
    def last_stats(self):
        return self.engine.last_stats


def _export_telemetry(args, telemetries):
    """--trace-out / --metrics-out: dump the run's telemetry to disk."""
    from repro.runtime import telemetry as TM

    if args.trace_out:
        doc = TM.write_chrome_trace(args.trace_out, telemetries)
        print(f"[telemetry] wrote {len(doc['traceEvents'])} trace events "
              f"to {args.trace_out} (open in Perfetto / chrome://tracing)")
    if args.metrics_out:
        TM.write_prometheus(args.metrics_out, telemetries)
        print(f"[telemetry] wrote metrics registry to {args.metrics_out}")


def _engine_main(args):
    import jax
    import numpy as np

    from repro.configs import get_config, reduced
    from repro.core.parallel import ParallelContext
    from repro.models import transformer as T
    from repro.runtime import decode_loop as DL

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    cfg = dataclasses.replace(cfg, remat="none")
    params = T.init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    shared = rng.integers(0, cfg.vocab_size, size=args.shared_prefix).tolist()
    lens = rng.integers(max(1, args.prompt_len // 4), args.prompt_len + 1,
                        size=args.requests)
    prompts = [shared + rng.integers(0, cfg.vocab_size, size=n).tolist()
               for n in lens]
    if args.mesh:
        return _mesh_engine_main(args, cfg, params, prompts)
    par = ParallelContext(mesh=None) if args.host_kv_chunks else None
    bucket = args.prompt_len + args.shared_prefix
    kw = dict(slots=args.batch, bucket=bucket, max_new_tokens=args.gen,
              segment=args.segment, n_host_chunks=args.host_kv_chunks,
              sampling=DL.SamplingConfig(temperature=args.temperature,
                                         top_k=args.top_k), par=par)
    if args.paged:
        from repro.runtime.paged import PagedServeEngine, SLOPagedServeEngine

        spill = args.spill_pages
        if args.kv_store and not spill:
            spill = 4 * args.n_pages if args.n_pages else 64  # restore target
        pkw = dict(prefill_chunk=args.prefill_chunk, page_size=args.page_size,
                   n_pages=args.n_pages, spill_pages=spill, **kw)
        if args.sched:
            engine = SLOPagedServeEngine(cfg, params, policy=args.sched,
                                         prefill_budget=args.prefill_budget,
                                         **pkw)
            name = (f"SLO scheduler (policy={args.sched}, page_size="
                    f"{engine.page_size}, prefill_budget="
                    f"{args.prefill_budget})")
            # QoS assignment: short prompts are the latency-sensitive tier
            # (priority 0, staggered arrivals); long ones ride best-effort
            med = int(np.median(lens))
            prompts = [DL.Request(
                tokens=tuple(p), arrival=i,
                priority=0 if lens[i] <= med else 1,
                itl_slo=8.0 if lens[i] <= med else float("inf"),
                tier="interactive" if lens[i] <= med else "batch")
                for i, p in enumerate(prompts)]
        else:
            engine = PagedServeEngine(cfg, params, **pkw)
            name = (f"paged pool (page_size={engine.page_size}, "
                    f"n_pages={engine.n_pages}, prefill_chunk={engine.cp}"
                    + (f", spill_pages={spill}" if spill else "") + ")")
        if args.kv_store:
            import os

            if os.path.exists(args.kv_store):
                n = engine.restore_kv_store(args.kv_store)
                print(f"[kv-store] restored {n} prefix pages from "
                      f"{args.kv_store}")
    elif args.blocking:
        engine = DL.BlockingServeEngine(cfg, params, **kw)
        name = "blocking baseline"
    else:
        engine = DL.ServeEngine(cfg, params, prefill_chunk=args.prefill_chunk,
                                **kw)
        name = f"fused scheduler (prefill_chunk={engine.cp})"
    t0 = time.perf_counter()
    outs = engine.generate(prompts, key=jax.random.PRNGKey(args.seed))
    dt = time.perf_counter() - t0
    total = sum(len(o) for o in outs)
    print(f"[{name}] {args.requests} requests (prompt {lens.min()}-"
          f"{lens.max()}{f' +{args.shared_prefix} shared' if args.shared_prefix else ''}) "
          f"over {args.batch} slots: {total} tokens in "
          f"{dt*1e3:.0f} ms ({total/dt:.1f} tok/s incl. compile)")
    steps = engine.last_stats["steps"][1:]  # drop the compile-bearing first
    refill = [s["ms"] for s in steps if s["prefilling"]]
    steady = [s["ms"] for s in steps if not s["prefilling"]]
    if refill and steady:
        print(f"  dispatch wall-clock: steady p50 {np.percentile(steady, 50):.2f} ms, "
              f"refill-active p95 {np.percentile(refill, 95):.2f} ms "
              f"({len(refill)}/{len(steps)} dispatches overlapped a refill)")
    if args.paged:
        st = engine.last_stats
        hit = st["prefix_hit_tokens"] / max(st["prompt_tokens"], 1)
        print(f"  paged pool: prefix hits {st['prefix_hit_tokens']}/"
              f"{st['prompt_tokens']} prompt tokens ({hit:.0%}), "
              f"{st['prefilled_tokens']} prefilled, "
              f"{st['cow_copies']} COW copies, peak occupancy "
              f"{st['pages_peak']}/{engine.n_pages} pages "
              f"({st['radix_pages']} retained in the radix tree)")
        if st.get("spill_pages"):
            print(f"  spill tier: {st['spilled_pages']}/{st['spill_pages']} "
                  f"host pages held, {st['spill_promotes']} promoted back "
                  f"on-device this run")
        if args.sched:
            pre = [r for r in st["requests"] if r["preemptions"]]
            print(f"  scheduler [{st['policy']}]: {st['preemptions']} "
                  f"preemptions ({len(pre)} requests), "
                  f"{st['prefill_pauses']} prefill pauses, "
                  f"{st['deferrals']} deferrals")
        if args.kv_store:
            n = engine.save_kv_store(args.kv_store)
            print(f"[kv-store] saved {n} prefix pages to {args.kv_store}")
    if args.trace_out and args.sched:
        # one-line per-request digest reconstructed from the trace alone
        summ = engine.telemetry.request_summaries()
        for r in sorted(summ):
            s = summ[r]
            print(f"  req {r}: ttft {s['ttft']} steps, itl p50/p95 "
                  f"{s['itl_p50']}/{s['itl_p95']}, queue wait "
                  f"{s['queue_wait']}, {s['n_emitted']} tokens, "
                  f"{s['preemptions']} preemptions, "
                  f"{s['prefix_hit_tokens']} prefix-hit")
    _export_telemetry(args, engine.telemetry)


def _mesh_engine_main(args, cfg, params, prompts):
    """--mesh/--replicas: sharded engine replicas behind the router."""
    import jax

    from repro.launch.mesh import serve_mesh
    from repro.launch.router import ReplicaRouter
    from repro.runtime import decode_loop as DL
    from repro.runtime.paged import PagedServeEngine

    data, model = (int(x) for x in args.mesh.split("x"))
    per, n = data * model, args.replicas
    devs = jax.devices()
    if len(devs) < per * n:
        raise SystemExit(f"--mesh {args.mesh} --replicas {n} needs "
                         f"{per * n} devices, have {len(devs)}")
    fault_plan = {}
    if args.fault_plan:
        from repro.launch.faults import parse_fault_plan
        fault_plan = parse_fault_plan(args.fault_plan)
        bad = [r for r in fault_plan if r >= n]
        if bad:
            raise SystemExit(f"--fault-plan names replicas {bad} but only "
                             f"{n} exist")
    kv_store = None
    if args.shared_kv_store:
        if not args.paged:
            raise SystemExit("--shared-kv-store needs --paged (the prefix "
                             "cache lives in the radix tree)")
        from repro.launch.kvstore import SharedKVStore
        kv_store = SharedKVStore(args.shared_kv_store)
    bucket = args.prompt_len + args.shared_prefix
    kw = dict(slots=args.batch, bucket=bucket, max_new_tokens=args.gen,
              segment=args.segment, n_host_chunks=args.host_kv_chunks,
              prefill_chunk=args.prefill_chunk,
              sampling=DL.SamplingConfig(temperature=args.temperature,
                                         top_k=args.top_k))
    if args.paged:
        spill = args.spill_pages
        if kv_store is not None and not spill:
            # restore lands in the spill tier; give it somewhere to land
            spill = 4 * args.n_pages if args.n_pages else 64
        kw.update(page_size=args.page_size, n_pages=args.n_pages,
                  spill_pages=spill)
    replicas = []
    for r in range(n):
        par = serve_mesh(data, model, devices=devs[r * per:(r + 1) * per])
        with par.mesh:
            eng = (PagedServeEngine if args.paged else DL.ServeEngine)(
                cfg, params, par=par, **kw)
        rep = _MeshReplica(eng, par)
        if r in fault_plan:
            from repro.launch.faults import FaultyReplica
            rep = FaultyReplica(rep, fault_plan[r], name=f"replica{r}")
        replicas.append(rep)
    router = ReplicaRouter(replicas, policy=args.router,
                           max_retries=args.retry,
                           dispatch_timeout=args.dispatch_timeout or None,
                           kv_store=kv_store)
    t0 = time.perf_counter()
    outs = router.generate(prompts)
    dt = time.perf_counter() - t0
    total = sum(len(o) for o in outs)
    st = router.last_stats
    print(f"[{n} x ({data} data x {model} model) mesh replicas, "
          f"router={args.router}] {len(prompts)} requests: {total} tokens "
          f"in {dt*1e3:.0f} ms ({total/dt:.1f} tok/s incl. compile)")
    for rs in st["per_replica"]:
        line = (f"  replica {rs['replica']}: {rs['requests']} requests")
        if "prompt_tokens" in rs:
            hit = rs.get("prefix_hit_tokens", 0)
            line += (f", {rs['prompt_tokens']} prompt tokens"
                     + (f", {hit} prefix-hit" if args.paged else ""))
        print(line)
    if args.router == "affine" and st["spilled"]:
        print(f"  {st['spilled']} requests spilled off their home replica")
    fo = st.get("failover")
    if fo and (fo["deaths"] or fo["retries"] or fo["timeouts"]):
        print(f"  failover: {fo['deaths']} deaths (dead={fo['dead']}), "
              f"{fo['retries']} retries, {fo['timeouts']} timeouts, "
              f"{fo['rehomed_requests']} requests re-homed "
              f"({fo['rehomed_sessions']} sessions), "
              f"{fo['recovered_prefix_tokens']} prefix tokens recovered "
              f"via the shared store ({fo['recovered_pages']} pages "
              f"restored), {fo['live']}/{n} replicas live")
    elif fo:
        print(f"  failover: clean run, {fo['live']}/{n} replicas live")
    if args.trace_out or args.metrics_out:
        tels = [router.telemetry]
        for r, rep in enumerate(replicas):
            # FaultyReplica.__getattr__ forwards to the wrapped replica
            tel = rep.engine.telemetry
            tel.replica = r  # label the replica's track group in the trace
            tels.append(tel)
        if kv_store is not None:
            tels.append(kv_store.telemetry)
        _export_telemetry(args, tels)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--host-kv-chunks", type=int, default=0,
                    help="FPDT-for-inference: stream KV from host in N chunks")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; > 0 samples at this temperature")
    ap.add_argument("--top-k", type=int, default=0,
                    help="restrict sampling to the k best tokens (0 = all)")
    ap.add_argument("--per-token", action="store_true",
                    help="legacy per-token dispatch loop instead of lax.scan")
    ap.add_argument("--engine", action="store_true",
                    help="continuous batching via the fused mixed-step "
                         "scheduler (ServeEngine) instead of one batch")
    ap.add_argument("--blocking", action="store_true",
                    help="with --engine: the stop-the-world refill baseline")
    ap.add_argument("--requests", type=int, default=8,
                    help="with --engine: queued prompts")
    ap.add_argument("--segment", type=int, default=8,
                    help="with --engine: mixed steps per dispatch")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="with --engine: prompt tokens streamed into a "
                         "refilling slot per mixed step (0 = auto)")
    ap.add_argument("--paged", action="store_true",
                    help="with --engine: slot-shared paged KV pool with "
                         "radix-tree prefix reuse (runtime/paged.py)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="with --paged: tokens per pool page")
    ap.add_argument("--n-pages", type=int, default=0,
                    help="with --paged: physical pages in the pool "
                         "(0 = dense-equivalent capacity)")
    ap.add_argument("--spill-pages", type=int, default=0,
                    help="with --paged: host-resident spill tier capacity "
                         "(evicted radix pages demote there instead of "
                         "dropping; 0 = no tier)")
    ap.add_argument("--kv-store", default="",
                    help="with --paged: persist the prefix cache at this "
                         "path — restored at startup when the file exists, "
                         "saved after the run (implies a spill tier)")
    ap.add_argument("--sched", default="", choices=["", "fifo", "slo"],
                    help="with --paged: SLO-aware admission "
                         "(SLOPagedServeEngine) — 'slo' preempts "
                         "lower-priority slots via page spill/publish, "
                         "'fifo' is the arrival-order baseline")
    ap.add_argument("--prefill-budget", type=int, default=0,
                    help="with --sched slo: prefill chunks a request may "
                         "burn before pausing while co-resident slots "
                         "decode (0 = unbounded)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="with --engine: prepend a common system prompt of "
                         "this many tokens to every request (radix hits)")
    ap.add_argument("--mesh", default="",
                    help="with --engine: shard each engine over an AxB "
                         "(data x model) device mesh, e.g. 1x4 or 2x4")
    ap.add_argument("--replicas", type=int, default=1,
                    help="with --mesh: engine replicas on disjoint device "
                         "slices behind the router")
    ap.add_argument("--router", default="affine", choices=["affine", "rr"],
                    help="with --replicas: session-affine dispatch (radix "
                         "locality survives routing) or round-robin")
    ap.add_argument("--fault-plan", default="",
                    help="with --replicas: deterministic fault injection, "
                         "';'-separated R:KIND@N[xC][~S] items (KIND in "
                         "raise/transient/hang), e.g. '1:raise@2' kills "
                         "replica 1 on its 3rd dispatch — the router "
                         "re-homes its work onto survivors")
    ap.add_argument("--retry", type=int, default=1,
                    help="with --replicas: dispatch retries before a "
                         "faulting replica is declared dead")
    ap.add_argument("--dispatch-timeout", type=float, default=0.0,
                    help="with --replicas: wall-clock seconds after which "
                         "a dispatch counts as a fault and its late "
                         "result is discarded (0 = no timeout)")
    ap.add_argument("--shared-kv-store", default="",
                    help="with --replicas + --paged: shared prefix-cache "
                         "directory (one npz per replica); on replica "
                         "death the dead replica's published cache "
                         "restores into survivors so re-homed requests "
                         "resume warm")
    ap.add_argument("--trace-out", default="",
                    help="with --engine: write the run's lifecycle spans "
                         "as Chrome trace-event JSON (open in Perfetto or "
                         "chrome://tracing; one process per engine/router, "
                         "one track per slot)")
    ap.add_argument("--metrics-out", default="",
                    help="with --engine: write the metrics registry as "
                         "Prometheus text exposition after the run")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.mesh and not args.engine:
        ap.error("--mesh requires --engine")
    if (args.trace_out or args.metrics_out) and not args.engine:
        ap.error("--trace-out/--metrics-out export engine telemetry; they "
                 "require --engine")
    if (args.fault_plan or args.shared_kv_store) and not args.mesh:
        ap.error("--fault-plan/--shared-kv-store act on the replica "
                 "router; they require --mesh (and --replicas > 1 to "
                 "have anywhere to fail over to)")
    if args.sched and not args.paged:
        ap.error("--sched requires --paged (preemption spills KV pages)")
    if args.sched and args.mesh:
        ap.error("--sched is single-engine for now; route QoS requests to "
                 "sharded replicas via launch/router.py instead")
    if args.mesh:
        try:
            data, model = (int(x) for x in args.mesh.split("x"))
        except ValueError:
            ap.error(f"--mesh must look like AxB, got {args.mesh!r}")
        import os

        # force enough fake CPU devices BEFORE jax import (train.py host8
        # pattern); a real accelerator fleet ignores this via its own flags
        if "--xla_force_host_platform_device_count" not in os.environ.get(
                "XLA_FLAGS", ""):
            os.environ["XLA_FLAGS"] = (
                os.environ.get("XLA_FLAGS", "") +
                f" --xla_force_host_platform_device_count="
                f"{data * model * args.replicas}").strip()
    if args.engine:
        return _engine_main(args)
    if args.host_kv_chunks and (args.prompt_len + args.gen) % args.host_kv_chunks:
        # models/serve.py would silently fall back to on-device attention
        ap.error(f"--host-kv-chunks {args.host_kv_chunks} must divide the "
                 f"cache length prompt-len+gen={args.prompt_len + args.gen}")

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, reduced
    from repro.core.parallel import ParallelContext
    from repro.models import serve as SV
    from repro.models import transformer as T
    from repro.runtime import decode_loop as DL

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    cfg = dataclasses.replace(cfg, remat="none")
    key = jax.random.PRNGKey(args.seed)
    params = T.init_params(cfg, key)
    max_len = args.prompt_len + args.gen
    b = args.batch

    if cfg.frontend == "audio_frames":
        batch = {"frame_embeds": jax.random.normal(key, (b, args.prompt_len, cfg.d_model),
                                                   jnp.dtype(cfg.param_dtype))}
    elif cfg.frontend == "vision_patches":
        batch = {
            "patch_embeds": jax.random.normal(key, (b, cfg.num_patches, cfg.d_model),
                                              jnp.dtype(cfg.param_dtype)),
            "tokens": jax.random.randint(key, (b, args.prompt_len - cfg.num_patches),
                                         0, cfg.vocab_size),
        }
    else:
        batch = {"tokens": jax.random.randint(key, (b, args.prompt_len), 0, cfg.vocab_size)}

    par = ParallelContext(mesh=None) if args.host_kv_chunks else None
    t0 = time.perf_counter()
    logits, cache = SV.prefill_step(cfg, par, params, batch, max_len=max_len)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    print(f"prefill {args.prompt_len} tokens x {b} seqs: {t_prefill*1e3:.1f} ms")

    sampling = DL.SamplingConfig(temperature=args.temperature, top_k=args.top_k)
    key, sub = jax.random.split(key)
    tok0 = DL.sample_token(logits[:, : cfg.vocab_size], sub, sampling)
    steps = args.gen - 1

    if args.per_token or cfg.frontend == "audio_frames":
        decode = jax.jit(
            lambda cache, inp, pos: SV.decode_step(
                cfg, par, params, cache, inp, pos, n_host_chunks=args.host_kv_chunks)
        )
        outs = [tok0[:, None]]
        t0 = time.perf_counter()
        for i in range(steps):
            inp = ({"tokens": outs[-1]} if cfg.frontend != "audio_frames"
                   else {"frame_embeds": jax.random.normal(key, (b, 1, cfg.d_model),
                                                           jnp.dtype(cfg.param_dtype))})
            logits, cache = decode(cache, inp, jnp.int32(args.prompt_len + i))
            key, sub = jax.random.split(key)
            outs.append(DL.sample_token(logits[:, : cfg.vocab_size], sub, sampling)[:, None])
        jax.block_until_ready(outs[-1])
        seqs = jnp.concatenate(outs, axis=1)
        mode = "per-token loop"
    else:
        decode = jax.jit(lambda cache, tok, pos, key: DL.decode_tokens(
            cfg, par, params, cache, tok, pos, num_steps=steps,
            n_host_chunks=args.host_kv_chunks, sampling=sampling, key=key))
        key, sub = jax.random.split(key)
        toks, _ = decode(cache, tok0[:, None], jnp.full((b,), args.prompt_len, jnp.int32), sub)
        jax.block_until_ready(toks)  # includes compile; timed run below
        t0 = time.perf_counter()
        toks, _ = decode(cache, tok0[:, None], jnp.full((b,), args.prompt_len, jnp.int32), sub)
        jax.block_until_ready(toks)
        seqs = jnp.concatenate([tok0[:, None], toks], axis=1)
        mode = "scan"
    dt = time.perf_counter() - t0
    print(f"decode [{mode}] {steps} steps x {b} seqs: {dt*1e3:.1f} ms "
          f"({dt / max(1, steps) * 1e3:.2f} ms/step, "
          f"{steps * b / dt:.1f} tok/s)")
    print("generated token ids (first seq):", seqs[0].tolist())


if __name__ == "__main__":
    main()
