"""Serving CLI: batched prefill + greedy decode.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
      --batch 4 --prompt-len 64 --gen 32 [--host-kv-chunks 8]
"""
from __future__ import annotations

import argparse
import dataclasses
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--host-kv-chunks", type=int, default=0,
                    help="FPDT-for-inference: stream KV from host in N chunks")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, reduced
    from repro.core.parallel import ParallelContext
    from repro.models import serve as SV
    from repro.models import transformer as T

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    cfg = dataclasses.replace(cfg, remat="none")
    key = jax.random.PRNGKey(args.seed)
    params = T.init_params(cfg, key)
    max_len = args.prompt_len + args.gen
    b = args.batch

    if cfg.frontend == "audio_frames":
        batch = {"frame_embeds": jax.random.normal(key, (b, args.prompt_len, cfg.d_model),
                                                   jnp.dtype(cfg.param_dtype))}
    elif cfg.frontend == "vision_patches":
        batch = {
            "patch_embeds": jax.random.normal(key, (b, cfg.num_patches, cfg.d_model),
                                              jnp.dtype(cfg.param_dtype)),
            "tokens": jax.random.randint(key, (b, args.prompt_len - cfg.num_patches),
                                         0, cfg.vocab_size),
        }
    else:
        batch = {"tokens": jax.random.randint(key, (b, args.prompt_len), 0, cfg.vocab_size)}

    par = ParallelContext(mesh=None) if args.host_kv_chunks else None
    t0 = time.perf_counter()
    logits, cache = SV.prefill_step(cfg, par, params, batch, max_len=max_len)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    print(f"prefill {args.prompt_len} tokens x {b} seqs: {t_prefill*1e3:.1f} ms")

    decode = jax.jit(
        lambda cache, tok, pos: SV.decode_step(
            cfg, par, params, cache, tok, pos, n_host_chunks=args.host_kv_chunks)
    )
    tok = jnp.argmax(logits[:, : cfg.vocab_size], -1)[:, None].astype(jnp.int32)
    outs = [tok]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        inp = ({"tokens": outs[-1]} if cfg.frontend != "audio_frames"
               else {"frame_embeds": jax.random.normal(key, (b, 1, cfg.d_model),
                                                       jnp.dtype(cfg.param_dtype))})
        logits, cache = decode(cache, inp, jnp.int32(args.prompt_len + i))
        outs.append(jnp.argmax(logits[:, : cfg.vocab_size], -1)[:, None].astype(jnp.int32))
    jax.block_until_ready(outs[-1])
    dt = time.perf_counter() - t0
    print(f"decode {args.gen - 1} steps x {b} seqs: {dt*1e3:.1f} ms "
          f"({dt / max(1, args.gen - 1) * 1e3:.2f} ms/step)")
    seqs = jnp.concatenate(outs, axis=1)
    print("generated token ids (first seq):", seqs[0].tolist())


if __name__ == "__main__":
    main()
