"""Session-affine replica router: the front door of the sharded serve tier.

One ``PagedServeEngine`` replica owns one mesh (its slice of the devices)
and one radix tree.  Prefix reuse therefore only pays off if requests that
*share* a prefix land on the *same* replica — round-robin over replicas
shreds a 97% radix hit rate into near-zero because each replica sees every
Nth request of a session.  The router restores locality:

* **affine** (default): each request hashes — by explicit session id when
  given, else by its leading ``prefix_tokens`` prompt tokens — to a home
  replica (``crc32``: deterministic across processes, unlike Python's
  seeded ``hash``).  Same session/system-prompt => same replica => radix
  hit.
* **spill**: affinity yields when the home replica is overloaded — if its
  queue is ``spill_margin`` deeper than the least-loaded replica's, the
  request goes to the latter instead (prefix miss traded for latency).
* **rr**: plain round-robin, kept as the measured locality baseline
  (``benchmarks/serve_bench.py::mesh_sweep``).

Replicas are anything with ``generate(prompts) -> List[List[int]]``
(engines, or subprocess/RPC proxies in a real deployment).  A replica
that raises is reported as :class:`ReplicaFailed` *naming the replica* —
a routing tier must say which backend died, not hang or blur the
traceback into the caller's.

Requests may be raw token sequences OR QoS-carrying
``runtime.decode_loop.Request`` objects (duck-typed on ``.tokens`` — the
router stays framework-free): routing hashes the token stream, and the
object itself passes through to the replica untouched, so priorities,
arrivals and deadlines survive the routing tier and land in a replica's
``SLOPagedServeEngine`` intact.
"""
from __future__ import annotations

import time
import zlib
from typing import Any, Dict, List, Optional, Sequence

__all__ = ["ReplicaFailed", "ReplicaRouter"]


def _tokens(prompt: Any) -> Sequence[int]:
    """The token stream of a request: ``Request``-likes carry it in
    ``.tokens``; anything else IS the stream."""
    return prompt.tokens if hasattr(prompt, "tokens") else prompt


class ReplicaFailed(RuntimeError):
    """A replica raised while serving its share of a workload."""

    def __init__(self, replica: int, cause: BaseException):
        self.replica = replica
        self.cause = cause
        super().__init__(f"replica {replica} failed: {cause!r}")


class ReplicaRouter:
    """Dispatch prompts across engine replicas, session-affine by default.

    Host-side and framework-free (plain ints and lists): routing must cost
    nothing next to a segment dispatch and must not trace/compile anything.
    """

    def __init__(self, replicas: Sequence[Any], *, policy: str = "affine",
                 prefix_tokens: int = 16, spill_margin: int = 0):
        if not replicas:
            raise ValueError("ReplicaRouter needs at least one replica")
        if policy not in ("affine", "rr"):
            raise ValueError(f"unknown router policy {policy!r} "
                             f"(expected 'affine' or 'rr')")
        self.replicas = list(replicas)
        self.policy = policy
        self.prefix_tokens = int(prefix_tokens)
        # 0 disables spilling (strict affinity); margin m spills a request
        # whose home queue is >= m deeper than the shallowest queue
        self.spill_margin = int(spill_margin)
        self._rr_next = 0
        self.depth = [0] * len(self.replicas)  # queued prompts per replica
        self.last_stats: Dict[str, Any] = {}

    # -- placement -------------------------------------------------------
    def home_of(self, prompt: Sequence[int],
                session: Optional[str] = None) -> int:
        """The affinity home: hash of the session id when given, else of
        the prompt's leading ``prefix_tokens`` tokens — requests sharing a
        system prompt share a home even without session bookkeeping."""
        if session is not None:
            key = session.encode()
        else:
            head = list(_tokens(prompt))[: self.prefix_tokens]
            key = b",".join(str(int(t)).encode() for t in head)
        return zlib.crc32(key) % len(self.replicas)

    def route(self, prompt: Sequence[int],
              session: Optional[str] = None) -> int:
        """Pick a replica for one request and account for its queue slot."""
        if self.policy == "rr":
            r = self._rr_next
            self._rr_next = (r + 1) % len(self.replicas)
            self.depth[r] += 1
            return r
        home = self.home_of(prompt, session)
        r = home
        if self.spill_margin > 0:
            least = min(range(len(self.replicas)), key=self.depth.__getitem__)
            if self.depth[home] - self.depth[least] >= self.spill_margin:
                r = least
        self.depth[r] += 1
        return r

    # -- dispatch --------------------------------------------------------
    def generate(self, prompts: Sequence[Sequence[int]],
                 sessions: Optional[Sequence[Optional[str]]] = None,
                 ) -> List[List[int]]:
        """Route every prompt, run each replica over its share, and merge
        the outputs back into request order.  Raises :class:`ReplicaFailed`
        if any replica raises."""
        if sessions is not None and len(sessions) != len(prompts):
            raise ValueError("sessions must align 1:1 with prompts")
        t0 = time.perf_counter()
        assigned: List[List[int]] = [[] for _ in self.replicas]  # request idx
        spilled = 0
        for i, p in enumerate(prompts):
            sess = sessions[i] if sessions is not None else None
            r = self.route(p, sess)
            if self.policy == "affine" and r != self.home_of(p, sess):
                spilled += 1
            assigned[r].append(i)

        outs: List[Optional[List[int]]] = [None] * len(prompts)
        per_replica: List[Dict[str, Any]] = []
        for r, idxs in enumerate(assigned):
            stats: Dict[str, Any] = {"replica": r, "requests": len(idxs)}
            if idxs:
                try:
                    got = self.replicas[r].generate([prompts[i] for i in idxs])
                except Exception as e:
                    # every assignment was accounted in route(); replicas
                    # after r never reach their own decrement, so drain the
                    # whole undispatched tail here — a failed workload must
                    # not leave phantom depth that skews future spills
                    for r2 in range(r, len(assigned)):
                        self.depth[r2] -= len(assigned[r2])
                    raise ReplicaFailed(r, e) from e
                self.depth[r] -= len(idxs)
                for i, o in zip(idxs, got):
                    outs[i] = o
                eng = getattr(self.replicas[r], "last_stats", None) or {}
                for k in ("prompt_tokens", "prefix_hit_tokens",
                          "prefilled_tokens", "dispatches"):
                    if k in eng:
                        stats[k] = eng[k]
            per_replica.append(stats)

        self.last_stats = {
            "policy": self.policy, "replicas": len(self.replicas),
            "requests": len(prompts), "spilled": spilled,
            "per_replica": per_replica, "s": time.perf_counter() - t0,
        }
        return [o if o is not None else [] for o in outs]
