"""Session-affine replica router: the front door of the sharded serve tier.

One ``PagedServeEngine`` replica owns one mesh (its slice of the devices)
and one radix tree.  Prefix reuse therefore only pays off if requests that
*share* a prefix land on the *same* replica — round-robin over replicas
shreds a 97% radix hit rate into near-zero because each replica sees every
Nth request of a session.  The router restores locality:

* **affine** (default): each request hashes — by explicit session id when
  given, else by its leading ``prefix_tokens`` prompt tokens — to a home
  replica via rendezvous (highest-random-weight) hashing over the *live*
  replica set (``crc32``: deterministic across processes, unlike Python's
  seeded ``hash``).  Same session/system-prompt => same replica => radix
  hit; and when a replica dies, *only its keys move* — survivors keep
  their radix locality, which mod-hashing would reshuffle wholesale.
* **spill**: affinity yields when the home replica is overloaded — if its
  queue is ``spill_margin`` deeper than the least-loaded replica's, the
  request goes to the latter instead (prefix miss traded for latency).
* **rr**: plain round-robin over live replicas, kept as the measured
  locality baseline (``benchmarks/serve_bench.py::mesh_sweep``).

Replicas are anything with ``generate(prompts) -> List[List[int]]``
(engines, or subprocess/RPC proxies in a real deployment).

Failover (default on)
---------------------
Each replica carries a health state driven purely by dispatch outcome::

    healthy ──fault──▶ suspect ──retries exhausted──▶ dead
       ▲                  │                             │
       └────success───────┘                             └──rejoin()──▶ healthy

A faulting dispatch (raise, short output, or wall-clock past
``dispatch_timeout`` — the late result is discarded) is retried up to
``max_retries`` times with capped exponential backoff, so transient
faults never trigger re-homing.  When retries exhaust, the replica is
dead: its completed outputs from earlier dispatches are kept, its
in-flight batch re-homes onto survivors (rendezvous hashing moves only
the dead replica's hash range), and — given a shared ``kv_store``
(:class:`launch.kvstore.SharedKVStore`) — the dead replica's published
prefix cache restores into the survivors first, so re-homed requests
resume with ``prefix_hit_tokens > 0`` instead of a cold prefill.  The
router degrades to any number >= 1 of live replicas with a one-shot
warning and full accounting in ``last_stats["failover"]``; only when the
*last* replica dies does :class:`ReplicaFailed` escape.  ``rejoin(r)``
re-admits a recovered replica (its keys move back, and its own published
cache restores into it).  ``failover=False`` restores the legacy
contract: first replica fault raises :class:`ReplicaFailed` immediately.

Either way the router never silently drops work: a request that ends the
call without an output raises :class:`IncompleteGeneration` naming the
missing indices — an empty list is a *generation*, not an error code.

Requests may be raw token sequences OR QoS-carrying
``runtime.decode_loop.Request`` objects (duck-typed on ``.tokens`` — the
router stays framework-free): routing hashes the token stream (or the
request's own ``.session``), and the object itself passes through to the
replica untouched — across re-homing too — so priorities, arrivals and
deadlines survive the routing tier and land in a replica's
``SLOPagedServeEngine`` intact.
"""
from __future__ import annotations

import time
import warnings
import zlib
from typing import Any, Dict, List, Optional, Sequence

from repro.runtime import telemetry as TM

__all__ = ["AllReplicasDead", "IncompleteGeneration", "ReplicaFailed",
           "ReplicaRouter"]

# per-replica dispatch stats the router aggregates across dispatches
_ENGINE_STAT_KEYS = ("prompt_tokens", "prefix_hit_tokens",
                     "prefilled_tokens", "dispatches")


def _tokens(prompt: Any) -> Sequence[int]:
    """The token stream of a request: ``Request``-likes carry it in
    ``.tokens``; anything else IS the stream."""
    return prompt.tokens if hasattr(prompt, "tokens") else prompt


def _rendezvous_score(key_crc: int, r: int) -> int:
    """Per-(key, replica) rendezvous weight.  crc32 alone is unusable
    here: it is GF(2)-linear, so ``crc32(key + suffix_r)`` differs across
    replicas by a key-independent XOR and whole key populations collapse
    onto one replica.  A multiplicative mix (the standard 32-bit hash
    finalizer) breaks the linearity while staying deterministic across
    processes — no seeded ``hash()``."""
    x = (key_crc ^ (0x9E3779B9 * (r + 1))) & 0xFFFFFFFF
    x = ((x ^ (x >> 16)) * 0x45D9F3B) & 0xFFFFFFFF
    x = ((x ^ (x >> 16)) * 0x45D9F3B) & 0xFFFFFFFF
    return x ^ (x >> 16)


class ReplicaFailed(RuntimeError):
    """A replica raised while serving its share of a workload."""

    def __init__(self, replica: int, cause: BaseException):
        self.replica = replica
        self.cause = cause
        super().__init__(f"replica {replica} failed: {cause!r}")


class AllReplicasDead(ReplicaFailed):
    """Every replica is dead — failover has nowhere left to re-home."""

    def __init__(self, replica: int, cause: BaseException):
        super().__init__(replica, cause)
        self.args = (f"all replicas dead (last: replica {replica}: "
                     f"{cause!r})",)


class IncompleteGeneration(RuntimeError):
    """Requests finished the routing pass without an output.

    The legacy behaviour returned ``[]`` for them — indistinguishable
    from a genuine empty generation, i.e. silent data loss.  Now the
    missing request indices are named and the caller decides."""

    def __init__(self, missing: Sequence[int], total: int):
        self.missing = list(missing)
        self.total = total
        super().__init__(
            f"{len(self.missing)}/{total} requests have no output "
            f"(indices {self.missing[:8]}{'...' if len(self.missing) > 8 else ''})")


class _DispatchTimeout(RuntimeError):
    """Internal: a dispatch completed after ``dispatch_timeout`` —
    treated as a fault, its (late) result discarded."""

    def __init__(self, elapsed: float, timeout: float):
        super().__init__(f"dispatch took {elapsed:.3f}s > "
                         f"timeout {timeout:.3f}s; result discarded")


class _ShortOutput(RuntimeError):
    """Internal: a replica returned fewer/more outputs than requests —
    a broken replica, handled like any other dispatch fault."""

    def __init__(self, got: int, want: int):
        super().__init__(f"replica returned {got} outputs for {want} "
                         f"requests")


class ReplicaRouter:
    """Dispatch prompts across engine replicas, session-affine by default.

    Host-side and framework-free (plain ints and lists): routing must cost
    nothing next to a segment dispatch and must not trace/compile anything.
    """

    HEALTHY, SUSPECT, DEAD = "healthy", "suspect", "dead"

    def __init__(self, replicas: Sequence[Any], *, policy: str = "affine",
                 prefix_tokens: int = 16, spill_margin: int = 0,
                 failover: bool = True, max_retries: int = 1,
                 backoff_s: float = 0.0, max_backoff_s: float = 0.1,
                 dispatch_timeout: Optional[float] = None,
                 kv_store: Optional[Any] = None, warn=None):
        if not replicas:
            raise ValueError("ReplicaRouter needs at least one replica")
        if policy not in ("affine", "rr"):
            raise ValueError(f"unknown router policy {policy!r} "
                             f"(expected 'affine' or 'rr')")
        self.replicas = list(replicas)
        self.policy = policy
        self.prefix_tokens = int(prefix_tokens)
        # 0 disables spilling (strict affinity); margin m spills a request
        # whose home queue is >= m deeper than the shallowest queue
        self.spill_margin = int(spill_margin)
        self.failover = bool(failover)
        self.max_retries = int(max_retries)
        self.backoff_s = float(backoff_s)
        self.max_backoff_s = float(max_backoff_s)
        self.dispatch_timeout = dispatch_timeout
        self.kv_store = kv_store
        self._warn = warn if warn is not None else (
            lambda msg: warnings.warn(msg, RuntimeWarning, stacklevel=3))
        self._warned_degraded = False
        self._rr_next = 0
        self.depth = [0] * len(self.replicas)  # queued prompts per replica
        self.health = [self.HEALTHY] * len(self.replicas)
        self.last_cause: List[Optional[BaseException]] = \
            [None] * len(self.replicas)
        # the router's own telemetry (stdlib-only — stays framework-free);
        # its step clock is the dispatch sequence number
        self.telemetry = TM.Telemetry(component="router")
        self.last_stats: Dict[str, Any] = self.telemetry.stats_view()
        self._dispatch_seq = 0
        # lifetime counters, cumulative across generate() calls (deaths
        # survive a workload); mirrored as registry counters
        # router_deaths/router_retries/router_timeouts.  Per-call deltas
        # live in last_stats["failover"] — semantics pinned by
        # tests/test_telemetry.py::test_failover_per_call_vs_lifetime
        self.deaths = 0
        self.retries = 0
        self.timeouts = 0

    # -- health ----------------------------------------------------------
    def live(self) -> List[int]:
        return [r for r in range(len(self.replicas))
                if self.health[r] != self.DEAD]

    def rejoin(self, r: int) -> int:
        """Re-admit a recovered replica: healthy again, its rendezvous
        keys route back to it, and (with a shared store) its own
        published prefix cache restores into it so it rejoins warm.
        Returns pages restored (0 without a store)."""
        self.health[r] = self.HEALTHY
        self.last_cause[r] = None
        restored = 0
        if self.kv_store is not None:
            restored = self.kv_store.restore_self(r, self.replicas[r])
        self.telemetry.event("router.rejoin", replica=r,
                             step=self._dispatch_seq, pages=restored)
        return restored

    # -- placement -------------------------------------------------------
    def _key(self, prompt: Sequence[int], session: Optional[str]) -> bytes:
        if session is None:  # QoS Request objects carry their own session
            session = getattr(prompt, "session", None)
        if session is not None:
            return session.encode()
        head = list(_tokens(prompt))[: self.prefix_tokens]
        return b",".join(str(int(t)).encode() for t in head)

    def home_of(self, prompt: Sequence[int],
                session: Optional[str] = None) -> int:
        """The affinity home: rendezvous hash of the session id (or the
        prompt's leading ``prefix_tokens`` tokens) over the live replica
        set — requests sharing a system prompt share a home even without
        session bookkeeping, and a dead replica moves *only its own*
        keys (every live replica keeps its rank for every other key)."""
        kc = zlib.crc32(self._key(prompt, session))
        live = self.live()
        if not live:
            raise AllReplicasDead(
                0, RuntimeError("no live replicas to route to"))
        return max(live, key=lambda r: _rendezvous_score(kc, r))

    def route(self, prompt: Sequence[int],
              session: Optional[str] = None) -> int:
        """Pick a live replica for one request and account for its queue
        slot."""
        live = self.live()
        if not live:
            raise AllReplicasDead(
                0, RuntimeError("no live replicas to route to"))
        if self.policy == "rr":
            r = live[self._rr_next % len(live)]
            self._rr_next += 1
            self.depth[r] += 1
            return r
        home = self.home_of(prompt, session)
        r = home
        if self.spill_margin > 0:
            least = min(live, key=self.depth.__getitem__)
            if self.depth[home] - self.depth[least] >= self.spill_margin:
                r = least
        self.depth[r] += 1
        return r

    # -- dispatch --------------------------------------------------------
    def _dispatch_once(self, r: int, batch: List[Any]) -> List[Any]:
        """One guarded dispatch: raises on replica exception, on a
        short/long output list, and on wall-clock past the timeout (the
        late result is discarded — its replica may be wedged)."""
        self._dispatch_seq += 1
        seq = self._dispatch_seq
        t0 = time.perf_counter()
        got = self.replicas[r].generate(batch)
        elapsed = time.perf_counter() - t0
        self.telemetry.event("router.dispatch", replica=r, step=seq,
                             n=len(batch), dur_ms=elapsed * 1e3)
        if (self.dispatch_timeout is not None
                and elapsed > self.dispatch_timeout):
            self.timeouts += 1
            self.telemetry.registry.counter("router_timeouts").inc()
            self.telemetry.event("router.timeout", replica=r, step=seq)
            raise _DispatchTimeout(elapsed, self.dispatch_timeout)
        if got is None or len(got) != len(batch):
            raise _ShortOutput(0 if got is None else len(got), len(batch))
        return got

    def _dispatch_with_retry(self, r: int,
                             batch: List[Any]) -> Optional[List[Any]]:
        """Dispatch with the health state machine: fault => suspect +
        bounded retry (capped exponential backoff); success => healthy;
        retries exhausted => dead, returns None (caller re-homes)."""
        delay = self.backoff_s
        for attempt in range(self.max_retries + 1):
            try:
                got = self._dispatch_once(r, batch)
            except Exception as e:
                self.last_cause[r] = e
                self.health[r] = self.SUSPECT
                if attempt < self.max_retries:
                    self.retries += 1
                    self.telemetry.registry.counter("router_retries").inc()
                    self.telemetry.event("router.retry", replica=r,
                                         step=self._dispatch_seq,
                                         attempt=attempt + 1)
                    if delay > 0:
                        time.sleep(min(delay, self.max_backoff_s))
                        delay = min(delay * 2 or self.max_backoff_s,
                                    self.max_backoff_s)
                    continue
                self.health[r] = self.DEAD
                self.deaths += 1
                self.telemetry.registry.counter("router_deaths").inc()
                self.telemetry.event("router.death", replica=r,
                                     step=self._dispatch_seq)
                return None
            self.health[r] = self.HEALTHY
            return got
        return None  # unreachable

    def _accumulate_engine_stats(self, r: int,
                                 per_replica: Dict[str, Any]) -> int:
        """Fold the replica's last-dispatch stats into its per-replica
        row (a replica may be dispatched several times per workload once
        re-homed batches land on it).  Returns the dispatch's
        ``prefix_hit_tokens`` so re-home dispatches can attribute
        recovery."""
        eng = getattr(self.replicas[r], "last_stats", None) or {}
        for k in _ENGINE_STAT_KEYS:
            if k in eng:
                per_replica[k] = per_replica.get(k, 0) + eng[k]
        return int(eng.get("prefix_hit_tokens", 0))

    def _on_death(self, r: int) -> int:
        """Permanent death bookkeeping: publish the dead replica's prefix
        cache (the engine is crash-consistent after a raised generate)
        and restore it into the survivors, so re-homed requests promote
        their context instead of recomputing it.  Returns pages restored
        into survivors (0 without a shared store)."""
        if not self._warned_degraded:
            self._warned_degraded = True
            self._warn(
                f"replica {r} died ({self.last_cause[r]!r}); degrading to "
                f"{len(self.live())} live replica(s) and re-homing its "
                f"sessions (further deaths logged in last_stats only)")
        if self.kv_store is None:
            return 0
        self.kv_store.publish(r, self.replicas[r])
        pages = self.kv_store.recover(
            r, [self.replicas[s] for s in self.live()])
        self.telemetry.event("router.recover", replica=r,
                             step=self._dispatch_seq, pages=pages,
                             survivors=len(self.live()))
        return pages

    def generate(self, prompts: Sequence[Sequence[int]],
                 sessions: Optional[Sequence[Optional[str]]] = None,
                 ) -> List[List[int]]:
        """Route every prompt, run each replica over its share, and merge
        the outputs back into request order.

        With ``failover`` (default): replica deaths re-home work onto
        survivors; raises :class:`AllReplicasDead` only when no replica
        is left, and :class:`IncompleteGeneration` if any request would
        otherwise silently miss an output.  With ``failover=False``:
        legacy contract, first fault raises :class:`ReplicaFailed`."""
        if sessions is not None and len(sessions) != len(prompts):
            raise ValueError("sessions must align 1:1 with prompts")
        if sessions is None:
            # QoS Request objects may carry their own session affinity
            sessions = [getattr(p, "session", None) for p in prompts]
        t0 = time.perf_counter()
        R = len(self.replicas)
        assigned: List[List[int]] = [[] for _ in range(R)]  # request idx
        spilled = 0
        for i, p in enumerate(prompts):
            r = self.route(p, sessions[i])
            if self.policy == "affine" and r != self.home_of(p, sessions[i]):
                spilled += 1
            assigned[r].append(i)

        outs: List[Optional[List[int]]] = [None] * len(prompts)
        per_replica: List[Dict[str, Any]] = [
            {"replica": r, "requests": len(assigned[r])} for r in range(R)]

        if not self.failover:
            self._generate_legacy(prompts, assigned, outs, per_replica)
        else:
            self._generate_failover(prompts, sessions, assigned, outs,
                                    per_replica, t0)

        missing = [i for i, o in enumerate(outs) if o is None]
        if missing:
            raise IncompleteGeneration(missing, len(prompts))
        self.last_stats.update({
            "policy": self.policy, "replicas": R,
            "requests": len(prompts), "spilled": spilled,
            "per_replica": per_replica, "s": time.perf_counter() - t0,
        })
        return list(outs)

    # the pre-failover dispatch loop, kept verbatim behind failover=False:
    # one dispatch per replica, first fault aborts the workload
    def _generate_legacy(self, prompts, assigned, outs, per_replica) -> None:
        for r, idxs in enumerate(assigned):
            if not idxs:
                continue
            try:
                got = self.replicas[r].generate([prompts[i] for i in idxs])
            except Exception as e:
                # every assignment was accounted in route(); replicas
                # after r never reach their own decrement, so drain the
                # whole undispatched tail here — a failed workload must
                # not leave phantom depth that skews future spills
                for r2 in range(r, len(assigned)):
                    self.depth[r2] -= len(assigned[r2])
                self.last_cause[r] = e
                raise ReplicaFailed(r, e) from e
            self.depth[r] -= len(idxs)
            if len(got) != len(idxs):
                raise ReplicaFailed(r, _ShortOutput(len(got), len(idxs)))
            for i, o in zip(idxs, got):
                outs[i] = o
            self._accumulate_engine_stats(r, per_replica[r])
        self.last_stats = self.telemetry.stats_view()

    def _generate_failover(self, prompts, sessions, assigned, outs,
                           per_replica, t0) -> None:
        R = len(self.replicas)
        deaths0, retries0, timeouts0 = self.deaths, self.retries, self.timeouts
        rehomed_idx: List[int] = []
        rehomed_sessions = set()
        recovered_prefix = 0
        recovered_pages = 0
        # original shares and re-homed work are kept in separate queues:
        # a re-homed batch dispatches on its own, so its prefix hits are
        # attributable to recovery, not to the survivor's original share
        queues: List[List[int]] = [list(idxs) for idxs in assigned]
        requeues: List[List[int]] = [[] for _ in range(R)]

        def rehome(idxs: List[int], dead: int) -> None:
            self.depth[dead] -= len(idxs)  # route() re-accounts below
            for i in idxs:
                r2 = self.route(prompts[i], sessions[i])
                requeues[r2].append(i)
                rehomed_idx.append(i)
                self.telemetry.event("router.rehome", request=i,
                                     session=sessions[i], replica=r2,
                                     step=self._dispatch_seq, dead=dead)
                if sessions[i] is not None:
                    rehomed_sessions.add(sessions[i])

        def drain_all_depth(dying_batch: List[int], r: int) -> None:
            # failover has nowhere left to go: drop every queued slot so
            # phantom depth doesn't skew a future workload's spills
            self.depth[r] -= len(dying_batch)
            for r2 in range(R):
                self.depth[r2] -= len(queues[r2]) + len(requeues[r2])
                queues[r2], requeues[r2] = [], []

        while True:
            # work queued on a replica that died serving a *different*
            # batch would otherwise be orphaned — re-home it first
            for r in range(R):
                if self.health[r] == self.DEAD and (queues[r] or requeues[r]):
                    idxs = queues[r] + requeues[r]
                    queues[r], requeues[r] = [], []
                    rehome(idxs, r)
            work = [(r, False) for r in self.live() if queues[r]] + \
                   [(r, True) for r in self.live() if requeues[r]]
            if not work:
                break
            for r, is_rehome in work:
                src = requeues[r] if is_rehome else queues[r]
                if not src or self.health[r] == self.DEAD:
                    continue  # died earlier in this pass; next pass re-homes
                idxs, src[:] = list(src), []
                got = self._dispatch_with_retry(
                    r, [prompts[i] for i in idxs])
                if got is None:  # permanent death
                    recovered_pages += self._on_death(r)
                    if not self.live():
                        drain_all_depth(idxs, r)
                        raise AllReplicasDead(r, self.last_cause[r]) \
                            from self.last_cause[r]
                    rehome(idxs, r)
                    continue
                self.depth[r] -= len(idxs)
                for i, o in zip(idxs, got):
                    outs[i] = o
                hit = self._accumulate_engine_stats(r, per_replica[r])
                if is_rehome:
                    recovered_prefix += hit
                if self.kv_store is not None:
                    self.kv_store.publish(r, self.replicas[r])

        # per-call deltas (counters reset to this workload's contribution)
        # PLUS an explicit lifetime view: the registry's
        # router_deaths/retries/timeouts counters accumulate forever,
        # the failover_* gauges hold the last call's deltas
        fo = {
            "deaths": self.deaths - deaths0,
            "dead": [r for r in range(R) if self.health[r] == self.DEAD],
            "retries": self.retries - retries0,
            "timeouts": self.timeouts - timeouts0,
            "rehomed_requests": len(rehomed_idx),
            "rehomed_sessions": len(rehomed_sessions),
            "recovered_prefix_tokens": recovered_prefix,
            "recovered_pages": recovered_pages,
            "health": list(self.health),
            "live": len(self.live()),
            "lifetime": {"deaths": self.deaths, "retries": self.retries,
                         "timeouts": self.timeouts},
        }
        for k in ("deaths", "retries", "timeouts", "rehomed_requests",
                  "rehomed_sessions", "recovered_prefix_tokens",
                  "recovered_pages"):
            self.telemetry.registry.gauge(f"failover_{k}").set(fo[k])
        self.last_stats = self.telemetry.stats_view()
        self.last_stats["failover"] = fo
