"""Sharding policies: parameters, optimizer state, inputs, caches.

ZeRO-3 equivalence: every weight is sharded across the data axes (pod+data),
so parameters, gradients, and optimizer moments never materialize
unsharded; GSPMD all-gathers weights at use and reduce-scatters gradients.
Expert weights are additionally expert-sharded over the model axis (EP);
embedding/head tables are vocab-sharded over the model axis
(vocab-parallel, Megatron-style — tables are too large to all-gather).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ModelConfig
from repro.core.parallel import ParallelContext


def _divisible(n: int, parts: int) -> bool:
    return parts > 0 and n % parts == 0


def param_spec(cfg: ModelConfig, par: ParallelContext, path_names, leaf) -> P:
    names = path_names
    dp = par.dp_axes
    dp_n = par.dp
    sp = par.sp_axis
    sp_n = par.sp
    shape = leaf.shape
    if not shape:
        return P()
    # embedding tables: vocab over DATA (ZeRO), d over MODEL — lookups of
    # sequence-sharded ids then stay local (a vocab-over-model table psums a
    # full fp32 [b,S,d] per lookup and scatter-adds its gradient: measured
    # ~1.5 GiB/device/step on llama3.2-1b, §Perf B2)
    if "embed" in names:
        return P(dp if _divisible(shape[0], dp_n) else None,
                 sp if _divisible(shape[1], sp_n) else None)
    if "head" in names:  # [d, V]: d over model, V over data
        return P(sp if _divisible(shape[0], sp_n) else None,
                 dp if _divisible(shape[1], dp_n) else None)
    # MoE expert stacks: [(cycles,) e, d, ff] -> expert dim over model
    if any(n in ("moe",) for n in names) and leaf.ndim >= 3:
        lead = (None,) * (leaf.ndim - 3)
        e_ax, d_ax = leaf.ndim - 3, leaf.ndim - 2
        return P(*lead,
                 sp if _divisible(shape[e_ax], sp_n) else None,
                 dp if _divisible(shape[d_ax], dp_n) else None,
                 None)
    # generic: shard the first dp-divisible dim (skip tiny leading stack dims)
    spec = [None] * leaf.ndim
    for ax in range(leaf.ndim):
        if names and names[0] in ("cycles",) and ax == 0:
            continue  # layer-stack axis stays unsharded (scan operand)
        if _divisible(shape[ax], dp_n) and shape[ax] >= dp_n * 4:
            spec[ax] = dp
            break
    return P(*spec)


# ZeRO-1 mode measured WORSE (X 682->1357 ms on llama3.2-1b train_4k, §Perf
# B3 refuted): with replicated weights GSPMD materializes full-size gradient
# all-reduces before the sharding constraint can turn them into
# reduce-scatters.  Keep ZeRO-3 (threshold 0 disables replication).
REPLICATE_SMALL_GB = 0.0


def params_total_gb(params_shape) -> float:
    return sum(l.size * jnp.dtype(l.dtype).itemsize
               for l in jax.tree.leaves(params_shape)) / 2**30


def param_shardings(cfg: ModelConfig, par: ParallelContext, params_shape: Any):
    """NamedShardings matching an eval_shape'd params pytree.

    ZeRO policy (§Perf B3): models whose weights fit comfortably replicated
    (< REPLICATE_SMALL_GB) use ZeRO-1 — weights replicated (no per-layer
    all-gather x3 passes), optimizer state sharded, gradients
    reduce-scattered, one updated-params all-gather per step.  Larger models
    keep full ZeRO-3 sharding.  Embedding tables stay sharded always."""
    small = params_total_gb(params_shape) <= REPLICATE_SMALL_GB

    def one(path, leaf):
        names = [str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path]
        if small and "embed" not in names and "head" not in names:
            return NamedSharding(par.mesh, P())
        return NamedSharding(par.mesh, param_spec(cfg, par, names, leaf))

    return jax.tree_util.tree_map_with_path(one, params_shape)


def opt_moment_shardings(cfg: ModelConfig, par: ParallelContext, params_shape: Any):
    """m/v are ALWAYS sharded (even in ZeRO-1 mode) via the generic rule."""

    def one(path, leaf):
        names = [str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path]
        return NamedSharding(par.mesh, param_spec(cfg, par, names, leaf))

    return jax.tree_util.tree_map_with_path(one, params_shape)


def opt_shardings(cfg: ModelConfig, par: ParallelContext, opt_shape: Any,
                  params_shape: Any):
    """Optimizer m/v use the always-sharded rule; step replicated."""
    from repro.optim.adamw import OptState

    msh = opt_moment_shardings(cfg, par, params_shape)
    return OptState(
        step=NamedSharding(par.mesh, P()),
        m=msh,
        v=msh,
    )


def batch_shardings(cfg: ModelConfig, par: ParallelContext, batch_shape: Any):
    """Tokens/labels [B, S] over (dp, model); embeds [B, S, d] likewise.
    Dims that don't divide their axes stay unsharded (e.g. batch=1 decode)."""

    def one(path, leaf):
        if leaf.ndim == 0:
            return NamedSharding(par.mesh, P())
        spec = [None] * leaf.ndim
        if _divisible(leaf.shape[0], par.dp):
            spec[0] = par.dp_axes
        if leaf.ndim >= 2 and _divisible(leaf.shape[1], par.sp) and leaf.shape[1] >= par.sp:
            spec[1] = par.sp_axis
        return NamedSharding(par.mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, batch_shape)
