"""Production meshes (assignment-mandated shapes) + mesh version compat.

A FUNCTION, not a module constant: importing this module never touches jax
device state.

``jax.sharding.AxisType`` (explicit axis-type meshes) only exists on newer
jax releases; on older installs meshes are built without explicit axis
types — every axis there is Auto-typed already, so semantics are identical.
All mesh construction in the repo goes through :func:`make_compat_mesh`.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5-ish
    from jax.sharding import AxisType
except ImportError:  # older jax: all mesh axes are implicitly Auto
    AxisType = None


def _axis_type_kwargs(n_axes: int) -> dict:
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}


def make_compat_mesh(shape, axes, devices=None):
    """Version-portable mesh constructor (explicit Auto axis types when the
    installed jax supports them, plain mesh otherwise)."""
    if devices is None:
        return jax.make_mesh(tuple(shape), tuple(axes), **_axis_type_kwargs(len(axes)))
    import numpy as np

    from jax.sharding import Mesh

    devs = np.asarray(devices).reshape(shape)
    return Mesh(devs, tuple(axes), **_axis_type_kwargs(len(axes)))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = 512 if multi_pod else 256
    devices = jax.devices()[:ndev]
    return make_compat_mesh(shape, axes, devices)


def dp_axes_of(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def serve_mesh(data: int, model: int, devices=None):
    """One serve-replica mesh: ``(data, model)`` with the repo's canonical
    axis names, wrapped in a ready ``ParallelContext`` (``dp_axes`` from
    :func:`dp_axes_of`, so the replica/data split follows the same rule the
    trainer uses).  ``models/serve.py::cache_shardings`` then shards the
    paged pool's kv heads over ``model`` and per-slot state over ``data``;
    multiple replicas each call this with their own device slice and sit
    behind ``launch/router.py``."""
    need = data * model
    have = len(jax.devices() if devices is None else devices)
    if have < need:
        raise ValueError(
            f"serve_mesh({data}, {model}) needs {need} devices but only "
            f"{have} are visible (set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={need} before importing "
            f"jax to fake them on CPU)")
    mesh = make_compat_mesh((data, model), ("data", "model"), devices)
    from repro.core.parallel import ParallelContext

    return ParallelContext.for_mesh(mesh)
