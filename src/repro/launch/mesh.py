"""Production meshes (assignment-mandated shapes).

A FUNCTION, not a module constant: importing this module never touches jax
device state.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = 512 if multi_pod else 256
    devices = jax.devices()[:ndev]
    import numpy as np

    devs = np.asarray(devices).reshape(shape)
    from jax.sharding import Mesh

    return Mesh(devs, axes, axis_types=(AxisType.Auto,) * len(axes))


def dp_axes_of(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
