"""Pure-jnp oracle for the flash-attention kernels.

Exact fp32 attention over one (q-chunk, kv-chunk) pair with global position
offsets (for FPDT chunk scheduling) and optional carry-in state, returning the
same ``(acc, m, l)`` unnormalized online-softmax state as the Pallas kernel.

Layout: q [b, hq, sq, d], k/v [b, hkv, sk, d]; GQA via head-group mapping.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.online_softmax import NEG_INF, SoftmaxState, finalize, merge, zero_state


def _expand_kv(x: jnp.ndarray, hq: int) -> jnp.ndarray:
    hkv = x.shape[1]
    if hkv == hq:
        return x
    assert hq % hkv == 0
    return jnp.repeat(x, hq // hkv, axis=1)


def attend_chunk(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    k_offset: int = 0,
    sm_scale: float | None = None,
    carry: SoftmaxState | None = None,
) -> SoftmaxState:
    """Online-softmax state after attending q (at q_offset) to k/v (at k_offset)."""
    b, hq, sq, d = q.shape
    k = _expand_kv(k, hq)
    v = _expand_kv(v, hq)
    scale = sm_scale if sm_scale is not None else d ** -0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if causal:
        qpos = q_offset + jnp.arange(sq)[:, None]
        kpos = k_offset + jnp.arange(k.shape[2])[None, :]
        ok = qpos >= kpos
        if window:
            ok = ok & (qpos - kpos < window)
        s = jnp.where(ok, s, NEG_INF)
    m = jnp.max(s, axis=-1)
    # fully-masked rows: keep identity state
    masked = m <= NEG_INF / 2
    m_safe = jnp.where(masked, NEG_INF, m)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(masked[..., None], 0.0, p)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    state = SoftmaxState(acc=acc, m=m_safe, l=l)
    if carry is not None:
        state = merge(carry, state)
    return state


def mha(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    k_offset: int = 0,
    sm_scale: float | None = None,
) -> jnp.ndarray:
    """Full exact attention (normalized output, q.dtype)."""
    st = attend_chunk(q, k, v, causal=causal, window=window, q_offset=q_offset,
                      k_offset=k_offset, sm_scale=sm_scale)
    return finalize(st).astype(q.dtype)


def mha_chunked(q, k, v, n_chunks: int, *, causal: bool = True, sm_scale=None) -> jnp.ndarray:
    """Full attention computed via chunked online merges (schedule oracle)."""
    b, hq, sq, d = q.shape
    sk = k.shape[2]
    assert sq % n_chunks == 0 and sk % n_chunks == 0
    cq, ck = sq // n_chunks, sk // n_chunks
    outs = []
    for i in range(n_chunks):
        qi = q[:, :, i * cq : (i + 1) * cq]
        state = zero_state((b, hq, cq, d))
        for j in range(i + 1 if causal else n_chunks):
            kj = k[:, :, j * ck : (j + 1) * ck]
            vj = v[:, :, j * ck : (j + 1) * ck]
            state = attend_chunk(
                qi, kj, vj, causal=causal, q_offset=i * cq, k_offset=j * ck,
                sm_scale=sm_scale, carry=state,
            )
        outs.append(finalize(state).astype(q.dtype))
    return jnp.concatenate(outs, axis=2)
