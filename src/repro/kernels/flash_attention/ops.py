"""Jit-ready flash-attention ops.

Three implementations with identical semantics (cross-checked in tests):
  * impl="pallas"    — the Pallas TPU kernels (interpret=True off-TPU).
  * impl="xla_flash" — jnp blockwise online-softmax (lax.scan over KV blocks,
                       O(seq) memory, custom recompute backward).  Used by the
                       512-device dry-run (Pallas doesn't lower on the CPU
                       backend) and as a portable fallback.
  * impl="ref"       — exact materialized attention (tiny tests only).

All expose the chunk-level primitives FPDT schedules:
  chunk_fwd      (q_i, kv_j, carry) -> running (acc, m, l)
  chunk_bwd_dq   per-pair dq contribution given final row LSE + delta
  chunk_bwd_dkv  per-pair (dk, dv) contribution
plus ``flash_attention`` — a fused single-call attention with custom VJP.

``q_offset``/``k_offset`` may be Python ints (unrolled FPDT) or *traced*
int scalars (the scan-compiled pipeline passes loop-carried chunk offsets):
the xla/ref paths consume them as ordinary values and the Pallas kernels
take them as a scalar-prefetch operand.  Shapes and block sizes stay
static.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.online_softmax import NEG_INF, SoftmaxState, finalize, lse
from repro.kernels.flash_attention import kernel as _k
from repro.kernels.flash_attention import ref as _ref

# ---------------------------------------------------------------------------
# XLA blockwise implementation
# ---------------------------------------------------------------------------


def _xla_chunk_fwd(q, k, v, carry, *, causal, window, q_offset, k_offset, sm_scale, block_k):
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    k = _ref._expand_kv(k, hq)
    v = _ref._expand_kv(v, hq)
    scale = sm_scale if sm_scale is not None else d ** -0.5
    block_k = _k._fit_block(sk, block_k)
    nk = sk // block_k
    kb = k.reshape(b, hq, nk, block_k, d).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(b, hq, nk, block_k, d).transpose(2, 0, 1, 3, 4)
    qf = q.astype(jnp.float32)
    qpos = q_offset + jnp.arange(sq)[:, None]

    def step(state, inp):
        j, kj, vj = inp
        acc, m, l = state
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kj.astype(jnp.float32)) * scale
        if causal:
            kpos = k_offset + j * block_k + jnp.arange(block_k)[None, :]
            ok = qpos >= kpos
            if window:
                ok = ok & (qpos - kpos < window)
            s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.where(s <= NEG_INF / 2, 0.0, jnp.exp(s - m_new[..., None]))
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vj.astype(jnp.float32))
        return (acc, m_new, l), None

    if carry is None:
        acc0 = jnp.zeros((b, hq, sq, d), jnp.float32)
        m0 = jnp.full((b, hq, sq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hq, sq), jnp.float32)
        carry = (acc0, m0, l0)
    (acc, m, l), _ = jax.lax.scan(step, tuple(carry), (jnp.arange(nk), kb, vb))
    return acc, m, l


def _xla_chunk_bwd_dq(q, k, v, do, L, delta, *, causal, window, q_offset, k_offset, sm_scale, block_k):
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    k = _ref._expand_kv(k, hq)
    v = _ref._expand_kv(v, hq)
    scale = sm_scale if sm_scale is not None else d ** -0.5
    block_k = _k._fit_block(sk, block_k)
    nk = sk // block_k
    kb = k.reshape(b, hq, nk, block_k, d).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(b, hq, nk, block_k, d).transpose(2, 0, 1, 3, 4)
    qf = q.astype(jnp.float32)
    dof = do.astype(jnp.float32)
    qpos = q_offset + jnp.arange(sq)[:, None]

    def step(dq, inp):
        j, kj, vj = inp
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kj.astype(jnp.float32)) * scale
        if causal:
            kpos = k_offset + j * block_k + jnp.arange(block_k)[None, :]
            ok = qpos >= kpos
            if window:
                ok = ok & (qpos - kpos < window)
            s = jnp.where(ok, s, NEG_INF)
        p = jnp.where(s <= NEG_INF / 2, 0.0, jnp.exp(s - L[..., None]))
        dp = jnp.einsum("bhqd,bhkd->bhqk", dof, vj.astype(jnp.float32))
        ds = p * (dp - delta[..., None]) * scale
        return dq + jnp.einsum("bhqk,bhkd->bhqd", ds, kj.astype(jnp.float32)), None

    dq0 = jnp.zeros((b, hq, sq, d), jnp.float32)
    dq, _ = jax.lax.scan(step, dq0, (jnp.arange(nk), kb, vb))
    return dq


def _xla_chunk_bwd_dkv(q, k, v, do, L, delta, *, causal, window, q_offset, k_offset, sm_scale, block_q):
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    g = hq // hkv
    ke = _ref._expand_kv(k, hq).astype(jnp.float32)
    ve = _ref._expand_kv(v, hq).astype(jnp.float32)
    scale = sm_scale if sm_scale is not None else d ** -0.5
    block_q = _k._fit_block(sq, block_q)
    nq = sq // block_q
    qb = q.reshape(b, hq, nq, block_q, d).transpose(2, 0, 1, 3, 4)
    dob = do.reshape(b, hq, nq, block_q, d).transpose(2, 0, 1, 3, 4)
    Lb = L.reshape(b, hq, nq, block_q).transpose(2, 0, 1, 3)
    deltab = delta.reshape(b, hq, nq, block_q).transpose(2, 0, 1, 3)
    kpos = k_offset + jnp.arange(sk)[None, :]

    def step(state, inp):
        dk, dv = state
        i, qi, doi, Li, di = inp
        s = jnp.einsum("bhqd,bhkd->bhqk", qi.astype(jnp.float32), ke) * scale
        if causal:
            qpos = q_offset + i * block_q + jnp.arange(block_q)[:, None]
            ok = qpos >= kpos
            if window:
                ok = ok & (qpos - kpos < window)
            s = jnp.where(ok, s, NEG_INF)
        p = jnp.where(s <= NEG_INF / 2, 0.0, jnp.exp(s - Li[..., None]))
        dv = dv + jnp.einsum("bhqk,bhqd->bhkd", p, doi.astype(jnp.float32))
        dp = jnp.einsum("bhqd,bhkd->bhqk", doi.astype(jnp.float32), ve)
        ds = p * (dp - di[..., None]) * scale
        dk = dk + jnp.einsum("bhqk,bhqd->bhkd", ds, qi.astype(jnp.float32))
        return (dk, dv), None

    z = jnp.zeros((b, hq, sk, d), jnp.float32)
    (dk, dv), _ = jax.lax.scan(step, (z, z), (jnp.arange(nq), qb, dob, Lb, deltab))
    if g > 1:  # GQA: sum the q-head group
        dk = dk.reshape(b, hkv, g, sk, d).sum(2)
        dv = dv.reshape(b, hkv, g, sk, d).sum(2)
    return dk, dv


# ---------------------------------------------------------------------------
# Dispatchers (chunk-level primitives used by FPDT)
# ---------------------------------------------------------------------------


def chunk_fwd(q, k, v, carry=None, *, causal=True, window=0, q_offset=0, k_offset=0,
              sm_scale=None, block_q=512, block_k=512, impl="pallas"):
    if impl == "pallas":
        return _k.flash_fwd(q, k, v, carry, causal=causal, window=window,
                            q_offset=q_offset, k_offset=k_offset, sm_scale=sm_scale,
                            block_q=block_q, block_k=block_k)
    if impl == "xla_flash":
        return _xla_chunk_fwd(q, k, v, carry, causal=causal, window=window,
                              q_offset=q_offset, k_offset=k_offset,
                              sm_scale=sm_scale, block_k=block_k)
    st = _ref.attend_chunk(q, k, v, causal=causal, window=window, q_offset=q_offset,
                           k_offset=k_offset, sm_scale=sm_scale,
                           carry=SoftmaxState(*carry) if carry is not None else None)
    return tuple(st)


def chunk_bwd_dq(q, k, v, do, L, delta, *, causal=True, window=0, q_offset=0, k_offset=0,
                 sm_scale=None, block_q=512, block_k=512, impl="pallas"):
    if impl == "pallas":
        return _k.flash_bwd_dq(q, k, v, do, L, delta, causal=causal, window=window,
                               q_offset=q_offset, k_offset=k_offset, sm_scale=sm_scale,
                               block_q=block_q, block_k=block_k)
    return _xla_chunk_bwd_dq(q, k, v, do, L, delta, causal=causal, window=window,
                             q_offset=q_offset, k_offset=k_offset, sm_scale=sm_scale,
                             block_k=block_k)


def chunk_bwd_dkv(q, k, v, do, L, delta, *, causal=True, window=0, q_offset=0, k_offset=0,
                  sm_scale=None, block_q=512, block_k=512, impl="pallas"):
    if impl == "pallas":
        return _k.flash_bwd_dkv(q, k, v, do, L, delta, causal=causal, window=window,
                                q_offset=q_offset, k_offset=k_offset, sm_scale=sm_scale,
                                block_q=block_q, block_k=block_k)
    return _xla_chunk_bwd_dkv(q, k, v, do, L, delta, causal=causal, window=window,
                              q_offset=q_offset, k_offset=k_offset, sm_scale=sm_scale,
                              block_q=block_q)


# ---------------------------------------------------------------------------
# Fused single-call attention with custom VJP
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _make_flash(causal, window, sm_scale, block_q, block_k, impl):
    kw = dict(causal=causal, window=window, sm_scale=sm_scale, block_q=block_q,
              block_k=block_k, impl=impl)

    @jax.custom_vjp
    def f(q, k, v):
        acc, m, l = chunk_fwd(q, k, v, **kw)
        return finalize(SoftmaxState(acc, m, l)).astype(q.dtype)

    def f_fwd(q, k, v):
        acc, m, l = chunk_fwd(q, k, v, **kw)
        o = finalize(SoftmaxState(acc, m, l))
        L = lse(SoftmaxState(acc, m, l))
        return o.astype(q.dtype), (q, k, v, o, L)

    def f_bwd(res, do):
        q, k, v, o, L = res
        dof = do.astype(jnp.float32)
        delta = jnp.sum(dof * o, axis=-1)
        dq = chunk_bwd_dq(q, k, v, dof, L, delta, **kw)
        dk, dv = chunk_bwd_dkv(q, k, v, dof, L, delta, **kw)
        return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)

    f.defvjp(f_fwd, f_bwd)
    return f


def flash_attention(q, k, v, *, causal=True, window=0, sm_scale=None,
                    block_q=512, block_k=512, impl="pallas"):
    """Fused causal flash attention [b, h, s, d] with custom VJP (GQA-aware)."""
    if impl == "ref":
        return _ref.mha(q, k, v, causal=causal, window=window, sm_scale=sm_scale)
    return _make_flash(causal, window, sm_scale, block_q, block_k, impl)(q, k, v)
