"""Pallas TPU flash-attention kernels with FPDT chunk-carry support.

Design (TPU-native, see DESIGN.md §2):
  * Layout [b, h, s, d]; grid (b, h, num_q_blocks, num_k_blocks) with the
    k-block dimension innermost and sequential ("arbitrary"), carrying the
    online-softmax state (m, l, acc) in fp32 VMEM scratch.
  * BlockSpec tiles: q (block_q, d), k/v (block_k, d) — d is the MXU lane
    dim (64/128/256 in our archs); block_q/block_k default 512 so a tile set
    (q + k + v + acc + p) stays well under VMEM (~4 MB at d=128, bf16 in /
    fp32 accum).
  * Carry-in (acc, m, l) inputs let the FPDT sequence-chunk pipeline continue
    one softmax across chunk boundaries; outputs are the *unnormalized*
    running state, normalized once per chunk row at the JAX level.
  * Causal masking against *global* positions: q_offset/k_offset arrive as a
    scalar-prefetch operand (SMEM), so they may be *traced* values — the
    scan-compiled FPDT pipeline calls one kernel instance with loop-carried
    chunk offsets instead of unrolling u**2 staticly-offset copies.  Dead
    (fully-masked) blocks are still skipped with @pl.when on a predicate
    computed from the prefetched offsets.
  * GQA is native: k/v index maps fold the q-head -> kv-head group mapping;
    the dkv backward kernel accumulates over the q heads of each group in its
    sequential inner grid dimension.

On non-TPU backends the kernels run with interpret=True (pure-Python
execution) — used by every test in this repo; real-TPU compilation is the
deployment target.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30



def _fit_block(n: int, block: int) -> int:
    """Largest divisor of n that is <= block (kernel grids need divisibility)."""
    b = min(block, n)
    while n % b:
        b -= 1
    return b

def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _offsets_operand(q_offset, k_offset) -> jnp.ndarray:
    """[q_offset, k_offset] as the int32 scalar-prefetch operand.

    Accepts Python ints (unrolled FPDT: offsets are trace-time constants)
    and traced int scalars (scan-compiled FPDT: offsets are loop carries).
    """
    return jnp.stack([jnp.asarray(q_offset, jnp.int32),
                      jnp.asarray(k_offset, jnp.int32)])


def _grid_spec(grid, in_specs, out_specs, scratch_shapes):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1, grid=grid, in_specs=in_specs,
        out_specs=out_specs, scratch_shapes=scratch_shapes,
    )


# ===========================================================================
# Forward
# ===========================================================================


def _fwd_kernel(
    offs_ref, q_ref, k_ref, v_ref, acc_in_ref, m_in_ref, l_in_ref,
    acc_out_ref, m_out_ref, l_out_ref,
    m_scr, l_scr, acc_scr,
    *, sm_scale, causal, window, block_q, block_k, nk,
):
    ik = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = m_in_ref[...].astype(jnp.float32)
        l_scr[...] = l_in_ref[...].astype(jnp.float32)
        acc_scr[...] = acc_in_ref[...].astype(jnp.float32)

    q_start = offs_ref[0] + iq * block_q
    k_start = offs_ref[1] + ik * block_k
    # dead block: fully above the diagonal, or fully left of the window band
    dead = causal & (q_start + block_q - 1 < k_start)
    if window:
        dead = dead | (k_start + block_k - 1 < q_start - window + 1)

    @pl.when(~dead)
    def _compute():
        q = q_ref[...].astype(jnp.float32)
        k = k_ref[...].astype(jnp.float32)
        v = v_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale  # [block_q, block_k]
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            ok = qpos >= kpos
            if window:
                ok = ok & (qpos - kpos < window)
            s = jnp.where(ok, s, NEG_INF)
        m_prev = m_scr[...]
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        # explicit mask (don't rely on exp underflow of NEG_INF - NEG_INF)
        p = jnp.where(s <= NEG_INF / 2, 0.0, jnp.exp(s - m_new[:, None]))
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_scr[...] * alpha + jnp.sum(p, axis=-1)
        acc_new = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new
        l_scr[...] = l_new
        acc_scr[...] = acc_new

    @pl.when(ik == nk - 1)
    def _write():
        acc_out_ref[...] = acc_scr[...]
        m_out_ref[...] = m_scr[...]
        l_out_ref[...] = l_scr[...]


def flash_fwd(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    carry: Optional[tuple] = None,  # (acc [b,h,sq,d] f32, m [b,h,sq] f32, l f32)
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    k_offset: int = 0,
    sm_scale: Optional[float] = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: Optional[bool] = None,
):
    """Unnormalized online attention of q (at q_offset) over k/v (at k_offset).

    Returns (acc, m, l): fp32 running state (continuing ``carry`` if given).
    """
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    block_q = _fit_block(sq, block_q)
    block_k = _fit_block(sk, block_k)
    nq, nk = sq // block_q, sk // block_k
    scale = sm_scale if sm_scale is not None else d ** -0.5
    interpret = _default_interpret() if interpret is None else interpret

    if carry is None:
        acc0 = jnp.zeros((b, hq, sq, d), jnp.float32)
        m0 = jnp.full((b, hq, sq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hq, sq), jnp.float32)
    else:
        acc0, m0, l0 = carry

    kernel = functools.partial(
        _fwd_kernel,
        sm_scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, nk=nk,
    )
    grid = (b, hq, nq, nk)
    q_spec = pl.BlockSpec((None, None, block_q, d), lambda b_, h, iq, ik, offs: (b_, h, iq, 0))
    kv_spec = pl.BlockSpec((None, None, block_k, d), lambda b_, h, iq, ik, offs: (b_, h // g, ik, 0))
    vec_spec = pl.BlockSpec((None, None, block_q), lambda b_, h, iq, ik, offs: (b_, h, iq))

    acc, m, l = pl.pallas_call(
        kernel,
        grid_spec=_grid_spec(
            grid,
            [q_spec, kv_spec, kv_spec, q_spec, vec_spec, vec_spec],
            [q_spec, vec_spec, vec_spec],
            [
                _vmem((block_q,), jnp.float32),
                _vmem((block_q,), jnp.float32),
                _vmem((block_q, d), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, sq, d), jnp.float32),
            jax.ShapeDtypeStruct((b, hq, sq), jnp.float32),
            jax.ShapeDtypeStruct((b, hq, sq), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=_compiler_params(),
    )(_offsets_operand(q_offset, k_offset), q, k, v, acc0, m0, l0)
    return acc, m, l


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)


def _compiler_params():
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        )
    except Exception:  # pragma: no cover
        return None


# ===========================================================================
# Backward: dq
# ===========================================================================


def _dq_kernel(
    offs_ref, q_ref, k_ref, v_ref, do_ref, L_ref, delta_ref,
    dq_ref,
    dq_scr,
    *, sm_scale, causal, window, block_q, block_k, nk,
):
    ik = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    q_start = offs_ref[0] + iq * block_q
    k_start = offs_ref[1] + ik * block_k
    dead = causal & (q_start + block_q - 1 < k_start)
    if window:
        dead = dead | (k_start + block_k - 1 < q_start - window + 1)

    @pl.when(~dead)
    def _compute():
        q = q_ref[...].astype(jnp.float32)
        k = k_ref[...].astype(jnp.float32)
        v = v_ref[...].astype(jnp.float32)
        do = do_ref[...].astype(jnp.float32)
        L = L_ref[...]
        delta = delta_ref[...]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * sm_scale
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            ok = qpos >= kpos
            if window:
                ok = ok & (qpos - kpos < window)
            s = jnp.where(ok, s, NEG_INF)
        p = jnp.where(s <= NEG_INF / 2, 0.0, jnp.exp(s - L[:, None]))
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * sm_scale
        dq_scr[...] += jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _write():
        dq_ref[...] = dq_scr[...]


def flash_bwd_dq(
    q, k, v, do, L, delta,
    *, causal=True, window=0, q_offset=0, k_offset=0, sm_scale=None,
    block_q=512, block_k=512, interpret=None,
):
    """dq contribution of this (q-chunk, kv-chunk) pair. fp32 output."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    g = hq // hkv
    block_q = _fit_block(sq, block_q)
    block_k = _fit_block(sk, block_k)
    nq, nk = sq // block_q, sk // block_k
    scale = sm_scale if sm_scale is not None else d ** -0.5
    interpret = _default_interpret() if interpret is None else interpret

    kernel = functools.partial(
        _dq_kernel, sm_scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, nk=nk,
    )
    q_spec = pl.BlockSpec((None, None, block_q, d), lambda b_, h, iq, ik, offs: (b_, h, iq, 0))
    kv_spec = pl.BlockSpec((None, None, block_k, d), lambda b_, h, iq, ik, offs: (b_, h // g, ik, 0))
    vec_spec = pl.BlockSpec((None, None, block_q), lambda b_, h, iq, ik, offs: (b_, h, iq))
    return pl.pallas_call(
        kernel,
        grid_spec=_grid_spec(
            (b, hq, nq, nk),
            [q_spec, kv_spec, kv_spec, q_spec, vec_spec, vec_spec],
            q_spec,
            [_vmem((block_q, d), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), jnp.float32),
        interpret=interpret,
        compiler_params=_compiler_params(),
    )(_offsets_operand(q_offset, k_offset), q, k, v, do, L, delta)


# ===========================================================================
# Backward: dk, dv
# ===========================================================================


def _dkv_kernel(
    offs_ref, q_ref, k_ref, v_ref, do_ref, L_ref, delta_ref,
    dk_ref, dv_ref,
    dk_scr, dv_scr,
    *, sm_scale, causal, window, block_q, block_k, nq, g,
):
    ik = pl.program_id(2)
    t = pl.program_id(3)  # runs over g * nq (q heads of the group x q blocks)
    iq = t % nq

    @pl.when(t == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q_start = offs_ref[0] + iq * block_q
    k_start = offs_ref[1] + ik * block_k
    dead = causal & (q_start + block_q - 1 < k_start)
    if window:
        dead = dead | (k_start + block_k - 1 < q_start - window + 1)

    @pl.when(~dead)
    def _compute():
        q = q_ref[...].astype(jnp.float32)
        k = k_ref[...].astype(jnp.float32)
        v = v_ref[...].astype(jnp.float32)
        do = do_ref[...].astype(jnp.float32)
        L = L_ref[...]
        delta = delta_ref[...]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * sm_scale
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            ok = qpos >= kpos
            if window:
                ok = ok & (qpos - kpos < window)
            s = jnp.where(ok, s, NEG_INF)
        p = jnp.where(s <= NEG_INF / 2, 0.0, jnp.exp(s - L[:, None]))  # [bq, bk]
        dv_scr[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * sm_scale
        dk_scr[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)

    @pl.when(t == g * nq - 1)
    def _write():
        dk_ref[...] = dk_scr[...]
        dv_ref[...] = dv_scr[...]


def flash_bwd_dkv(
    q, k, v, do, L, delta,
    *, causal=True, window=0, q_offset=0, k_offset=0, sm_scale=None,
    block_q=512, block_k=512, interpret=None,
):
    """(dk, dv) contribution of this (q-chunk, kv-chunk) pair (GQA-summed)."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    g = hq // hkv
    block_q = _fit_block(sq, block_q)
    block_k = _fit_block(sk, block_k)
    nq, nk = sq // block_q, sk // block_k
    scale = sm_scale if sm_scale is not None else d ** -0.5
    interpret = _default_interpret() if interpret is None else interpret

    kernel = functools.partial(
        _dkv_kernel, sm_scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, nq=nq, g=g,
    )
    # inner sequential dim covers q heads of the kv group x q blocks
    q_spec = pl.BlockSpec(
        (None, None, block_q, d), lambda b_, h, ik, t, offs: (b_, h * g + t // nq, t % nq, 0)
    )
    kv_spec = pl.BlockSpec((None, None, block_k, d), lambda b_, h, ik, t, offs: (b_, h, ik, 0))
    vec_spec = pl.BlockSpec(
        (None, None, block_q), lambda b_, h, ik, t, offs: (b_, h * g + t // nq, t % nq)
    )
    return pl.pallas_call(
        kernel,
        grid_spec=_grid_spec(
            (b, hkv, nk, g * nq),
            [q_spec, kv_spec, kv_spec, q_spec, vec_spec, vec_spec],
            [kv_spec, kv_spec],
            [_vmem((block_k, d), jnp.float32), _vmem((block_k, d), jnp.float32)],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, sk, d), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, sk, d), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=_compiler_params(),
    )(_offsets_operand(q_offset, k_offset), q, k, v, do, L, delta)
