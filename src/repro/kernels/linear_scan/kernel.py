"""Pallas TPU blocked linear-scan kernel: h_t = a_t * h_{t-1} + b_t.

Used by the Mamba selective scan (channels = d_inner * d_state) and the
RG-LRU recurrence (channels = lru_width).  TPU mapping:
  * layout [batch, seq, chan], chan on the 128-lane axis, seq on sublanes;
  * grid (batch, chan_blocks, seq_blocks), seq innermost & sequential,
    carrying the running state h [block_c] in fp32 VMEM scratch;
  * within a block the inclusive scan is computed with a *vectorized*
    work-efficient associative scan (log2(block_s) shifted multiply-adds),
    not a serial per-timestep loop — the VPU stays fully occupied;
  * cross-block composition uses the scanned pair (A_cum, B_cum):
    h_block = B_cum + A_cum * h_carry.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)


def _compiler_params():
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        )
    except Exception:  # pragma: no cover
        return None


def _scan_kernel(a_ref, b_ref, h0_ref, o_ref, h_scr, *, ns):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        h_scr[...] = h0_ref[...].astype(jnp.float32)

    a = a_ref[...].astype(jnp.float32)  # [block_s, block_c]
    b = b_ref[...].astype(jnp.float32)

    def compose(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, b2 + a2 * b1

    A, B = jax.lax.associative_scan(compose, (a, b), axis=0)
    h_in = h_scr[...]
    out = B + A * h_in[None, :]
    o_ref[...] = out.astype(o_ref.dtype)
    h_scr[...] = out[-1]


def linear_scan(
    a: jnp.ndarray,
    b: jnp.ndarray,
    h0: Optional[jnp.ndarray] = None,
    *,
    block_s: int = 256,
    block_c: int = 512,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """All inclusive states of h_t = a_t h_{t-1} + b_t. fp32 output."""
    bsz, seq, chan = a.shape
    if h0 is None:
        h0 = jnp.zeros((bsz, chan), jnp.float32)
    block_s = min(block_s, seq)
    block_c = min(block_c, chan)
    assert seq % block_s == 0 and chan % block_c == 0, (seq, block_s, chan, block_c)
    ns, nc = seq // block_s, chan // block_c
    interpret = _default_interpret() if interpret is None else interpret

    return pl.pallas_call(
        functools.partial(_scan_kernel, ns=ns),
        grid=(bsz, nc, ns),
        in_specs=[
            pl.BlockSpec((None, block_s, block_c), lambda b_, ic, is_: (b_, is_, ic)),
            pl.BlockSpec((None, block_s, block_c), lambda b_, ic, is_: (b_, is_, ic)),
            pl.BlockSpec((None, block_c), lambda b_, ic, is_: (b_, ic)),
        ],
        out_specs=pl.BlockSpec((None, block_s, block_c), lambda b_, ic, is_: (b_, is_, ic)),
        out_shape=jax.ShapeDtypeStruct((bsz, seq, chan), jnp.float32),
        scratch_shapes=[_vmem((block_c,), jnp.float32)],
        interpret=interpret,
        compiler_params=_compiler_params(),
    )(a, b, h0)
