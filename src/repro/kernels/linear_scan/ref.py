"""Pure-jnp oracle for the linear-scan kernel.

Computes h_t = a_t * h_{t-1} + b_t (elementwise over channels) with initial
state h0.  Shapes: a, b [batch, seq, chan]; h0 [batch, chan].
Returns all states h [batch, seq, chan] (inclusive).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def linear_scan(a: jnp.ndarray, b: jnp.ndarray, h0: jnp.ndarray | None = None) -> jnp.ndarray:
    bsz, seq, chan = a.shape
    if h0 is None:
        h0 = jnp.zeros((bsz, chan), jnp.float32)

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    a_t = a.astype(jnp.float32).transpose(1, 0, 2)
    b_t = b.astype(jnp.float32).transpose(1, 0, 2)
    _, hs = jax.lax.scan(step, h0.astype(jnp.float32), (a_t, b_t))
    return hs.transpose(1, 0, 2)


def linear_scan_naive(a, b, h0=None):
    """Python-loop recurrence (tiny tests only)."""
    import numpy as np

    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    bsz, seq, chan = a.shape
    h = np.zeros((bsz, chan)) if h0 is None else np.asarray(h0, np.float64).copy()
    out = np.zeros_like(a)
    for t in range(seq):
        h = a[:, t] * h + b[:, t]
        out[:, t] = h
    return out
