"""Jit-ready linear scan with custom VJP.

The adjoint of a linear scan is another linear scan run in reverse:
  g_t = dL/dh_t(total) = dout_t + a_{t+1} g_{t+1}
  db_t = g_t;  da_t = g_t * h_{t-1};  dh0 = a_0 * g_0
so backward reuses the same Pallas kernel on flipped, shifted inputs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.linear_scan import kernel as _k
from repro.kernels.linear_scan import ref as _ref


def _scan_impl(a, b, h0, impl, block_s, block_c):
    if impl == "pallas":
        return _k.linear_scan(a, b, h0, block_s=block_s, block_c=block_c)
    return _ref.linear_scan(a, b, h0)


@functools.lru_cache(maxsize=None)
def _make(impl, block_s, block_c):
    @jax.custom_vjp
    def f(a, b, h0):
        return _scan_impl(a, b, h0, impl, block_s, block_c)

    def f_fwd(a, b, h0):
        h = _scan_impl(a, b, h0, impl, block_s, block_c)
        return h, (a, h, h0)

    def f_bwd(res, dout):
        a, h, h0 = res
        af = a.astype(jnp.float32)
        # reverse scan for the accumulated adjoint g
        a_shift = jnp.concatenate([af[:, 1:], jnp.ones_like(af[:, :1])], axis=1)
        g = _scan_impl(
            jnp.flip(a_shift, axis=1), jnp.flip(dout.astype(jnp.float32), axis=1),
            jnp.zeros_like(h0, dtype=jnp.float32), impl, block_s, block_c,
        )
        g = jnp.flip(g, axis=1)
        h_prev = jnp.concatenate([h0.astype(jnp.float32)[:, None], h[:, :-1]], axis=1)
        da = (g * h_prev).astype(a.dtype)
        db = g.astype(a.dtype)
        dh0 = (af[:, 0] * g[:, 0]).astype(h0.dtype)
        return da, db, dh0

    f.defvjp(f_fwd, f_bwd)
    return f


def linear_scan(a, b, h0=None, *, impl="pallas", block_s=256, block_c=512):
    """Differentiable inclusive linear scan h_t = a_t h_{t-1} + b_t."""
    if h0 is None:
        h0 = jnp.zeros((a.shape[0], a.shape[2]), jnp.float32)
    return _make(impl, block_s, block_c)(a, b, h0)
