"""Online-softmax running statistics and their merge operator.

The FPDT chunk pipeline continues a *single* softmax across sequence chunks:
each chunk's attention produces an unnormalized accumulator ``acc`` together
with running row-max ``m`` and row-sum ``l``.  ``merge`` combines two such
partial states; it is associative and commutative (tested by hypothesis), so
any chunk schedule (forward pipeline, nested backward loop, tree reduction)
yields identical results.

State convention (all fp32):
  m:   [..., sq]      running row max of logits
  l:   [..., sq]      running sum of exp(logits - m)
  acc: [..., sq, d]   running sum of exp(logits - m) @ V  (unnormalized)

``finalize(acc, l) = acc / l`` is the attention output.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

NEG_INF = -1e30  # avoid actual -inf: exp(-inf - -inf) = nan


class SoftmaxState(NamedTuple):
    acc: jnp.ndarray  # [..., sq, d] fp32
    m: jnp.ndarray  # [..., sq] fp32
    l: jnp.ndarray  # [..., sq] fp32


def zero_state(shape_sq_d, dtype=jnp.float32) -> SoftmaxState:
    """Identity element of ``merge``: m=-inf, l=0, acc=0."""
    *lead, sq, d = shape_sq_d
    return SoftmaxState(
        acc=jnp.zeros((*lead, sq, d), dtype),
        m=jnp.full((*lead, sq), NEG_INF, dtype),
        l=jnp.zeros((*lead, sq), dtype),
    )


def zero_state_like(q: jnp.ndarray) -> SoftmaxState:
    """Identity state shaped for a query block ``q [..., sq, d]`` (fp32 —
    the running statistics always accumulate in fp32 regardless of q's
    dtype).  This is the scan-carry init of the loop-compiled FPDT forward;
    passing it as an explicit carry is numerically identical to the
    ``carry=None`` initialization inside the chunk kernels."""
    return zero_state(q.shape)


def merge(a: SoftmaxState, b: SoftmaxState) -> SoftmaxState:
    """Associative merge of two partial online-softmax states."""
    m = jnp.maximum(a.m, b.m)
    ea = jnp.exp(a.m - m)
    eb = jnp.exp(b.m - m)
    l = a.l * ea + b.l * eb
    acc = a.acc * ea[..., None] + b.acc * eb[..., None]
    return SoftmaxState(acc=acc, m=m, l=l)


def finalize(state: SoftmaxState, eps: float = 0.0) -> jnp.ndarray:
    """Normalized attention output. Rows with l == 0 (fully masked) -> 0."""
    l = state.l
    safe = jnp.where(l == 0.0, 1.0, l)
    return state.acc / (safe[..., None] + eps)


def lse(state: SoftmaxState) -> jnp.ndarray:
    """Row log-sum-exp (the quantity flash backward needs)."""
    return state.m + jnp.log(jnp.where(state.l == 0.0, 1.0, state.l))
