"""Parallel context: mesh, named-axis policy, and sharding-constraint helpers.

The framework expresses distribution in the pjit/GSPMD world: model code is
written over *global* arrays and placement is steered with sharding
constraints.  The Ulysses all-to-all (sequence<->head resharding), the CP KV
all-gather, and the EP dispatch all *emerge* from these constraints — the
dry-run HLO is parsed to verify the intended collectives were chosen
(EXPERIMENTS.md §Dry-run), and hillclimbing may override GSPMD choices with
explicit shard_map collectives where profitable.

Axis convention (per assignment):
  pod   — outermost data parallelism across pods (multi-pod mesh only)
  data  — data parallelism + ZeRO-3/FSDP parameter & optimizer sharding
  model — the sequence-parallel group (Ulysses heads / CP / SSM channels / EP)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.runtime.placement import PlacementPolicy, default_policy


@dataclasses.dataclass(frozen=True)
class ParallelContext:
    mesh: Optional[Mesh] = None
    dp_axes: Tuple[str, ...] = ("data",)  # ("pod", "data") on the multi-pod mesh
    sp_axis: Optional[str] = "model"
    attn_impl: str = "pallas"  # chunk-op kernel impl: pallas | xla_flash | ref
    offload_to_host: bool = True  # honor fpdt_offload / remat-offload configs
    placement: Optional[PlacementPolicy] = None  # None -> probe-once default

    # ------------------------------------------------------------------
    @classmethod
    def for_mesh(cls, mesh, **kw) -> "ParallelContext":
        """Context over an existing mesh with ``dp_axes`` derived from its
        axis names (``launch.mesh.dp_axes_of``) — the one construction rule
        shared by the trainer and the serve replicas."""
        from repro.launch.mesh import dp_axes_of

        return cls(mesh=mesh, dp_axes=dp_axes_of(mesh), **kw)

    @property
    def pol(self) -> PlacementPolicy:
        """The backend-capability policy all placement ops route through."""
        return self.placement if self.placement is not None else default_policy()

    @property
    def offload_active(self) -> bool:
        """Offload requested here AND possible on this backend."""
        return self.offload_to_host and self.pol.can_offload

    @property
    def sp(self) -> int:
        if self.mesh is None or self.sp_axis is None:
            return 1
        return self.mesh.shape[self.sp_axis]

    @property
    def dp(self) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in self.dp_axes:
            n *= self.mesh.shape[a]
        return n

    def ns(self, *spec) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, P(*spec))

    def constrain(self, x: jnp.ndarray, *spec) -> jnp.ndarray:
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(x, self.ns(*spec))

    # --- canonical activation specs ----------------------------------
    def batch_spec(self):
        """Leading (batch) axis spec component."""
        return self.dp_axes if self.mesh is not None else None

    def seq_sharded(self, x: jnp.ndarray) -> jnp.ndarray:
        """[b, s, ...]: batch over dp, sequence over model."""
        rest = (None,) * (x.ndim - 2)
        return self.constrain(x, self.dp_axes, self.sp_axis, *rest)

    def head_sharded(self, x: jnp.ndarray) -> jnp.ndarray:
        """[b, s, h, d]: batch over dp, heads over model (Ulysses inside-attn)."""
        return self.constrain(x, self.dp_axes, None, self.sp_axis, None)

    def channel_sharded(self, x: jnp.ndarray) -> jnp.ndarray:
        """[b, s, c]: channels over model (Ulysses-for-SSM inside-mixer)."""
        return self.constrain(x, self.dp_axes, None, self.sp_axis)

    def replicated_kv(self, x: jnp.ndarray) -> jnp.ndarray:
        """[b, s, h, d] KV replicated across model (CP all-gather)."""
        return self.constrain(x, self.dp_axes, None, None, None)

    # --- host offload (routed through the placement policy) ------------
    def to_host(self, x: jnp.ndarray, *spec) -> jnp.ndarray:
        if not self.offload_to_host:
            return x
        return self.pol.to_host(x, self.mesh, spec)

    def to_device(self, x: jnp.ndarray, *spec) -> jnp.ndarray:
        if not self.offload_to_host:
            return x
        return self.pol.to_device(x, self.mesh, spec)


def make_shard_fn(par: Optional[ParallelContext]):
    """Hint-based constraint fn handed to family mixers (mamba/rglru/moe)."""
    if par is None or par.mesh is None:
        return None

    def shard(x, hint: str):
        if hint in ("seq", "seq3"):
            return par.seq_sharded(x)
        if hint == "channel":
            return par.channel_sharded(x)
        if hint == "expert":  # [e, g, c, d]
            return par.constrain(x, par.sp_axis, par.dp_axes, None, None)
        raise ValueError(hint)

    return shard
