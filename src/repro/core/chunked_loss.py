"""Vocab-projection + cross-entropy, chunked along the sequence (paper §5.4).

The last linear projection to vocab logits (fp32) is the paper's final
memory spike: [b, s, V] fp32 with V >> d.  Chunking the sequence into
~ceil(V/d)*2 chunks bounds the live logits buffer to ~2x the hidden chunk.
Backward recomputes per chunk (jax.checkpoint inside the scan), so the
spike never materializes in either pass.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig

IGNORE = -100


def auto_chunks(cfg: ModelConfig, seq_len: int, sp: int = 1) -> int:
    """Paper's rule vocab/hidden*2, rounded down so seq_len % n == 0 AND each
    chunk's sequence stays divisible by the model axis (so logits chunks can
    remain sequence-sharded — no hidden-state gather per chunk)."""
    target = max(1, (2 * cfg.vocab_size) // cfg.d_model)
    best = 1
    for n in range(1, min(target, seq_len) + 1):
        if seq_len % n == 0 and (seq_len // n) % max(1, sp) == 0:
            best = n
    return best


def softmax_xent_chunked(
    x: jnp.ndarray,  # [b, s, d] final hidden (normed)
    head: jnp.ndarray,  # [d, V]
    labels: jnp.ndarray,  # [b, s] int32, IGNORE masked
    n_chunks: int,
    z_weight: float = 0.0,
    par=None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (sum_loss fp32 scalar, token_count fp32 scalar).

    Distributed: chunks are taken along the BATCH (each chunk = one
    per-dp-shard batch row group); the hidden chunk all-gathers its sequence
    (small) while logits stay V-sharded over the model axis, so the head is
    never replicated and its gradient never all-reduced (§Perf B1: the
    seq-chunked variant re-gathered hidden per chunk and all-reduced a
    replicated head grad — measured, refuted)."""
    b, s, d = x.shape
    dp = par.dp if par is not None and par.mesh is not None else 1
    if par is not None and par.mesh is not None:
        # tables are stored (vocab->data, d->model) for cheap lookups; the
        # loss wants V-sharded logits, so reshard the head ONCE (d full,
        # V->model).  Without this GSPMD contracts over the sharded d and
        # psums full fp32 logits (measured +670 ms/step, §Perf B2).
        head = par.constrain(head, None, par.sp_axis)
    batch_mode = dp > 1 and b % dp == 0 and (b // dp) >= 1
    if batch_mode:
        n_chunks = min(b // dp if b // dp > 1 else 1, max(1, n_chunks))
        n_chunks = next(n for n in range(n_chunks, 0, -1) if (b // dp) % n == 0 or n == 1)
        if (b // dp) % n_chunks:
            n_chunks = 1
        cb = b // n_chunks
        xs = x.reshape(n_chunks, cb, s, d)
        ys = labels.reshape(n_chunks, cb, s)
    else:
        if s % n_chunks != 0:
            n_chunks = 1
        cs = s // n_chunks
        xs = x.reshape(b, n_chunks, cs, d).transpose(1, 0, 2, 3)
        ys = labels.reshape(b, n_chunks, cs).transpose(1, 0, 2)

    @jax.checkpoint
    def step(carry, inp):
        xc, yc = inp
        if par is not None and par.mesh is not None and batch_mode:
            xc = par.constrain(xc, par.dp_axes, None, None)  # gather seq, keep batch
        logits = (xc @ head).astype(jnp.float32)  # [.., .., V]
        if par is not None and par.mesh is not None:
            if batch_mode:  # vocab-parallel logits, batch over dp
                logits = par.constrain(logits, par.dp_axes, None, par.sp_axis)
            else:
                logits = par.constrain(logits, par.dp_axes, par.sp_axis, None)
        lz = jax.nn.logsumexp(logits, axis=-1)
        ok = yc != IGNORE
        tgt = jnp.take_along_axis(logits, jnp.maximum(yc, 0)[..., None], axis=-1)[..., 0]
        nll = jnp.where(ok, lz - tgt, 0.0)
        if z_weight:
            nll = nll + jnp.where(ok, z_weight * lz**2, 0.0)
        loss_sum, count = carry
        return (loss_sum + nll.sum(), count + ok.sum()), None

    (loss_sum, count), _ = jax.lax.scan(step, (jnp.float32(0.0), jnp.float32(0.0)), (xs, ys))
    return loss_sum, count
