"""FPDT: the paper's sequence-chunk pipelined distributed attention.

One implementation covers both the paper's baseline and its contribution:

  * ``u = 1``     -> plain DeepSpeed-Ulysses attention (project, all-to-all,
                     flash attention, all-to-all back).  This *is* the
                     paper's baseline, and the u>1 path must match it
                     bit-for-bit in expectation (tested).
  * ``u > 1``     -> the FPDT pipeline: the hidden chunk T_i is projected to
                     (q_i, k_i, v_i), per-chunk all-to-all scatters heads and
                     gathers sequence (GSPMD reshard induced by a sharding
                     constraint), online attention continues one softmax
                     across KV chunks j <= i, and idle KV chunks are
                     offloaded to host memory and fetched back chunk-by-chunk
                     with *explicit* double buffering: the fetch of chunk
                     j+1 is issued before the chunk-j kernel, so the async
                     copy-start/copy-done pair overlaps chunk compute by
                     program order.  All residency decisions route through
                     ``runtime.placement.PlacementPolicy`` — on a backend
                     with no pinned-host pool (e.g. CPU) offload degrades
                     to a no-op and the pipeline still matches u=1 exactly.

Two compilation strategies for the u>1 pipeline:

  * scan-compiled (default) — the forward is one ``lax.scan`` over query
    chunks whose carry holds the KV store as preallocated
    ``[u, b, h, cq, dh]`` buffers (``dynamic_update_slice`` on append,
    ``dynamic_slice`` + placement-routed fetch on read); the inner KV loop
    and the Fig. 7 backward's nested loops are ``fori_loop``s with *traced*
    chunk offsets (the flash kernels take offsets as scalar-prefetch
    operands), and ``pair_live`` is a traced predicate gating each pair
    with ``lax.cond`` — window/sparsity chunk skipping skips compute *and*
    host traffic inside the compiled loop.  HLO size is O(1) in u, so
    u=32/u=64 schedules (the path to the paper's 2M-token setting) trace
    and compile in near-constant time (see benchmarks/compile_scaling.py).
  * unrolled (``cfg.fpdt_unroll=True``) — the original Python-unrolled
    O(u^2) double loop.  Kept as a differential-testing oracle
    (tests/test_fpdt_scan.py) and for roofline probes that want per-pair
    HLO costs; impractical beyond toy u (quadratic HLO growth).

Double buffering in the scan path carries the prefetched chunk in the loop
state (``runtime.placement.fori_double_buffered``): the fetch of chunk j+1
is issued before chunk j's kernels in program order, exactly like the
generator-based schedule of the unrolled path.

Backward is a custom VJP implementing the paper's Fig. 7 nested loop:
outer loop over KV chunks j, inner loop over query chunks i >= j, using the
saved final row-LSE L_i so every (i, j) pair's contribution is independent:
dk_j/dv_j accumulate across the inner loop; dq_i accumulates across outer
iterations and finalizes when j == i, at which point it is all-to-all'd back
(a sharding constraint) and back-projected — overlapping with the next KV
chunk's fetch, exactly the paper's schedule.

Two distribution modes (DESIGN.md §3):
  * kind="ulysses" — heads % sp == 0 (GQA KV replicated to the SP degree);
  * kind="cp"      — all-gather context parallelism for archs whose head
                     count doesn't divide the model axis; with u > 1 this
                     becomes chunk-streamed KV all-gather + offload
                     ("FPDT-CP", a beyond-paper generalization);
  * kind="local"   — single-device / no model axis.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.core.online_softmax import SoftmaxState, finalize, lse, zero_state_like
from repro.core.parallel import ParallelContext
from repro.kernels.flash_attention import ops as fa
from repro.models.layers import apply_rope, qkv_proj
from repro.runtime.placement import double_buffered, fori_double_buffered

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# sharding helpers (kind-dependent): placement of the per-chunk q/k/v
# ---------------------------------------------------------------------------


def _shard_q(par: ParallelContext, kind: str, q: jnp.ndarray) -> jnp.ndarray:
    """q [b, s, h, d] placement inside attention."""
    if par.mesh is None or kind == "local":
        return q
    if kind == "ulysses":
        return par.head_sharded(q)  # seq gathered, heads scattered (a2a)
    return par.constrain(q, par.dp_axes, par.sp_axis, None, None)  # cp: seq stays

def _shard_q_stacked(par: ParallelContext, kind: str, x: jnp.ndarray) -> jnp.ndarray:
    """Chunk-stacked [u, b, s, h, d] variant of ``_shard_q``."""
    if par.mesh is None or kind == "local":
        return x
    if kind == "ulysses":
        return par.constrain(x, None, par.dp_axes, None, par.sp_axis, None)
    return par.constrain(x, None, par.dp_axes, par.sp_axis, None, None)


def _shard_kv(par: ParallelContext, kind: str, x: jnp.ndarray) -> jnp.ndarray:
    if par.mesh is None or kind == "local":
        return x
    if kind == "ulysses" and x.shape[2] % par.sp == 0:
        return par.head_sharded(x)
    # GQA/MQA with kv_heads < sp: keep KV replicated across the model axis
    # (a clean all-gather; never materialize a repeat + reshard, which GSPMD
    # can only realize by full rematerialization)
    return par.replicated_kv(x)


def _kv_rep(cfg: ModelConfig, par: ParallelContext, kind: str) -> int:
    """KV head replication for Ulysses.  Disabled (returns 1) since v2 of the
    sharding scheme: kv_heads < sp now keeps KV replicated over the model
    axis instead of repeating heads (see _shard_kv) — GSPMD turned the
    repeat+reshard into a full rematerialization (measured §Perf A1)."""
    return 1


def _host_spec_kv(par: ParallelContext, kind: str, n_heads: int, chunk_len: int):
    """Sharding spec of offloaded head-layout [b, h, s, d] chunks: heads over
    model when divisible, else the chunk's sequence dim, else dp only."""
    if kind == "ulysses" and par.sp and n_heads % par.sp == 0:
        return (par.dp_axes, par.sp_axis, None, None)
    if par.sp and chunk_len % par.sp == 0:
        return (par.dp_axes, None, par.sp_axis, None)
    return (par.dp_axes, None, None, None)


# ---------------------------------------------------------------------------
# chunk-pair liveness (window band / block sparsity)
# ---------------------------------------------------------------------------


def sparsity_stride(sparsity: float) -> int:
    """Distance stride keeping ~(1-sparsity) of off-diagonal KV chunks."""
    return max(1, round(1.0 / max(1e-9, 1.0 - sparsity)))


def pair_live(i: int, j: int, *, cq: int, window: int, sparsity: float) -> bool:
    """Is the (query chunk i, KV chunk j) pair attended?  Static indices
    (unrolled path / tests); ``pair_live_traced`` is the loop twin."""
    if j > i:
        return False
    if window and (i - j) * cq >= window + cq - 1:
        return False  # chunk pair fully outside the attention band
    if sparsity > 0.0 and j < i:
        # block-sparse (paper §5.6): keep ~(1-sparsity) of off-diagonal
        # KV chunks by distance stride; the diagonal is always attended.
        # Fewer KV chunks are fetched from host — the paper's Table 4.
        if (i - j - 1) % sparsity_stride(sparsity) != 0:
            return False
    return True


def pair_live_traced(i, j, *, cq: int, window: int, sparsity: float):
    """Traced-predicate twin of ``pair_live`` (i/j may be int tracers).

    window/sparsity/cq stay trace-time constants — only the chunk indices
    are dynamic, so the compiled loop body carries one boolean that gates
    the pair's kernels and fetches with ``lax.cond``.
    """
    i = jnp.asarray(i, jnp.int32)
    j = jnp.asarray(j, jnp.int32)
    liv = j <= i
    if window:
        liv &= (i - j) * cq < window + cq - 1
    if sparsity > 0.0:
        liv &= (j == i) | ((i - j - 1) % sparsity_stride(sparsity) == 0)
    return liv


# ---------------------------------------------------------------------------
# the chunk pipeline (forward + Fig.7 backward), cached per static config
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _make_fpdt(cfg: ModelConfig, par: ParallelContext, kind: str, window: int,
               u: int, offload: bool, seq_len: int, pos_offset: int):
    sparsity = cfg.attn_sparsity
    """Returns f(x, p) -> o  with x [b, S, d], o [b, S, hq*dh] (seq-sharded)."""
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    rep = _kv_rep(cfg, par, kind)
    impl = par.attn_impl
    bq, bk = cfg.block_q, cfg.block_k
    assert seq_len % u == 0, (seq_len, u)
    cq = seq_len // u
    # u=1 has no chunk loop to compile — the unrolled builder IS the plain
    # Ulysses/CP baseline there, so the scan machinery only engages for u>1.
    unroll = cfg.fpdt_unroll or u == 1
    # Offload *requested*: capability degradation (no pinned-host pool ->
    # identity + one logged warning) happens inside the placement policy.
    do_offload = offload and par.offload_to_host and u > 1
    kv_spec = _host_spec_kv(par, kind, hkv * rep, seq_len // u)
    q_spec = _host_spec_kv(par, kind, hq, seq_len // u)

    def project(p, xi, i):
        """(q, k, v) of hidden chunk i in head layout; i may be traced."""
        q, k, v = qkv_proj(cfg, p, xi)  # [b, cq, h, dh]
        pos = jnp.asarray(i, jnp.int32) * cq + pos_offset + jnp.arange(cq)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
        if rep > 1:
            k = jnp.repeat(k, rep, axis=2)
            v = jnp.repeat(v, rep, axis=2)
        q = _shard_q(par, kind, q)
        k = _shard_kv(par, kind, k)
        v = _shard_kv(par, kind, v)
        # head-major layout for the kernels
        return (q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3))

    def unrope_back(g, i):
        """Backward of rope: rotate by -theta (orthogonal map); traced i ok."""
        pos = -(jnp.asarray(i, jnp.int32) * cq + pos_offset + jnp.arange(cq))
        return apply_rope(g, pos, cfg.rope_theta)

    live_py = functools.partial(pair_live, cq=cq, window=window, sparsity=sparsity)
    live_tr = functools.partial(pair_live_traced, cq=cq, window=window,
                                sparsity=sparsity)

    def to_host(t, spec=None):
        return par.to_host(t, *(spec or kv_spec)) if do_offload else t

    def to_dev(t, spec=None):
        return par.to_device(t, *(spec or kv_spec)) if do_offload else t

    # chunk-stacked [u, ...] stores: the leading chunk axis never shards
    kv_store_spec = (None,) + kv_spec
    q_store_spec = (None,) + q_spec

    def pair_kwargs(i, j):
        return dict(causal=True, window=window, q_offset=i * cq, k_offset=j * cq,
                    block_q=bq, block_k=bk, impl=impl)

    # ================= unrolled path (fpdt_unroll / u == 1) =================

    def fwd_unrolled(x, p):
        b = x.shape[0]
        kv_store = []  # (k_j, v_j) in head layout, offloaded when idle
        outs, Ls, res_q, res_o = [], [], [], []
        for i in range(u):
            xi = jax.lax.slice_in_dim(x, i * cq, (i + 1) * cq, axis=1)
            qi, ki, vi = project(p, xi, i)
            carry = None
            # Explicit double buffering (Fig. 6): the fetch of KV chunk j+1
            # is issued before the chunk-j kernel, so the host->device copy
            # overlaps compute by program order, not XLA scheduling luck.
            live = [j for j in range(i) if live_py(i, j)]

            def fetch_kv(j):
                kj, vj = kv_store[j]
                return to_dev(kj), to_dev(vj)

            for j, (kj, vj) in zip(live, double_buffered(live, fetch_kv)):
                carry = fa.chunk_fwd(qi, kj, vj, carry, **pair_kwargs(i, j))
            carry = fa.chunk_fwd(qi, ki, vi, carry, **pair_kwargs(i, i))
            st = SoftmaxState(*carry)
            oi = finalize(st)  # [b, h, cq, dh] fp32
            Li = lse(st)
            kv_store.append((to_host(ki), to_host(vi)))
            res_q.append(to_host(qi, q_spec))
            res_o.append(oi)
            Ls.append(Li)
            # back to token layout + seq sharding (inverse all-to-all)
            ot = oi.astype(x.dtype).transpose(0, 2, 1, 3)  # [b, cq, hq, dh]
            if par.mesh is not None and kind != "local":
                ot = par.constrain(ot, par.dp_axes, par.sp_axis, None, None)
            outs.append(ot.reshape(b, cq, hq * dh))
        o = jnp.concatenate(outs, axis=1)
        if par.mesh is not None:
            o = par.seq_sharded(o)
        return o, (x, p, kv_store, res_q, res_o, Ls)

    def bwd_unrolled(res, do):
        x, p, kv_store, res_q, res_o, Ls = res
        b = x.shape[0]
        # head-layout do + delta per chunk
        dos, deltas = [], []
        for i in range(u):
            doi = jax.lax.slice_in_dim(do, i * cq, (i + 1) * cq, axis=1)
            doi = doi.reshape(b, cq, hq, dh)
            doi = _shard_q(par, kind, doi).transpose(0, 2, 1, 3).astype(jnp.float32)
            dos.append(doi)
            deltas.append(jnp.sum(doi * res_o[i], axis=-1))  # [b, h, cq]

        dqs: list = [None] * u
        dks: list = [None] * u
        dvs: list = [None] * u

        # Fig. 7 schedule with explicit double buffering on both loops: the
        # next KV chunk's fetch is issued before this chunk's inner loop,
        # and the next query chunk's fetch before the current (i, j) pair's
        # kernels — each copy overlaps the preceding chunk's compute.
        def fetch_kv(j):
            kj, vj = kv_store[j]
            return to_dev(kj), to_dev(vj)

        def fetch_q(i):
            return to_dev(res_q[i], q_spec)

        for j, (kj, vj) in zip(range(u), double_buffered(range(u), fetch_kv)):
            inner = [i for i in range(j, u) if live_py(i, j)]
            for i, qi in zip(inner, double_buffered(inner, fetch_q)):
                kwargs = pair_kwargs(i, j)
                dk_c, dv_c = fa.chunk_bwd_dkv(qi, kj, vj, dos[i], Ls[i], deltas[i], **kwargs)
                dq_c = fa.chunk_bwd_dq(qi, kj, vj, dos[i], Ls[i], deltas[i], **kwargs)
                dks[j] = dk_c if dks[j] is None else dks[j] + dk_c
                dvs[j] = dv_c if dvs[j] is None else dvs[j] + dv_c
                dqs[i] = dq_c if dqs[i] is None else dqs[i] + dq_c

        # per-chunk: a2a back, un-rope, un-project; accumulate dW.  A chunk
        # with no live pairs at all (reachable only under schedules that
        # drop the diagonal) contributes exact-zero grads — note dq's zero
        # has hq heads, NOT the kv-head count zkv carries.
        zkv = jnp.zeros((b, hkv * rep, cq, dh), jnp.float32)
        zq = jnp.zeros((b, hq, cq, dh), jnp.float32)
        dq_stack = jnp.stack([dq if dq is not None else zq for dq in dqs])
        dk_stack = jnp.stack([dk if dk is not None else zkv for dk in dks])
        dv_stack = jnp.stack([dv if dv is not None else zkv for dv in dvs])
        return _unproject_unrolled(x, p, dq_stack, dk_stack, dv_stack)

    def _unproject_body(p, i, xi, dq, dk, dv, b):
        """Shared per-chunk grad epilogue: a2a back, un-rope, un-project.
        Returns (dx_i, dW contributions).  i may be traced (scan path)."""
        dq = dq.astype(xi.dtype).transpose(0, 2, 1, 3)  # [b, cq, h, dh]
        dk = dk.astype(xi.dtype).transpose(0, 2, 1, 3)
        dv = dv.astype(xi.dtype).transpose(0, 2, 1, 3)
        if par.mesh is not None and kind != "local":
            dq = par.constrain(dq, par.dp_axes, par.sp_axis, None, None)
            dk = par.constrain(dk, par.dp_axes, par.sp_axis, None, None)
            dv = par.constrain(dv, par.dp_axes, par.sp_axis, None, None)
        if rep > 1:  # sum grads of replicated KV heads
            dk = dk.reshape(b, cq, hkv, rep, dh).sum(3)
            dv = dv.reshape(b, cq, hkv, rep, dh).sum(3)
        dq = unrope_back(dq, i)
        dk = unrope_back(dk, i)
        dqf = dq.reshape(b, cq, hq * dh)
        dkf = dk.reshape(b, cq, hkv * dh)
        dvf = dv.reshape(b, cq, hkv * dh)
        dx = dqf @ p["wq"].T + dkf @ p["wk"].T + dvf @ p["wv"].T
        contrib = {
            "wq": jnp.einsum("bsd,bse->de", xi, dqf),
            "wk": jnp.einsum("bsd,bse->de", xi, dkf),
            "wv": jnp.einsum("bsd,bse->de", xi, dvf),
        }
        if cfg.qkv_bias:
            contrib["bq"] = jnp.sum(dqf, axis=(0, 1))
            contrib["bk"] = jnp.sum(dkf, axis=(0, 1))
            contrib["bv"] = jnp.sum(dvf, axis=(0, 1))
        return dx, contrib

    def _unproject_unrolled(x, p, dq_stack, dk_stack, dv_stack):
        b = x.shape[0]
        dx_chunks, dp = [], None
        for i in range(u):
            xi = jax.lax.slice_in_dim(x, i * cq, (i + 1) * cq, axis=1)
            dx, contrib = _unproject_body(p, i, xi, dq_stack[i], dk_stack[i],
                                          dv_stack[i], b)
            dx_chunks.append(dx)
            dp = contrib if dp is None else jax.tree.map(jnp.add, dp, contrib)
        dx = jnp.concatenate(dx_chunks, axis=1)
        if par.mesh is not None:
            dx = par.seq_sharded(dx)
        # wo is not part of this custom_vjp (out_proj applied by caller)
        return dx, dp

    # ================= scan-compiled path (default for u > 1) ===============
    #
    # One lax.scan over query chunks; the KV store is a pair of preallocated
    # [u, b, h, cq, dh] carry buffers living in the offload pool
    # (placement-annotated after every append), appended with
    # dynamic_update_slice and read back chunk-by-chunk through the
    # double-buffered fori_loop.  HLO contains ONE copy of the chunk body.

    def _store_kv(store, chunk, i):
        store = jax.lax.dynamic_update_index_in_dim(store, chunk, i, axis=0)
        return to_host(store, kv_store_spec)

    def _load(store, j, spec):
        return to_dev(jax.lax.dynamic_index_in_dim(store, j, axis=0,
                                                   keepdims=False), spec)

    def fwd_scan(x, p):
        b = x.shape[0]
        xs = x.reshape(b, u, cq, -1).swapaxes(0, 1)  # [u, b, cq, d]
        proj_dtype = jnp.result_type(x.dtype, p["wq"].dtype)
        # stores start in the offload pool so the scan carry's memory
        # placement agrees between loop entry and the to_host'd body outputs
        kst0 = to_host(jnp.zeros((u, b, hkv * rep, cq, dh), proj_dtype),
                       kv_store_spec)
        vst0 = to_host(jnp.zeros((u, b, hkv * rep, cq, dh), proj_dtype),
                       kv_store_spec)
        qst0 = to_host(jnp.zeros((u, b, hq, cq, dh), proj_dtype), q_store_spec)

        def body(carry, inp):
            kst, vst, qst = carry
            i, xi = inp
            qi, ki, vi = project(p, xi, i)

            def fetch_kv(j):
                return _load(kst, j, kv_spec), _load(vst, j, kv_spec)

            def pair(j, kv, st):
                kj, vj = kv
                return tuple(fa.chunk_fwd(qi, kj, vj, tuple(st), **pair_kwargs(i, j)))

            st = fori_double_buffered(
                0, i, fetch_kv, pair, tuple(zero_state_like(qi)),
                live=lambda j: live_tr(i, j))
            st = SoftmaxState(*fa.chunk_fwd(qi, ki, vi, st, **pair_kwargs(i, i)))
            oi = finalize(st)  # [b, hq, cq, dh] fp32
            Li = lse(st)
            kst = _store_kv(kst, ki, i)
            vst = _store_kv(vst, vi, i)
            qst = to_host(jax.lax.dynamic_update_index_in_dim(qst, qi, i, axis=0),
                          q_store_spec)
            # back to token layout + seq sharding (inverse all-to-all)
            ot = oi.astype(x.dtype).transpose(0, 2, 1, 3)  # [b, cq, hq, dh]
            if par.mesh is not None and kind != "local":
                ot = par.constrain(ot, par.dp_axes, par.sp_axis, None, None)
            return (kst, vst, qst), (oi, Li, ot.reshape(b, cq, hq * dh))

        (kst, vst, qst), (ost, Lst, ots) = jax.lax.scan(
            body, (kst0, vst0, qst0), (jnp.arange(u), xs))
        o = ots.swapaxes(0, 1).reshape(b, seq_len, hq * dh)
        if par.mesh is not None:
            o = par.seq_sharded(o)
        return o, (x, p, kst, vst, qst, ost, Lst)

    def bwd_scan(res, do):
        x, p, kst, vst, qst, ost, Lst = res
        b = x.shape[0]
        # chunk-stacked head-layout do + delta: [u, b, hq, cq, dh] fp32
        dot = do.reshape(b, u, cq, hq, dh).swapaxes(0, 1)
        dot = _shard_q_stacked(par, kind, dot)
        dos = dot.transpose(0, 1, 3, 2, 4).astype(jnp.float32)
        deltas = jnp.sum(dos * ost, axis=-1)  # [u, b, hq, cq]

        def fetch_kv(j):
            return _load(kst, j, kv_spec), _load(vst, j, kv_spec)

        def fetch_q(i):
            return _load(qst, i, q_spec)

        # Fig. 7: outer scan over KV chunks j (dk_j/dv_j emitted as scan
        # outputs), inner double-buffered fori_loop over query chunks
        # i in [j, u) accumulating into the dq store carried across both.
        def outer(carry, j):
            dq_acc, kj, vj = carry
            knext, vnext = fetch_kv(jnp.minimum(j + 1, u - 1))  # Fig. 6 prefetch

            def pair(i, qi, st):
                dk, dv, dq_acc = st
                doi = jax.lax.dynamic_index_in_dim(dos, i, axis=0, keepdims=False)
                Li = jax.lax.dynamic_index_in_dim(Lst, i, axis=0, keepdims=False)
                di = jax.lax.dynamic_index_in_dim(deltas, i, axis=0, keepdims=False)
                kwargs = pair_kwargs(i, j)
                dk_c, dv_c = fa.chunk_bwd_dkv(qi, kj, vj, doi, Li, di, **kwargs)
                dq_c = fa.chunk_bwd_dq(qi, kj, vj, doi, Li, di, **kwargs)
                dq_i = jax.lax.dynamic_index_in_dim(dq_acc, i, axis=0, keepdims=False)
                dq_acc = jax.lax.dynamic_update_index_in_dim(dq_acc, dq_i + dq_c, i, axis=0)
                return dk + dk_c, dv + dv_c, dq_acc

            z = jnp.zeros((b, hkv * rep, cq, dh), jnp.float32)
            dk, dv, dq_acc = fori_double_buffered(
                j, u, fetch_q, pair, (z, z, dq_acc),
                live=lambda i: live_tr(i, j))
            return (dq_acc, knext, vnext), (dk, dv)

        dq0 = jnp.zeros((u, b, hq, cq, dh), jnp.float32)
        k0, v0 = fetch_kv(0)
        (dqs, _, _), (dks, dvs) = jax.lax.scan(
            outer, (dq0, k0, v0), jnp.arange(u))

        # per-chunk grad epilogue as one more scan; dW accumulates in the carry
        xs = x.reshape(b, u, cq, -1).swapaxes(0, 1)

        def unproj(carry, inp):
            i, xi, dq, dk, dv = inp
            dx, contrib = _unproject_body(p, i, xi, dq, dk, dv, b)
            return jax.tree.map(jnp.add, carry, contrib), dx

        dp0 = {"wq": jnp.zeros_like(p["wq"]), "wk": jnp.zeros_like(p["wk"]),
               "wv": jnp.zeros_like(p["wv"])}
        if cfg.qkv_bias:
            dp0.update({"bq": jnp.zeros_like(p["bq"]),
                        "bk": jnp.zeros_like(p["bk"]),
                        "bv": jnp.zeros_like(p["bv"])})
        dp, dxs = jax.lax.scan(unproj, dp0, (jnp.arange(u), xs, dqs, dks, dvs))
        dx = dxs.swapaxes(0, 1).reshape(b, seq_len, -1)
        if par.mesh is not None:
            dx = par.seq_sharded(dx)
        return dx, dp

    fwd, bwd = (fwd_unrolled, bwd_unrolled) if unroll else (fwd_scan, bwd_scan)

    @jax.custom_vjp
    def f(x, p):
        return fwd(x, p)[0]

    f.defvjp(fwd, bwd)
    return f


def fpdt_attention(
    cfg: ModelConfig,
    par: Optional[ParallelContext],
    p: Params,
    x: jnp.ndarray,
    *,
    kind: str = "ulysses",
    window: int = 0,
    pos_offset: int = 0,
) -> jnp.ndarray:
    """Chunk-pipelined distributed attention over hidden states.

    x: [b, S, d] (seq-sharded).  Returns [b, S, hq*dh] (seq-sharded),
    ready for the output projection.  u = cfg.fpdt_chunks (1 = Ulysses/CP
    baseline); offload per cfg.fpdt_offload; scan-compiled chunk loops
    unless cfg.fpdt_unroll.
    """
    par = par if par is not None else ParallelContext(mesh=None)
    attn_p = {k_: p[k_] for k_ in ("wq", "wk", "wv", "bq", "bk", "bv") if k_ in p}
    f = _make_fpdt(cfg, par, kind, window, cfg.fpdt_chunks, cfg.fpdt_offload,
                   x.shape[1], pos_offset)
    return f(x, attn_p)
