"""FPDT: the paper's sequence-chunk pipelined distributed attention.

One implementation covers both the paper's baseline and its contribution:

  * ``u = 1``     -> plain DeepSpeed-Ulysses attention (project, all-to-all,
                     flash attention, all-to-all back).  This *is* the
                     paper's baseline, and the u>1 path must match it
                     bit-for-bit in expectation (tested).
  * ``u > 1``     -> the FPDT pipeline: the hidden chunk T_i is projected to
                     (q_i, k_i, v_i), per-chunk all-to-all scatters heads and
                     gathers sequence (GSPMD reshard induced by a sharding
                     constraint), online attention continues one softmax
                     across KV chunks j <= i, and idle KV chunks are
                     offloaded to host memory and fetched back chunk-by-chunk
                     with *explicit* double buffering: the fetch of chunk
                     j+1 is issued before the chunk-j kernel (see
                     ``runtime.placement.double_buffered``), so the async
                     copy-start/copy-done pair overlaps chunk compute by
                     program order.  All residency decisions route through
                     ``runtime.placement.PlacementPolicy`` — on a backend
                     with no pinned-host pool (e.g. CPU) offload degrades
                     to a no-op and the pipeline still matches u=1 exactly.

Backward is a custom VJP implementing the paper's Fig. 7 nested loop:
outer loop over KV chunks j, inner loop over query chunks i >= j, using the
saved final row-LSE L_i so every (i, j) pair's contribution is independent:
dk_j/dv_j accumulate across the inner loop; dq_i accumulates across outer
iterations and finalizes when j == i, at which point it is all-to-all'd back
(a sharding constraint) and back-projected — overlapping with the next KV
chunk's fetch, exactly the paper's schedule.

Two distribution modes (DESIGN.md §3):
  * kind="ulysses" — heads % sp == 0 (GQA KV replicated to the SP degree);
  * kind="cp"      — all-gather context parallelism for archs whose head
                     count doesn't divide the model axis; with u > 1 this
                     becomes chunk-streamed KV all-gather + offload
                     ("FPDT-CP", a beyond-paper generalization);
  * kind="local"   — single-device / no model axis.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.core.online_softmax import SoftmaxState, finalize, lse
from repro.core.parallel import ParallelContext
from repro.kernels.flash_attention import ops as fa
from repro.models.layers import apply_rope, qkv_proj
from repro.runtime.placement import double_buffered

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# sharding helpers (kind-dependent): placement of the per-chunk q/k/v
# ---------------------------------------------------------------------------


def _shard_q(par: ParallelContext, kind: str, q: jnp.ndarray) -> jnp.ndarray:
    """q [b, s, h, d] placement inside attention."""
    if par.mesh is None or kind == "local":
        return q
    if kind == "ulysses":
        return par.head_sharded(q)  # seq gathered, heads scattered (a2a)
    return par.constrain(q, par.dp_axes, par.sp_axis, None, None)  # cp: seq stays


def _shard_kv(par: ParallelContext, kind: str, x: jnp.ndarray) -> jnp.ndarray:
    if par.mesh is None or kind == "local":
        return x
    if kind == "ulysses" and x.shape[2] % par.sp == 0:
        return par.head_sharded(x)
    # GQA/MQA with kv_heads < sp: keep KV replicated across the model axis
    # (a clean all-gather; never materialize a repeat + reshard, which GSPMD
    # can only realize by full rematerialization)
    return par.replicated_kv(x)


def _kv_rep(cfg: ModelConfig, par: ParallelContext, kind: str) -> int:
    """KV head replication for Ulysses.  Disabled (returns 1) since v2 of the
    sharding scheme: kv_heads < sp now keeps KV replicated over the model
    axis instead of repeating heads (see _shard_kv) — GSPMD turned the
    repeat+reshard into a full rematerialization (measured §Perf A1)."""
    return 1


def _host_spec_kv(par: ParallelContext, kind: str, n_heads: int, chunk_len: int):
    """Sharding spec of offloaded head-layout [b, h, s, d] chunks: heads over
    model when divisible, else the chunk's sequence dim, else dp only."""
    if kind == "ulysses" and par.sp and n_heads % par.sp == 0:
        return (par.dp_axes, par.sp_axis, None, None)
    if par.sp and chunk_len % par.sp == 0:
        return (par.dp_axes, None, par.sp_axis, None)
    return (par.dp_axes, None, None, None)


# ---------------------------------------------------------------------------
# the chunk pipeline (forward + Fig.7 backward), cached per static config
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _make_fpdt(cfg: ModelConfig, par: ParallelContext, kind: str, window: int,
               u: int, offload: bool, seq_len: int, pos_offset: int):
    sparsity = cfg.attn_sparsity
    """Returns f(x, p) -> o  with x [b, S, d], o [b, S, hq*dh] (seq-sharded)."""
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    rep = _kv_rep(cfg, par, kind)
    impl = par.attn_impl
    bq, bk = cfg.block_q, cfg.block_k
    assert seq_len % u == 0, (seq_len, u)
    cq = seq_len // u
    # Offload *requested*: capability degradation (no pinned-host pool ->
    # identity + one logged warning) happens inside the placement policy.
    do_offload = offload and par.offload_to_host and u > 1
    kv_spec = _host_spec_kv(par, kind, hkv * rep, seq_len // u)
    q_spec = _host_spec_kv(par, kind, hq, seq_len // u)

    def project(p, xi, i):
        b = xi.shape[0]
        q, k, v = qkv_proj(cfg, p, xi)  # [b, cq, h, dh]
        pos = jnp.arange(i * cq + pos_offset, i * cq + cq + pos_offset)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
        if rep > 1:
            k = jnp.repeat(k, rep, axis=2)
            v = jnp.repeat(v, rep, axis=2)
        q = _shard_q(par, kind, q)
        k = _shard_kv(par, kind, k)
        v = _shard_kv(par, kind, v)
        # head-major layout for the kernels
        return (q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3))

    def unrope_back(g, i):
        """Backward of rope: rotate by -theta (orthogonal map)."""
        pos = -jnp.arange(i * cq + pos_offset, i * cq + cq + pos_offset)
        return apply_rope(g, pos, cfg.rope_theta)

    def pair_live(i, j):
        if j > i:
            return False
        if window and (i - j) * cq >= window + cq - 1:
            return False  # chunk pair fully outside the attention band
        if sparsity > 0.0 and j < i:
            # block-sparse (paper §5.6): keep ~(1-sparsity) of off-diagonal
            # KV chunks by distance stride; the diagonal is always attended.
            # Fewer KV chunks are fetched from host — the paper's Table 4.
            stride = max(1, round(1.0 / max(1e-9, 1.0 - sparsity)))
            if (i - j - 1) % stride != 0:
                return False
        return True

    def to_host(t, spec=None):
        return par.to_host(t, *(spec or kv_spec)) if do_offload else t

    def to_dev(t, spec=None):
        return par.to_device(t, *(spec or kv_spec)) if do_offload else t

    # ---------------- forward ----------------
    def fwd(x, p):
        b = x.shape[0]
        kv_store = []  # (k_j, v_j) in head layout, offloaded when idle
        outs, Ls, res_q, res_o = [], [], [], []
        for i in range(u):
            xi = jax.lax.slice_in_dim(x, i * cq, (i + 1) * cq, axis=1)
            qi, ki, vi = project(p, xi, i)
            carry = None
            # Explicit double buffering (Fig. 6): the fetch of KV chunk j+1
            # is issued before the chunk-j kernel, so the host->device copy
            # overlaps compute by program order, not XLA scheduling luck.
            live = [j for j in range(i) if pair_live(i, j)]

            def fetch_kv(j):
                kj, vj = kv_store[j]
                return to_dev(kj), to_dev(vj)

            for j, (kj, vj) in zip(live, double_buffered(live, fetch_kv)):
                carry = fa.chunk_fwd(
                    qi, kj, vj, carry, causal=True, window=window,
                    q_offset=i * cq, k_offset=j * cq, block_q=bq, block_k=bk,
                    impl=impl,
                )
            carry = fa.chunk_fwd(
                qi, ki, vi, carry, causal=True, window=window,
                q_offset=i * cq, k_offset=i * cq, block_q=bq, block_k=bk,
                impl=impl,
            )
            st = SoftmaxState(*carry)
            oi = finalize(st)  # [b, h, cq, dh] fp32
            Li = lse(st)
            kv_store.append((to_host(ki), to_host(vi)))
            res_q.append(to_host(qi, q_spec))
            res_o.append(oi)
            Ls.append(Li)
            # back to token layout + seq sharding (inverse all-to-all)
            ot = oi.astype(x.dtype).transpose(0, 2, 1, 3)  # [b, cq, hq, dh]
            if par.mesh is not None and kind != "local":
                ot = par.constrain(ot, par.dp_axes, par.sp_axis, None, None)
            outs.append(ot.reshape(b, cq, hq * dh))
        o = jnp.concatenate(outs, axis=1)
        if par.mesh is not None:
            o = par.seq_sharded(o)
        return o, (x, p, kv_store, res_q, res_o, Ls)

    # ---------------- backward: Fig. 7 nested loop ----------------
    def bwd(res, do):
        x, p, kv_store, res_q, res_o, Ls = res
        b = x.shape[0]
        # head-layout do + delta per chunk
        dos, deltas = [], []
        for i in range(u):
            doi = jax.lax.slice_in_dim(do, i * cq, (i + 1) * cq, axis=1)
            doi = doi.reshape(b, cq, hq, dh)
            doi = _shard_q(par, kind, doi).transpose(0, 2, 1, 3).astype(jnp.float32)
            dos.append(doi)
            deltas.append(jnp.sum(doi * res_o[i], axis=-1))  # [b, h, cq]

        dqs: list = [None] * u
        dks: list = [None] * u
        dvs: list = [None] * u

        # Fig. 7 schedule with explicit double buffering on both loops: the
        # next KV chunk's fetch is issued before this chunk's inner loop,
        # and the next query chunk's fetch before the current (i, j) pair's
        # kernels — each copy overlaps the preceding chunk's compute.
        def fetch_kv(j):
            kj, vj = kv_store[j]
            return to_dev(kj), to_dev(vj)

        def fetch_q(i):
            return to_dev(res_q[i], q_spec)

        for j, (kj, vj) in zip(range(u), double_buffered(range(u), fetch_kv)):
            inner = [i for i in range(j, u) if pair_live(i, j)]
            for i, qi in zip(inner, double_buffered(inner, fetch_q)):
                kwargs = dict(causal=True, window=window, q_offset=i * cq,
                              k_offset=j * cq, block_q=bq, block_k=bk, impl=impl)
                dk_c, dv_c = fa.chunk_bwd_dkv(qi, kj, vj, dos[i], Ls[i], deltas[i], **kwargs)
                dq_c = fa.chunk_bwd_dq(qi, kj, vj, dos[i], Ls[i], deltas[i], **kwargs)
                dks[j] = dk_c if dks[j] is None else dks[j] + dk_c
                dvs[j] = dv_c if dvs[j] is None else dvs[j] + dv_c
                dqs[i] = dq_c if dqs[i] is None else dqs[i] + dq_c

        # per-chunk: a2a back, un-rope, un-project; accumulate dW
        dx_chunks = []
        dwq = dwk = dwv = None
        dbq = dbk = dbv = None
        for i in range(u):
            xi = jax.lax.slice_in_dim(x, i * cq, (i + 1) * cq, axis=1)
            dq = dqs[i].astype(x.dtype).transpose(0, 2, 1, 3)  # [b, cq, h, dh]
            zkv = jnp.zeros((b, hkv * rep, cq, dh), x.dtype)
            dk = (dks[i] if dks[i] is not None else zkv).astype(x.dtype).transpose(0, 2, 1, 3)
            dv = (dvs[i] if dvs[i] is not None else zkv).astype(x.dtype).transpose(0, 2, 1, 3)
            if par.mesh is not None and kind != "local":
                dq = par.constrain(dq, par.dp_axes, par.sp_axis, None, None)
                dk = par.constrain(dk, par.dp_axes, par.sp_axis, None, None)
                dv = par.constrain(dv, par.dp_axes, par.sp_axis, None, None)
            if rep > 1:  # sum grads of replicated KV heads
                dk = dk.reshape(b, cq, hkv, rep, dh).sum(3)
                dv = dv.reshape(b, cq, hkv, rep, dh).sum(3)
            dq = unrope_back(dq, i)
            dk = unrope_back(dk, i)
            dqf = dq.reshape(b, cq, hq * dh)
            dkf = dk.reshape(b, cq, hkv * dh)
            dvf = dv.reshape(b, cq, hkv * dh)
            dx = dqf @ p["wq"].T + dkf @ p["wk"].T + dvf @ p["wv"].T
            dx_chunks.append(dx)
            wq_c = jnp.einsum("bsd,bse->de", xi, dqf)
            wk_c = jnp.einsum("bsd,bse->de", xi, dkf)
            wv_c = jnp.einsum("bsd,bse->de", xi, dvf)
            dwq = wq_c if dwq is None else dwq + wq_c
            dwk = wk_c if dwk is None else dwk + wk_c
            dwv = wv_c if dwv is None else dwv + wv_c
            if cfg.qkv_bias:
                bq_c = jnp.sum(dqf, axis=(0, 1))
                bk_c = jnp.sum(dkf, axis=(0, 1))
                bv_c = jnp.sum(dvf, axis=(0, 1))
                dbq = bq_c if dbq is None else dbq + bq_c
                dbk = bk_c if dbk is None else dbk + bk_c
                dbv = bv_c if dbv is None else dbv + bv_c

        dx = jnp.concatenate(dx_chunks, axis=1)
        if par.mesh is not None:
            dx = par.seq_sharded(dx)
        dp = {"wq": dwq, "wk": dwk, "wv": dwv}
        if cfg.qkv_bias:
            dp.update({"bq": dbq, "bk": dbk, "bv": dbv})
        # wo is not part of this custom_vjp (out_proj applied by caller)
        return dx, dp

    @jax.custom_vjp
    def f(x, p):
        return fwd(x, p)[0]

    f.defvjp(fwd, bwd)
    return f


def fpdt_attention(
    cfg: ModelConfig,
    par: Optional[ParallelContext],
    p: Params,
    x: jnp.ndarray,
    *,
    kind: str = "ulysses",
    window: int = 0,
    pos_offset: int = 0,
) -> jnp.ndarray:
    """Chunk-pipelined distributed attention over hidden states.

    x: [b, S, d] (seq-sharded).  Returns [b, S, hq*dh] (seq-sharded),
    ready for the output projection.  u = cfg.fpdt_chunks (1 = Ulysses/CP
    baseline); offload per cfg.fpdt_offload.
    """
    par = par if par is not None else ParallelContext(mesh=None)
    attn_p = {k_: p[k_] for k_ in ("wq", "wk", "wv", "bq", "bk", "bv") if k_ in p}
    f = _make_fpdt(cfg, par, kind, window, cfg.fpdt_chunks, cfg.fpdt_offload,
                   x.shape[1], pos_offset)
    return f(x, attn_p)
