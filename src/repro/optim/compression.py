"""Int8 gradient compression with error feedback.

Targets the *cross-pod* gradient reduction (the slow DCN hop on a multi-pod
mesh): per-tensor-block scaling, int8 quantization, residual (error
feedback) carried in the optimizer state so compression noise doesn't
accumulate.  ~4x less DCN traffic per step at <1% effective noise (tested
for contraction of the error-feedback recursion).

``compress/decompress`` are pure and used two ways:
  * inline (quantize-dequantize) on the pod-mean gradients — numerically
    identical to compressing each pod's contribution when pods hold equal
    shards; this is what train_step applies under ``compress_grads=True``;
  * by the shard_map-over-pod reduction in repro/runtime/pod_reduce.py
    (explicit collective on the pod axis).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

BLOCK = 2048


class Compressed(NamedTuple):
    q: jnp.ndarray  # int8 payload
    scale: jnp.ndarray  # fp32 per-block scales


def compress(x: jnp.ndarray) -> Compressed:
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return Compressed(q=q, scale=scale)


def decompress(c: Compressed, shape, dtype) -> jnp.ndarray:
    import numpy as np

    n = int(np.prod(shape))
    flat = (c.q.astype(jnp.float32) * c.scale).reshape(-1)[:n]
    return flat.reshape(shape).astype(dtype)


def quantize_with_feedback(g: jnp.ndarray, residual: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (dequantized g_hat, new residual).  g_hat + residual' == g + residual."""
    target = g.astype(jnp.float32) + residual.astype(jnp.float32)
    c = compress(target)
    g_hat = decompress(c, g.shape, jnp.float32)
    return g_hat.astype(g.dtype), (target - g_hat).astype(residual.dtype)


def tree_quantize_with_feedback(grads, residuals):
    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residuals)
    out = [quantize_with_feedback(g, r) for g, r in zip(flat_g, flat_r)]
    return (jax.tree.unflatten(tdef, [o[0] for o in out]),
            jax.tree.unflatten(tdef, [o[1] for o in out]))


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
