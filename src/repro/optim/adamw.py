"""AdamW with sharded (ZeRO-3-equivalent) optimizer state.

States inherit the parameter shardings (params themselves are sharded over
the data axes = ZeRO-3), so m/v never materialize unsharded.  State dtype is
configurable (bf16 for the 780B llama4 config so per-chip state fits v5e HBM).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    state_dtype: str = "float32"


class OptState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def lr_at(oc: OptConfig, step) -> jnp.ndarray:
    """Linear warmup + cosine decay to min_lr_frac."""
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(1.0, oc.warmup_steps)
    prog = (step - oc.warmup_steps) / jnp.maximum(1.0, oc.total_steps - oc.warmup_steps)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = oc.min_lr_frac + (1 - oc.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return oc.lr * jnp.where(step < oc.warmup_steps, warm, cos)


def init(oc: OptConfig, params) -> OptState:
    dt = jnp.dtype(oc.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return OptState(step=jnp.zeros((), jnp.int32),
                    m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params))


def global_norm(grads) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )


def apply(oc: OptConfig, params, grads, state: OptState):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, oc.clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    lr = lr_at(oc, step)
    c1 = 1.0 - oc.b1 ** step.astype(jnp.float32)
    c2 = 1.0 - oc.b2 ** step.astype(jnp.float32)
    dt = jnp.dtype(oc.state_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m1 = oc.b1 * m.astype(jnp.float32) + (1 - oc.b1) * g
        v1 = oc.b2 * v.astype(jnp.float32) + (1 - oc.b2) * g * g
        mh, vh = m1 / c1, v1 / c2
        step_w = mh / (jnp.sqrt(vh) + oc.eps) + oc.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_w).astype(p.dtype), m1.astype(dt), v1.astype(dt)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
