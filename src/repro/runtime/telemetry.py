"""Unified telemetry: metrics registry + lifecycle tracer + exporters.

One substrate replaces the five independently invented ``stats`` /
``last_stats`` dicts that used to live in ``runtime/decode_loop.py``,
``runtime/paged.py``, ``launch/router.py`` and ``launch/faults.py``:

* :class:`MetricsRegistry` — named counters, gauges and histograms.
  Histograms keep **exact** running aggregates (count / sum / min / max)
  plus a *bounded reservoir* for percentiles, so per-step records no
  longer grow without bound (the old ``stats["steps"]`` lists appended
  one dict per dispatch forever).
* :class:`Tracer` — structured lifecycle events keyed on
  ``(request, session, replica)`` with **dual timestamps**: wall-clock
  ``perf_counter`` for humans/Perfetto AND the deterministic
  dispatch-step clock, so the same seed + the same ``--fault-plan``
  reproduce the identical event sequence under test
  (:meth:`Tracer.deterministic_view` excludes the wall-clock fields).
* :class:`StatsView` — a ``MutableMapping`` facade that keeps the
  existing ``engine.last_stats[...]`` contract intact while storing
  every scalar in the registry, so BENCH numbers derive from the
  registry instead of parallel hand-rolled accounting.
* Exporters — Chrome trace-event JSON (load in Perfetto / chrome://
  tracing; one track per slot, one process per component/replica),
  Prometheus text exposition, and per-request summaries (TTFT, ITL
  p50/p95, queue wait, preemptions, prefix-hit tokens).

Reservoir policy
----------------
Histograms window the most recent ``reservoir`` observations (default
4096) in a ring buffer: percentiles are exact over that sliding window,
while ``count`` / ``total`` / ``min`` / ``max`` stay exact over the full
stream.  The same policy bounds :class:`StepRing` (the ``stats["steps"]``
replacement) and the :class:`Tracer` event buffer — old entries drop
FIFO and a ``dropped`` counter records how many.  Workload-scale runs in
this repo sit far below the caps, so views are bit-identical to the old
unbounded lists; only forever-running servers see the window.

Deliberately stdlib-only: the router layer is framework-free and the
tracer must cost nothing next to a segment dispatch.

Span taxonomy (``kind`` values emitted by the instrumented stack)::

    engine.dispatch                 one fused mixed-step dispatch (dur)
    request.queued/admit/emit/complete  per-request lifecycle
    request.preempt/resume/pause/pause_resume  SLO scheduler actions
    pool.cow/promote/demote/evict/defer        paged-pool actions
    kvstore.save/restore/publish/recover       persistence tier
    router.dispatch/retry/timeout/death/rehome/rejoin/recover
    compile.<program>, alert.programs          jit-cache growth
    train.step                                 one optimizer step (dur)
"""
from __future__ import annotations

import collections
import json
import numbers
import time
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "StatsView",
    "StepRing", "Tracer", "Telemetry", "timed_dispatch",
    "chrome_trace", "write_chrome_trace",
    "prometheus_text", "write_prometheus", "request_summaries",
]

DEFAULT_RESERVOIR = 4096
DEFAULT_STEPS_CAP = 4096
DEFAULT_EVENTS_CAP = 65536


def _is_scalar(v: Any) -> bool:
    # bools are ints in python; keep them out of the numeric registry so
    # flags like ``radix``/``offload`` stay local dict values
    return isinstance(v, numbers.Number) and not isinstance(v, bool)


def percentile(sorted_vals: List[float], p: float) -> float:
    """Nearest-rank percentile over an ascending list (0 for empty)."""
    if not sorted_vals:
        return 0.0
    k = min(len(sorted_vals) - 1,
            max(0, int(round(p / 100.0 * (len(sorted_vals) - 1)))))
    return sorted_vals[k]


# --------------------------------------------------------------------------
# metrics
# --------------------------------------------------------------------------
class Counter:
    """Monotone event count."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> int:
        self.value += n
        return self.value


class Gauge:
    """Last-written value (keeps the writer's numeric type)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def set(self, v: Any) -> None:
        self.value = v

    def add(self, v: Any) -> None:
        self.value += v


class Histogram:
    """Exact aggregates over the full stream + a bounded reservoir
    (sliding window of the most recent ``reservoir`` samples) for
    percentiles — see the module docstring for the policy."""

    def __init__(self, reservoir: int = DEFAULT_RESERVOIR) -> None:
        self.count = 0
        self.total = 0.0
        self.vmin: Optional[float] = None
        self.vmax: Optional[float] = None
        self.window: collections.deque = collections.deque(maxlen=reservoir)

    def observe(self, v: float) -> None:
        self.count += 1
        self.total += v
        self.vmin = v if self.vmin is None else min(self.vmin, v)
        self.vmax = v if self.vmax is None else max(self.vmax, v)
        self.window.append(v)

    @property
    def dropped(self) -> int:
        return self.count - len(self.window)

    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, p: float) -> float:
        return percentile(sorted(self.window), p)

    def summary(self) -> Dict[str, float]:
        return {"count": self.count, "total": self.total,
                "mean": self.mean(), "min": self.vmin or 0.0,
                "max": self.vmax or 0.0, "p50": self.percentile(50),
                "p95": self.percentile(95), "dropped": self.dropped}


class MetricsRegistry:
    """Name -> metric.  ``counter``/``gauge``/``histogram`` create on
    first use; re-requesting a name returns the same instance."""

    def __init__(self) -> None:
        self.counters: Dict[str, Counter] = {}
        self.gauges: Dict[str, Gauge] = {}
        self.histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge()
        return g

    def histogram(self, name: str,
                  reservoir: int = DEFAULT_RESERVOIR) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(reservoir)
        return h

    def value(self, name: str, default: Any = 0) -> Any:
        if name in self.counters:
            return self.counters[name].value
        if name in self.gauges:
            return self.gauges[name].value
        return default

    def snapshot(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {}
        out.update({k: c.value for k, c in self.counters.items()})
        out.update({k: g.value for k, g in self.gauges.items()})
        out.update({k: h.summary() for k, h in self.histograms.items()})
        return out


class StatsView(collections.abc.MutableMapping):
    """The ``last_stats`` facade: reads/writes look like a plain dict, but
    every scalar lives in the registry (as a gauge named ``prefix+key``),
    so existing consumers keep working unchanged while BENCH/exporters
    read the registry as the single source of truth.  Non-scalar values
    (lists like ``requests``, strings like ``policy``, bools) stay
    local."""

    def __init__(self, registry: MetricsRegistry, prefix: str = "",
                 init: Optional[Dict[str, Any]] = None) -> None:
        self._reg = registry
        self._prefix = prefix
        self._local: Dict[str, Any] = {}
        self._scalar: Dict[str, Gauge] = {}
        self._order: List[str] = []
        for k, v in (init or {}).items():
            self[k] = v

    def registry(self) -> MetricsRegistry:
        return self._reg

    def __setitem__(self, k: str, v: Any) -> None:
        if _is_scalar(v):
            g = self._scalar.get(k)
            if g is None:
                g = self._scalar[k] = self._reg.gauge(self._prefix + k)
            g.set(v)
            self._local.pop(k, None)
        else:
            self._local[k] = v
            self._scalar.pop(k, None)
        if k not in self._order:
            self._order.append(k)

    def __getitem__(self, k: str) -> Any:
        g = self._scalar.get(k)
        if g is not None:
            return g.value
        if k in self._local:
            return self._local[k]
        raise KeyError(k)

    def __delitem__(self, k: str) -> None:
        self._scalar.pop(k, None)
        self._local.pop(k, None)
        self._order.remove(k)

    def __iter__(self):
        return iter(self._order)

    def __len__(self) -> int:
        return len(self._order)

    def __repr__(self) -> str:
        return f"StatsView({dict(self)!r})"


class StepRing:
    """Bounded list-like replacement for the old ``stats["steps"]``:
    keeps the most recent ``cap`` per-dispatch records (FIFO drop beyond
    that, counted in ``dropped``) while supporting the list operations
    existing consumers use — iteration, ``len``, indexing and slicing."""

    def __init__(self, cap: int = DEFAULT_STEPS_CAP) -> None:
        self._q: collections.deque = collections.deque(maxlen=cap)
        self.dropped = 0

    def append(self, rec: Dict[str, Any]) -> None:
        if len(self._q) == self._q.maxlen:
            self.dropped += 1
        self._q.append(rec)

    def __len__(self) -> int:
        return len(self._q)

    def __iter__(self):
        return iter(self._q)

    def __getitem__(self, i):
        if isinstance(i, slice):
            return list(self._q)[i]
        return self._q[i]

    def __bool__(self) -> bool:
        return bool(self._q)

    def __repr__(self) -> str:
        return f"StepRing({list(self._q)!r}, dropped={self.dropped})"


# --------------------------------------------------------------------------
# tracing
# --------------------------------------------------------------------------
class Tracer:
    """Bounded buffer of lifecycle events.

    Every event carries the dual clock: ``wall`` (``perf_counter`` at
    record time, plus ``dur_ms`` for spans) and ``step`` (the engine /
    router dispatch-step counter the caller passes in).  Wall fields are
    for humans and Perfetto; the step clock plus the identity key
    ``(request, session, replica)`` and the free-form ``args`` form the
    deterministic view golden tests compare."""

    def __init__(self, max_events: int = DEFAULT_EVENTS_CAP) -> None:
        self.events: collections.deque = collections.deque(maxlen=max_events)
        self.dropped = 0

    def event(self, kind: str, *, step: Optional[int] = None,
              request: Optional[Any] = None, session: Optional[str] = None,
              replica: Optional[int] = None, slot: Optional[int] = None,
              dur_ms: Optional[float] = None, **args: Any) -> None:
        if len(self.events) == self.events.maxlen:
            self.dropped += 1
        self.events.append({
            "kind": kind, "wall": time.perf_counter(), "dur_ms": dur_ms,
            "step": step, "request": request, "session": session,
            "replica": replica, "slot": slot, "args": args,
        })

    def deterministic_view(self) -> List[Tuple]:
        """The reproducible projection: everything except wall-clock
        (``wall`` and ``dur_ms``) and except wall-derived args (any arg
        key ending in ``_ms`` or ``_s``)."""
        out = []
        for e in self.events:
            args = tuple(sorted((k, v) for k, v in e["args"].items()
                                if not (k.endswith("_ms") or k.endswith("_s"))))
            out.append((e["kind"], e["step"], e["request"], e["session"],
                        e["replica"], e["slot"], args))
        return out

    def kinds(self) -> List[str]:
        return [e["kind"] for e in self.events]


class Telemetry:
    """Per-component facade bundling one registry + one tracer.

    ``component`` labels the Chrome-trace process; ``replica`` (when the
    component is one of several engine replicas) labels its track group.
    ``set_tracing(False)`` stops event recording (the registry still
    counts) — the knob ``benchmarks/serve_bench.py::run_obs`` measures.
    """

    def __init__(self, component: str = "engine",
                 replica: Optional[int] = None, *,
                 steps_cap: int = DEFAULT_STEPS_CAP,
                 max_events: int = DEFAULT_EVENTS_CAP,
                 program_limit: int = 1) -> None:
        self.component = component
        self.replica = replica
        self.registry = MetricsRegistry()
        self.tracer = Tracer(max_events)
        self.tracing = True
        self.steps_cap = steps_cap
        # bounded-program-set alert threshold: compiles of one program
        # past this surface as ``alert.programs`` events + a counter
        self.program_limit = program_limit

    def set_tracing(self, on: bool) -> "Telemetry":
        self.tracing = bool(on)
        return self

    # -- recording ---------------------------------------------------------
    def event(self, kind: str, **kw: Any) -> None:
        if self.tracing:
            if kw.get("replica") is None and self.replica is not None:
                kw["replica"] = self.replica
            self.tracer.event(kind, **kw)

    def compile_event(self, program: str, **kw: Any) -> None:
        """Called from inside the ``per_engine`` jit wrapper: the wrapped
        python function only runs while jax traces a NEW program, so each
        call == one fresh compilation of ``program``.  Count it, trace
        it, and raise a telemetry alert once the bounded-program-set
        contract (<= ``program_limit`` per program) is violated."""
        n = self.registry.counter(f"compiles_{program}").inc()
        self.event(f"compile.{program}", count=n, **kw)
        if n > self.program_limit:
            self.registry.counter("alerts").inc()
            self.event("alert.programs", program=program, count=n, **kw)

    def stats_view(self, init: Optional[Dict[str, Any]] = None,
                   prefix: str = "") -> StatsView:
        return StatsView(self.registry, prefix, init)

    def steps_ring(self) -> StepRing:
        return StepRing(self.steps_cap)

    # -- derived views -----------------------------------------------------
    def request_summaries(self) -> Dict[Any, Dict[str, Any]]:
        return request_summaries(self.tracer)

    def alerts(self) -> int:
        return self.registry.value("alerts")


class _DispatchProbe:
    """What :func:`timed_dispatch` yields: the caller fills in what only
    it knows (``emitted``, optionally ``prefilling``) before the block
    exits."""

    __slots__ = ("emitted", "prefilling")

    def __init__(self, prefilling: int) -> None:
        self.emitted = 0
        self.prefilling = prefilling


class timed_dispatch:
    """The shared dispatch-timing helper (context manager) that replaces
    the triplicated ``t0 = perf_counter() ... stats["steps"].append(...)``
    snippet in ``ServeEngine.generate``, ``BlockingServeEngine.generate``
    and ``SLOPagedServeEngine.generate``::

        with timed_dispatch(tel, stats, prefilling=n) as td:
            ... dispatch + device_get ...
            td.emitted = int(va.sum())

    On exit it appends the step record ({"ms", "prefilling", "emitted"}
    (+"step" when a scheduler clock is passed), exactly the old shape),
    bumps ``stats["dispatches"]``, feeds the registry's ``dispatch_ms``
    histogram and ``emitted_tokens`` counter, and emits one
    ``engine.dispatch`` span on the step clock (the scheduler's ``step``
    when given, else the dispatch count)."""

    def __init__(self, telemetry: Optional[Telemetry],
                 stats: collections.abc.MutableMapping, *,
                 prefilling: int = 0, step: Optional[int] = None) -> None:
        self.tel = telemetry
        self.stats = stats
        self.step = step
        self.probe = _DispatchProbe(prefilling)

    def __enter__(self) -> _DispatchProbe:
        self.t0 = time.perf_counter()
        return self.probe

    def __exit__(self, etype, e, tb) -> bool:
        if etype is not None:
            return False
        dt_ms = (time.perf_counter() - self.t0) * 1e3
        p = self.probe
        rec = {"ms": dt_ms, "prefilling": p.prefilling, "emitted": p.emitted}
        if self.step is not None:
            rec["step"] = self.step
        self.stats["dispatches"] = self.stats.get("dispatches", 0) + 1
        self.stats["steps"].append(rec)
        if self.tel is not None:
            self.tel.registry.histogram("dispatch_ms").observe(dt_ms)
            self.tel.registry.counter("emitted_tokens").inc(p.emitted)
            self.tel.event("engine.dispatch", dur_ms=dt_ms,
                           step=self.step if self.step is not None
                           else self.stats["dispatches"],
                           prefilling=p.prefilling, emitted=p.emitted)
        return False


# --------------------------------------------------------------------------
# exporters
# --------------------------------------------------------------------------
def _as_telemetries(ts) -> List[Telemetry]:
    return [ts] if isinstance(ts, Telemetry) else list(ts)


def chrome_trace(telemetries) -> Dict[str, Any]:
    """Chrome trace-event JSON (the ``traceEvents`` array format both
    Perfetto and chrome://tracing load).  One *process* per telemetry
    component/replica, one *thread track* per slot (track 0 = events not
    tied to a slot).  Spans (events with ``dur_ms``) become complete
    ``"X"`` events; the rest are instants."""
    evs: List[Dict[str, Any]] = []
    for pid, tel in enumerate(_as_telemetries(telemetries)):
        pname = tel.component if tel.replica is None \
            else f"{tel.component}[{tel.replica}]"
        evs.append({"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
                    "args": {"name": pname}})
        tids = set()
        for e in tel.tracer.events:
            tid = 0 if e["slot"] is None else int(e["slot"]) + 1
            tids.add(tid)
            args = {k: v for k, v in (("step", e["step"]),
                                      ("request", e["request"]),
                                      ("session", e["session"]),
                                      ("replica", e["replica"]))
                    if v is not None}
            args.update(e["args"])
            ts_us = e["wall"] * 1e6
            ev = {"name": e["kind"], "pid": pid, "tid": tid,
                  "ts": ts_us, "args": args}
            if e["dur_ms"] is not None:
                ev.update(ph="X", ts=ts_us - e["dur_ms"] * 1e3,
                          dur=e["dur_ms"] * 1e3)
            else:
                ev.update(ph="i", s="t")
            evs.append(ev)
        for tid in sorted(tids):
            evs.append({"name": "thread_name", "ph": "M", "pid": pid,
                        "tid": tid,
                        "args": {"name": "scheduler" if tid == 0
                                 else f"slot {tid - 1}"}})
    return {"traceEvents": evs, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, telemetries) -> Dict[str, Any]:
    doc = chrome_trace(telemetries)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


def _prom_name(prefix: str, name: str) -> str:
    out = "".join(c if c.isalnum() or c == "_" else "_" for c in name)
    return prefix + out


def prometheus_text(telemetries, prefix: str = "repro_") -> str:
    """Prometheus text exposition (format 0.0.4).  Histograms export as
    Prometheus summaries (quantile series + ``_sum``/``_count``)."""
    lines: List[str] = []
    for tel in _as_telemetries(telemetries):
        label = f'component="{tel.component}"'
        if tel.replica is not None:
            label += f',replica="{tel.replica}"'
        reg = tel.registry
        for name, c in sorted(reg.counters.items()):
            n = _prom_name(prefix, name)
            lines += [f"# TYPE {n} counter", f"{n}{{{label}}} {c.value}"]
        for name, g in sorted(reg.gauges.items()):
            v = g.value
            if not _is_scalar(v):
                continue
            n = _prom_name(prefix, name)
            lines += [f"# TYPE {n} gauge", f"{n}{{{label}}} {v}"]
        for name, h in sorted(reg.histograms.items()):
            n = _prom_name(prefix, name)
            lines.append(f"# TYPE {n} summary")
            for q in (0.5, 0.95):
                lines.append(f'{n}{{{label},quantile="{q}"}} '
                             f"{h.percentile(q * 100)}")
            lines += [f"{n}_sum{{{label}}} {h.total}",
                      f"{n}_count{{{label}}} {h.count}"]
    return "\n".join(lines) + "\n"


def write_prometheus(path: str, telemetries, prefix: str = "repro_") -> str:
    text = prometheus_text(telemetries, prefix)
    with open(path, "w") as f:
        f.write(text)
    return text


def request_summaries(tracer: Tracer) -> Dict[Any, Dict[str, Any]]:
    """Reconstruct per-request summaries from lifecycle events alone —
    the exporter behind ``--trace-out``'s summary and the cross-check
    that trace spans reproduce the scheduler's own accounting.

    Per request id: ``queued_step`` / ``admit_step`` / ``queue_wait``
    (steps from arrival to first admission), ``first_emit`` /
    ``last_emit`` / ``ttft`` (steps from arrival to first token),
    ``itl_p50`` / ``itl_p95`` / ``max_gap`` (inter-token gaps on the
    step clock), ``n_emitted``, ``preemptions``, ``prefix_hit_tokens``,
    and wall-clock ``ttft_ms`` when wall data is present."""
    out: Dict[Any, Dict[str, Any]] = {}

    def rec(rid) -> Dict[str, Any]:
        r = out.get(rid)
        if r is None:
            r = out[rid] = {
                "request": rid, "session": None, "queued_step": None,
                "admit_step": None, "queue_wait": None, "first_emit": None,
                "last_emit": None, "ttft": None, "ttft_ms": None,
                "itl_p50": 0, "itl_p95": 0, "max_gap": 0, "n_emitted": 0,
                "preemptions": 0, "prefix_hit_tokens": 0,
                "_emit_steps": [], "_queued_wall": None,
            }
        return r

    for e in tracer.events:
        rid = e["request"]
        if rid is None:
            continue
        r = rec(rid)
        if e["session"] is not None:
            r["session"] = e["session"]
        k, step = e["kind"], e["step"]
        if k == "request.queued":
            r["queued_step"] = step
            r["_queued_wall"] = e["wall"]
        elif k in ("request.admit", "request.resume"):
            if r["admit_step"] is None:
                r["admit_step"] = step
                if r["queued_step"] is not None:
                    r["queue_wait"] = step - r["queued_step"]
            r["prefix_hit_tokens"] += e["args"].get("prefix_hit", 0)
        elif k == "request.emit":
            n = e["args"].get("n", 1)
            r["n_emitted"] += n
            r["_emit_steps"].append(step)
            if r["first_emit"] is None:
                r["first_emit"] = step
                base = r["queued_step"] if r["queued_step"] is not None \
                    else r["admit_step"]
                if base is not None and step is not None:
                    r["ttft"] = step - base
                if r["_queued_wall"] is not None:
                    r["ttft_ms"] = (e["wall"] - r["_queued_wall"]) * 1e3
            r["last_emit"] = step
        elif k == "request.preempt":
            r["preemptions"] += 1

    for r in out.values():
        steps = [s for s in r.pop("_emit_steps") if s is not None]
        gaps = [b - a for a, b in zip(steps, steps[1:])]
        r.pop("_queued_wall")
        if gaps:
            g = sorted(gaps)
            r["itl_p50"] = percentile(g, 50)
            r["itl_p95"] = percentile(g, 95)
            r["max_gap"] = g[-1]
    return out
