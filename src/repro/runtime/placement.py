"""Backend-aware memory placement: the single audited layer for every
device/host residency decision in the codebase.

The paper's memory headroom comes from offloading idle KV/query chunks to
host memory and double-buffering the fetch so it hides behind chunk compute
(FPDT Fig. 6-7).  Whether that is *possible* — and which memory-kind strings
name the two pools — is a backend property:

  backend   | memory kinds advertised          | offload
  ----------|----------------------------------|---------------------------
  TPU       | ``device``, ``pinned_host``      | supported
  GPU       | ``device``, ``pinned_host``      | supported
  CPU       | ``unpinned_host`` (default only) | no distinct pool -> no-op

The seed hardcoded ``memory_kind="device"/"pinned_host"`` at every call
site, which crashes with ``ValueError: Could not find memory addressable by
device cpu`` anywhere the backend doesn't advertise those kinds.
``PlacementPolicy`` probes the backend once (``device.addressable_memories()``
/ ``device.default_memory()``), records the compute and offload memory
kinds, and degrades gracefully: on a backend with no distinct host pool,
``to_host``/``to_device`` are identity functions and a warning is logged
once, so the FPDT pipeline runs the same program on CPU, GPU, and TPU.

All ``jax.device_put`` / ``memory_kind`` decisions route through this
module (enforced: ``grep -rn 'memory_kind=' src/ | grep -v placement``
must return nothing).  See ``docs/placement.md`` for the support matrix.
"""
from __future__ import annotations

import dataclasses
import functools
import logging
from typing import Any, Callable, Iterable, Iterator, Optional, Sequence, TypeVar

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

log = logging.getLogger(__name__)

# The memory-kind names backends advertise for the two pools the FPDT
# schedule cares about (compute-resident vs. offloaded-idle).
HOST_MEMORY_KIND = "pinned_host"

_WARNED: set = set()


def _warn_once(key: str, msg: str) -> None:
    if key not in _WARNED:
        _WARNED.add(key)
        log.warning(msg)


@dataclasses.dataclass(frozen=True)
class PlacementPolicy:
    """Immutable record of one backend's memory capabilities.

    Frozen + hashable on purpose: it rides inside ``ParallelContext``,
    which keys the per-config ``lru_cache`` of compiled FPDT pipelines.

    ``device_kind``   — the backend's default (compute) memory kind.
    ``host_kind``     — the offload pool, or ``None`` when the backend has
                        no host pool distinct from its default memory.
    ``offload_enabled`` — operator switch; ``False`` forces no-op placement
                        even on capable backends (the dry-run uses this).
    """

    device_kind: Optional[str] = None
    host_kind: Optional[str] = None
    backend: str = "unknown"
    offload_enabled: bool = True

    # -- capability probe ------------------------------------------------
    @classmethod
    def probe(cls, device: Optional[Any] = None, *,
              offload_enabled: bool = True) -> "PlacementPolicy":
        """Inspect one device's memory spaces (once; result is immutable)."""
        if device is None:
            device = jax.devices()[0]
        try:
            kinds = {m.kind for m in device.addressable_memories()}
        except Exception:  # very old jax: no memories API at all
            kinds = set()
        try:
            default = device.default_memory().kind
        except Exception:
            default = None
        host = HOST_MEMORY_KIND if HOST_MEMORY_KIND in kinds else None
        if host is not None and host == default:
            # a "host" pool that IS the default memory is not an offload
            # target (there is nowhere to offload *from*)
            host = None
        return cls(device_kind=default, host_kind=host,
                   backend=getattr(device, "platform", "unknown"),
                   offload_enabled=offload_enabled)

    # -- capabilities ----------------------------------------------------
    @property
    def supports_pinned_host(self) -> bool:
        """Backend advertises a pinned-host pool distinct from compute memory."""
        return self.host_kind is not None

    @property
    def can_offload(self) -> bool:
        """Offload is both possible (backend) and enabled (operator)."""
        return self.offload_enabled and self.supports_pinned_host

    def _noop(self, verb: str):
        _warn_once(
            f"{self.backend}:{verb}",
            f"[placement] {verb} requested but backend '{self.backend}' has "
            f"no '{HOST_MEMORY_KIND}' memory distinct from its default "
            f"('{self.device_kind}'); offload degrades to a no-op.",
        )

    # -- sharding construction ------------------------------------------
    def ns(self, mesh: Optional[Mesh], *spec, on_host: bool = False
           ) -> Optional[NamedSharding]:
        """NamedSharding over ``mesh`` with the policy's memory kind.

        ``on_host=True`` targets the offload pool when supported and
        silently falls back to a plain (default-memory) sharding when not —
        callers never name a memory kind themselves.
        """
        if mesh is None:
            return None
        kw = {}
        if self.can_offload:
            kw["memory_kind"] = self.host_kind if on_host else self.device_kind
        return NamedSharding(mesh, P(*spec), **kw)

    def host_sharding(self, mesh: Optional[Mesh], *spec) -> Optional[NamedSharding]:
        return self.ns(mesh, *spec, on_host=True)

    def device_sharding(self, mesh: Optional[Mesh], *spec) -> Optional[NamedSharding]:
        return self.ns(mesh, *spec, on_host=False)

    def _single(self, on_host: bool):
        kind = self.host_kind if on_host else self.device_kind
        return jax.sharding.SingleDeviceSharding(
            jax.devices()[0], **({"memory_kind": kind} if kind else {}))

    # -- placement ops ---------------------------------------------------
    def to_host(self, x, mesh: Optional[Mesh] = None,
                spec: Sequence = ()):  # noqa: ANN001 - jax array/tracer
        """Move ``x`` to the offload pool; identity on incapable backends."""
        if not self.can_offload:
            self._noop("to_host")
            return x
        s = self._single(True) if mesh is None else self.host_sharding(mesh, *spec)
        return jax.device_put(x, s)

    def to_device(self, x, mesh: Optional[Mesh] = None, spec: Sequence = ()):
        """Fetch ``x`` back into compute memory; identity when no offload."""
        if not self.can_offload:
            self._noop("to_device")
            return x
        s = self._single(False) if mesh is None else self.device_sharding(mesh, *spec)
        return jax.device_put(x, s)

    def put(self, x, sharding=None):
        """Audited passthrough for plain (default-memory) ``device_put`` —
        checkpoint restore, batch staging.  Never names a memory kind."""
        return jax.device_put(x, sharding) if sharding is not None else jax.device_put(x)

    # -- remat offload ---------------------------------------------------
    def remat_policy(self, offload_names: Sequence[str] = ("block_in",)):
        """Checkpoint policy for ``remat='offload'``: offload the named
        residuals to the host pool, falling back to full remat (save
        nothing) when the backend can't host-offload."""
        if not self.can_offload:
            self._noop("remat-offload")
            return jax.checkpoint_policies.nothing_saveable
        return jax.checkpoint_policies.save_and_offload_only_these_names(
            names_which_can_be_saved=[],
            names_which_can_be_offloaded=list(offload_names),
            offload_src=self.device_kind or "device",
            offload_dst=self.host_kind,
        )


@functools.lru_cache(maxsize=None)
def default_policy(offload_enabled: bool = True) -> PlacementPolicy:
    """The process-wide policy for the default backend (probed once)."""
    return PlacementPolicy.probe(offload_enabled=offload_enabled)


# ---------------------------------------------------------------------------
# explicit double buffering
# ---------------------------------------------------------------------------

T = TypeVar("T")
U = TypeVar("U")


def double_buffered(items: Iterable[T], fetch: Callable[[T], U]) -> Iterator[U]:
    """Two-deep prefetch pipeline over ``items`` (FPDT Fig. 6).

    Yields ``fetch(item_k)`` with the guarantee that ``fetch(item_{k+1})``
    has already been *issued* before the consumer runs compute on item k:
    the fetch (a ``device_put`` copy-start on offload-capable backends)
    precedes the chunk kernel in program order, so the host->device copy
    overlaps chunk compute explicitly instead of relying on XLA to discover
    the independence.
    """
    seq = list(items)
    if not seq:
        return
    ahead = fetch(seq[0])
    for k in range(len(seq)):
        cur = ahead
        ahead = fetch(seq[k + 1]) if k + 1 < len(seq) else None
        yield cur


def fori_double_buffered(lo, hi, fetch: Callable, body: Callable, init,
                         *, live: Optional[Callable] = None):
    """Scan-carry variant of ``double_buffered`` for traced chunk loops.

    Runs ``carry = body(idx, fetch(idx), carry)`` for ``idx`` in ``[lo, hi)``
    — ``lo``/``hi`` may be traced (lowers to a while loop) — with the same
    Fig. 6 guarantee as the generator version: the fetched value consumed at
    iteration ``idx`` is carried in the loop state and the *next* consumed
    chunk's fetch is issued *before* ``body(idx)``'s kernels in program
    order, so on offload-capable backends the host->device copy of the next
    chunk overlaps the current chunk's compute.

    Carry contract (everything rides a ``fori_loop`` state, so all of it
    must be shape/dtype-stable across iterations):
      * the loop state is ``(prefetch_buffer, carry)``; ``fetch(idx)`` must
        return the same pytree structure/shapes/dtypes for every ``idx``
        (it is probed once via ``jax.eval_shape`` on the live path);
      * ``init`` must match the structure ``body`` returns — ``body`` is
        traced once and may not change the carry's shape;
      * ``fetch``/``body``/``live`` must be pure; ``fetch`` runs under
        ``lax.cond`` on the live path, so its placement ops must be legal
        in traced context (``device_put`` with memory-kind shardings is).
    Returns the final user carry (the prefetch buffer is discarded; the
    tail iteration's clamped prefetch is never consumed).

    ``live(idx) -> bool tracer`` optionally restricts the schedule to live
    indices: dead (window/sparsity-skipped) iterations are complete no-ops
    — no fetch, no body — and each live iteration prefetches the next
    *live* index (a traced search, mirroring the unrolled path's
    ``double_buffered(live_items, fetch)`` over the filtered item list), so
    sparse schedules keep the copy/compute overlap instead of issuing
    fetches from skipped iterations that overlap nothing.
    """
    lo = jnp.asarray(lo, jnp.int32)
    hi = jnp.asarray(hi, jnp.int32)

    def clamp(idx):
        return jnp.clip(idx, 0, jnp.maximum(hi - 1, 0))

    if live is None:
        def step(idx, state):
            buf, carry = state
            nxt = fetch(clamp(idx + 1))  # clamped tail prefetch: never consumed
            carry = body(idx, buf, carry)
            return nxt, carry

        buf0 = fetch(clamp(lo))
        _, carry = jax.lax.fori_loop(lo, hi, step, (buf0, init))
        return carry

    def next_live(idx):
        """Smallest live index in (idx, hi); hi when none (live() must be
        pure index arithmetic — it is probed past the range)."""
        return jax.lax.while_loop(
            lambda t: (t < hi) & ~live(t), lambda t: t + 1, idx + 1)

    def zeros_like_fetch():
        shapes = jax.eval_shape(fetch, clamp(lo))
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)

    first = next_live(lo - 1)
    buf0 = jax.lax.cond(first < hi, lambda: fetch(clamp(first)), zeros_like_fetch)

    def step(idx, state):
        buf, carry = state

        def live_step():
            nxt = next_live(idx)
            nbuf = jax.lax.cond(nxt < hi, lambda: fetch(clamp(nxt)), lambda: buf)
            return nbuf, body(idx, buf, carry)

        return jax.lax.cond(live(idx), live_step, lambda: (buf, carry))

    _, carry = jax.lax.fori_loop(lo, hi, step, (buf0, init))
    return carry
