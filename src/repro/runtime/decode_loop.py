"""Scan-compiled multi-token decode engine (FPDT-style serving).

``models/serve.py`` owns the single-step primitives (prefill, one-token
decode against the cache); this module owns the *loop*:

* ``decode_tokens`` — ONE ``lax.scan`` over generation steps.  The decode
  body (a full layer-cycle scan, optionally with host-chunked KV streaming)
  is traced once, so program size is flat in the number of generated tokens
  — the per-token Python loop it replaces re-dispatched a jitted call per
  token and paid host latency on every step.  Greedy and temperature/top-k
  sampling, per-sequence stop-token and budget handling.
* ``ServeEngine`` — continuous batching on top: a fixed number of cache
  slots, variable-length prompts prefilled position-masked into a common
  bucket, finished sequences harvested between scan segments and their
  slots re-used for queued prompts.

Measured by ``benchmarks/serve_bench.py``; architecture notes in
``docs/serving.md``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.core.parallel import ParallelContext
from repro.models import serve as SV

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SamplingConfig:
    """``temperature <= 0`` selects greedy argmax (the default); otherwise
    categorical sampling at the given temperature, optionally restricted to
    the ``top_k`` highest-probability tokens (0 = full vocabulary).

    Frozen + hashable so it can close over a jitted decode loop."""

    temperature: float = 0.0
    top_k: int = 0


GREEDY = SamplingConfig()


def sample_token(logits: jnp.ndarray, key, sc: SamplingConfig = GREEDY) -> jnp.ndarray:
    """logits [b, V] fp32 -> sampled token ids [b] int32."""
    if sc.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if sc.top_k:
        kth = jax.lax.top_k(logits, sc.top_k)[0][:, -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(key, logits / sc.temperature, axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# scan-compiled multi-token decode
# ---------------------------------------------------------------------------


def decode_tokens(cfg: ModelConfig, par: Optional[ParallelContext], params: Params,
                  cache: Params, tok: jnp.ndarray, pos: jnp.ndarray, *,
                  num_steps: int, n_host_chunks: int = 0,
                  sampling: SamplingConfig = GREEDY,
                  stop_tokens: Sequence[int] = (), pad_id: int = 0,
                  key: Optional[jnp.ndarray] = None,
                  done: Optional[jnp.ndarray] = None,
                  remaining: Optional[jnp.ndarray] = None,
                  collect_logits: bool = False):
    """Generate up to ``num_steps`` tokens per sequence with one ``lax.scan``.

    Carry contract (shape/dtype-stable across steps, scan-compatible):
      cache      — decode cache pytree (``models/serve.py`` layouts);
      tok [b,1]  — the token each sequence feeds NEXT.  The caller samples
                   the first token from the prefill logits, so the full
                   generation is ``[tok0, *emitted]``;
      pos [b]    — the position ``tok`` occupies; frozen once a row is done;
      key        — PRNG carry (split every step; unused under greedy);
      done [b]   — finished rows emit ``pad_id``, stop advancing ``pos``,
                   and stop consuming budget.  Their dummy decode writes
                   land at the frozen ``pos`` slot, which is rewritten by
                   the next prefill when the slot is re-used;
      remaining [b] — per-row emission budget; a row finishes after
                   emitting ``remaining`` tokens or a ``stop_tokens`` hit
                   (the stop token itself is emitted).

    Step t feeds ``tok`` at ``pos``, samples from the resulting logits, and
    emits the SAMPLED token — identical to the per-token loop
    ``outs.append(sample(decode(cache, outs[-1], pos)))``.

    Returns ``(tokens [b, num_steps] int32, aux)`` with
    ``aux = {cache, tok, pos, key, done, remaining[, logits]}`` — exactly
    the carry, so segments chain: feed ``aux`` back in to continue (the
    continuous-batching engine decodes in segments and harvests/refills
    between them).  ``aux["remaining"]`` deltas give per-row emission
    counts; ``collect_logits`` adds the per-step pre-sampling logits
    ``[num_steps, b, vocab]`` (parity tests only — it scales with vocab).
    """
    if cfg.frontend == "audio_frames":
        raise ValueError("decode_tokens feeds token ids; the audio_frames "
                         "frontend consumes frame embeddings — drive "
                         "decode_step directly for frame synthesis")
    b = tok.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    key = jax.random.PRNGKey(0) if key is None else key
    done = jnp.zeros((b,), bool) if done is None else done
    if remaining is None:
        remaining = jnp.full((b,), num_steps + 1, jnp.int32)
    remaining = jnp.asarray(remaining, jnp.int32)
    done = done | (remaining <= 0)
    stop = jnp.asarray(tuple(stop_tokens), jnp.int32)

    def step(carry, _):
        cache, tok, pos, key, was_done, rem = carry
        key, sub = jax.random.split(key)
        logits, cache = SV.decode_step(cfg, par, params, cache, {"tokens": tok},
                                       pos, n_host_chunks=n_host_chunks)
        lv = logits[:, : cfg.vocab_size]
        nxt = sample_token(lv, sub, sampling)
        rem = rem - jnp.where(was_done, 0, 1)
        emit = jnp.where(was_done, pad_id, nxt)  # the stop token itself is emitted
        done = was_done | jnp.isin(nxt, stop) | (rem <= 0)
        pos = jnp.where(was_done, pos, pos + 1)
        return (cache, emit[:, None], pos, key, done, rem), (
            emit, lv if collect_logits else None)

    carry0 = (cache, tok.astype(jnp.int32), pos, key, done, remaining)
    (cache, tok, pos, key, done, remaining), (toks, logits) = jax.lax.scan(
        step, carry0, None, length=num_steps)
    aux = {"cache": cache, "tok": tok, "pos": pos, "key": key,
           "done": done, "remaining": remaining}
    if collect_logits:
        aux["logits"] = logits
    return toks.T, aux


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------


def _batch_axis(path) -> int:
    """Batch-dim axis of a cache leaf: stacked cycle leaves are [C, b, ...],
    tail leaves [b, ...] (mirrors ``SV.cache_shardings``)."""
    names = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
    return 0 if names[0] == "tail" else 1


def insert_slot(cache: Params, one: Params, i) -> Params:
    """Write a single-sequence (b=1) cache ``one`` into batch slot ``i`` of
    ``cache`` — the slot-reuse primitive of continuous batching."""
    def put(path, cb, c1):
        return jax.lax.dynamic_update_slice_in_dim(
            cb, c1.astype(cb.dtype), i, axis=_batch_axis(path))

    return jax.tree_util.tree_map_with_path(put, cache, one)


class ServeEngine:
    """Continuous batching over ``slots`` concurrent cache rows.

    Prompts are right-padded into a fixed ``bucket`` length and prefilled
    position-masked (``prefill_step(..., lengths=...)``), decode runs in
    jitted ``decode_tokens`` segments of ``segment`` steps, and between
    segments finished rows are harvested and their slots re-prefilled with
    queued prompts — three compiled programs total (batched prefill,
    single-row refill prefill, decode segment) regardless of workload mix.

    Variable prompt lengths require a pure global-attention layout (see
    ``prefill_step``); recurrent archs can still use the engine when every
    prompt exactly fills the bucket — no pad tokens, so prefill runs
    unmasked (``lengths=None``) and stop tokens / budgets stagger finishes.
    """

    def __init__(self, cfg: ModelConfig, params: Params, *, slots: int,
                 bucket: int, max_new_tokens: int,
                 n_host_chunks: int = 0, sampling: SamplingConfig = GREEDY,
                 stop_tokens: Sequence[int] = (), pad_id: int = 0,
                 segment: int = 8, par: Optional[ParallelContext] = None):
        self.cfg, self.params, self.par = cfg, params, par
        self.slots, self.bucket = slots, bucket
        self.max_new = max_new_tokens
        self.max_len = bucket + max_new_tokens
        self.sampling, self.pad_id = sampling, pad_id
        self.segment = segment
        stop_tokens = tuple(stop_tokens)
        self._stop_set = frozenset(int(t) for t in stop_tokens)
        if n_host_chunks and self.max_len % n_host_chunks:
            # models/serve.py silently falls back to on-device attention for
            # non-dividing chunk counts — the operator would be serving a
            # different program than requested
            raise ValueError(
                f"n_host_chunks={n_host_chunks} does not divide the cache "
                f"length bucket+max_new_tokens={self.max_len}; host-KV "
                f"streaming requires equal slabs")

        def prefill(toks, lengths):
            return SV.prefill_step(cfg, par, params, {"tokens": toks},
                                   max_len=self.max_len, lengths=lengths)

        self._prefill = jax.jit(prefill)

        def decode_seg(cache, tok, pos, key, done, rem):
            return decode_tokens(cfg, par, params, cache, tok, pos,
                                 num_steps=segment, n_host_chunks=n_host_chunks,
                                 sampling=sampling, stop_tokens=stop_tokens,
                                 pad_id=pad_id, key=key, done=done,
                                 remaining=rem)

        self._decode = jax.jit(decode_seg)
        self._insert = jax.jit(insert_slot)

    # -- helpers ---------------------------------------------------------
    def _pad(self, rows: List[List[int]]) -> Tuple[jnp.ndarray, jnp.ndarray]:
        lengths = [len(r) for r in rows]
        assert all(0 < n <= self.bucket for n in lengths), \
            f"prompt lengths {lengths} must be in (0, bucket={self.bucket}]"
        toks = jnp.asarray(
            [list(r) + [self.pad_id] * (self.bucket - len(r)) for r in rows],
            jnp.int32)
        return toks, jnp.asarray(lengths, jnp.int32)

    # -- the scheduler ---------------------------------------------------
    def generate(self, prompts: Sequence[Sequence[int]],
                 key: Optional[jnp.ndarray] = None) -> List[List[int]]:
        """Run every prompt to completion (stop token or ``max_new_tokens``),
        re-using slots as sequences finish.  Returns one generated-token
        list per prompt (stop token included when one fired), in order."""
        key = jax.random.PRNGKey(0) if key is None else key
        queue = list(enumerate(prompts))
        out: List[List[int]] = [[] for _ in prompts]
        B = self.slots

        # initial fill: pad the first B prompts into one batched prefill;
        # short queues fill trailing slots with a dummy row that starts done
        first = queue[:B]
        queue = queue[B:]
        rows = [list(p) for _, p in first] + [[self.pad_id] * self.bucket] * (B - len(first))
        toks, lengths = self._pad(rows)
        # no pad tokens -> unmasked prefill (lengths=None): this is the path
        # recurrent layouts can take, since prefill_step refuses lengths=...
        no_pads = all(len(r) == self.bucket for r in rows)
        logits, cache = self._prefill(toks, None if no_pads else lengths)
        key, sub = jax.random.split(key)
        tok = sample_token(logits[:, : self.cfg.vocab_size], sub, self.sampling)
        owner: List[Optional[int]] = [i for i, _ in first] + [None] * (B - len(first))
        for s, o in enumerate(owner):
            if o is not None:
                out[o].append(int(tok[s]))
        pos = lengths
        # a prefill-sampled first token may itself be a stop token (or the
        # whole budget): such rows start done and are refilled at the next
        # harvest, never entering the scan as live
        done = jnp.asarray([o is None or int(tok[s]) in self._stop_set
                            or self.max_new <= 1
                            for s, o in enumerate(owner)])
        rem = jnp.full((B,), self.max_new - 1, jnp.int32)
        tok = tok[:, None]

        while not all(o is None for o in owner):
            rem_before = rem
            toks_seg, aux = self._decode(cache, tok, pos, key, done, rem)
            cache, tok, pos, key = aux["cache"], aux["tok"], aux["pos"], aux["key"]
            done, rem = aux["done"], aux["remaining"]
            emitted = jax.device_get(rem_before - rem)
            seg_host = jax.device_get(toks_seg)
            done_host = jax.device_get(done)
            for s in range(B):
                if owner[s] is None:
                    continue
                out[owner[s]].extend(int(t) for t in seg_host[s, : emitted[s]])
                if not done_host[s]:
                    continue
                if not queue:  # finished, nothing queued: park the slot
                    owner[s] = None
                    continue
                # slot reuse: single-row position-masked prefill + insert
                idx, prompt = queue.pop(0)
                toks1, len1 = self._pad([list(prompt)])
                logits1, cache1 = self._prefill(
                    toks1, None if len(prompt) == self.bucket else len1)
                key, sub = jax.random.split(key)
                t0 = sample_token(logits1[:, : self.cfg.vocab_size], sub,
                                  self.sampling)
                cache = self._insert(cache, cache1, s)
                owner[s] = idx
                out[idx].append(int(t0[0]))
                tok = tok.at[s].set(t0)
                pos = pos.at[s].set(len1[0])
                done = done.at[s].set(int(t0[0]) in self._stop_set
                                      or self.max_new <= 1)
                rem = rem.at[s].set(self.max_new - 1)
        return out
