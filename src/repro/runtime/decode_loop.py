"""Scan-compiled multi-token decode engine (FPDT-style serving).

``models/serve.py`` owns the single-step primitives (prefill, one-token
decode against the cache); this module owns the *loop*:

* ``decode_tokens`` — ONE ``lax.scan`` over generation steps.  The decode
  body (a full layer-cycle scan, optionally with host-chunked KV streaming)
  is traced once, so program size is flat in the number of generated tokens
  — the per-token Python loop it replaces re-dispatched a jitted call per
  token and paid host latency on every step.  Greedy and temperature/top-k
  sampling, per-sequence stop-token and budget handling.
* ``ServeEngine`` — continuous batching via the **fused mixed-step
  scheduler**: ONE compiled program per step that, for every cache slot,
  either consumes one prefill chunk or decodes one token, selected by a
  per-slot traced state machine (``FREE / PREFILL / DECODE``) carried
  through the scan — so a refilling slot's prompt streams in
  chunk-by-chunk *under* the other slots' decode steps (ChunkFlow-style,
  the serving-side dual of the FPDT sequence-chunk pipeline), and prompts
  longer than the bucket are legal (they just take more chunks).
* ``BlockingServeEngine`` — the PR 3 three-program engine (batched
  prefill, decode segment, synchronous single-row refill prefill), kept
  as the measured stall baseline for ``benchmarks/serve_bench.py``.

Measured by ``benchmarks/serve_bench.py``; architecture notes in
``docs/serving.md``.
"""
from __future__ import annotations

import collections
import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ModelConfig
from repro.core.parallel import ParallelContext
from repro.models import serve as SV
from repro.runtime import telemetry as TM

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SamplingConfig:
    """``temperature <= 0`` selects greedy argmax (the default); otherwise
    categorical sampling at the given temperature, optionally restricted to
    the ``top_k`` highest-probability tokens (0 = full vocabulary).

    Frozen + hashable so it can close over a jitted decode loop."""

    temperature: float = 0.0
    top_k: int = 0


GREEDY = SamplingConfig()


def sample_token(logits: jnp.ndarray, key, sc: SamplingConfig = GREEDY) -> jnp.ndarray:
    """logits [b, V] fp32 -> sampled token ids [b] int32."""
    if sc.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if sc.top_k:
        kth = jax.lax.top_k(logits, sc.top_k)[0][:, -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(key, logits / sc.temperature, axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# requests (QoS contract for the SLO-aware scheduler)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Request:
    """One queued generation request plus its QoS contract.

    Plain prompts (token sequences) coerce to default requests via
    :func:`as_request`, so every engine ``generate`` keeps accepting raw
    token lists.  The extra fields only matter to the SLO-aware scheduler
    (``runtime/paged.py::SLOPagedServeEngine``); the FIFO engines ignore
    them:

      priority       — admission class, LOWER value = more urgent (0 =
                       interactive tier).  The SLO scheduler admits
                       strictly by (priority, itl_slo) and may preempt a
                       decoding request to seat a strictly more urgent
                       one;
      arrival        — the dispatch step at which the request becomes
                       visible to the scheduler (the traffic simulator's
                       deterministic clock; 0 = already queued);
      itl_slo        — inter-token-latency deadline in dispatch steps
                       (the tie-break within a priority class: tightest
                       deadline first, EDF-style).  ``inf`` = no deadline;
      prefill_chunks — per-request prefill budget: at most this many
                       prefill chunks per burst before the scheduler
                       pauses the prefill for one segment so co-resident
                       decodes get a chunk-free (fast-path) step
                       (0 = engine default / unlimited);
      tier           — free-form label carried into per-request stats
                       (the benchmark's goodput-under-SLO accounting);
      session        — routing-affinity id read by ``launch/router.py``
                       (requests of one session hash to one replica, and
                       re-home together on replica death); ``None`` =
                       route by prompt-prefix hash.  Engines ignore it.
    """

    tokens: Tuple[int, ...]
    priority: int = 1
    arrival: int = 0
    itl_slo: float = math.inf
    prefill_chunks: int = 0
    tier: str = ""
    session: Optional[str] = None


def as_request(r: Union[Request, Sequence[int]]) -> Request:
    """Coerce a raw prompt (token sequence) into a default :class:`Request`;
    pass real requests through untouched."""
    if isinstance(r, Request):
        return r
    return Request(tokens=tuple(int(t) for t in r))


# ---------------------------------------------------------------------------
# scan-compiled multi-token decode
# ---------------------------------------------------------------------------


def decode_tokens(cfg: ModelConfig, par: Optional[ParallelContext], params: Params,
                  cache: Params, tok: jnp.ndarray, pos: jnp.ndarray, *,
                  num_steps: int, n_host_chunks: int = 0,
                  sampling: SamplingConfig = GREEDY,
                  stop_tokens: Sequence[int] = (), pad_id: int = 0,
                  key: Optional[jnp.ndarray] = None,
                  done: Optional[jnp.ndarray] = None,
                  remaining: Optional[jnp.ndarray] = None,
                  collect_logits: bool = False):
    """Generate up to ``num_steps`` tokens per sequence with one ``lax.scan``.

    Carry contract (shape/dtype-stable across steps, scan-compatible):
      cache      — decode cache pytree (``models/serve.py`` layouts);
      tok [b,1]  — the token each sequence feeds NEXT.  The caller samples
                   the first token from the prefill logits, so the full
                   generation is ``[tok0, *emitted]``;
      pos [b]    — the position ``tok`` occupies; frozen once a row is done;
      key        — PRNG carry (split every step; unused under greedy);
      done [b]   — finished rows emit ``pad_id``, stop advancing ``pos``,
                   and stop consuming budget.  Their dummy decode writes
                   land at the frozen ``pos`` slot, which is rewritten by
                   the next prefill when the slot is re-used;
      remaining [b] — per-row emission budget; a row finishes after
                   emitting ``remaining`` tokens or a ``stop_tokens`` hit
                   (the stop token itself is emitted).

    Step t feeds ``tok`` at ``pos``, samples from the resulting logits, and
    emits the SAMPLED token — identical to the per-token loop
    ``outs.append(sample(decode(cache, outs[-1], pos)))``.

    Returns ``(tokens [b, num_steps] int32, aux)`` with
    ``aux = {cache, tok, pos, key, done, remaining[, logits]}`` — exactly
    the carry, so segments chain: feed ``aux`` back in to continue (the
    continuous-batching engine decodes in segments and harvests/refills
    between them).  ``aux["remaining"]`` deltas give per-row emission
    counts; ``collect_logits`` adds the per-step pre-sampling logits
    ``[num_steps, b, vocab]`` (parity tests only — it scales with vocab).
    """
    if cfg.frontend == "audio_frames":
        raise ValueError("decode_tokens feeds token ids; the audio_frames "
                         "frontend consumes frame embeddings — drive "
                         "decode_step directly for frame synthesis")
    b = tok.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    key = jax.random.PRNGKey(0) if key is None else key
    done = jnp.zeros((b,), bool) if done is None else done
    if remaining is None:
        remaining = jnp.full((b,), num_steps + 1, jnp.int32)
    remaining = jnp.asarray(remaining, jnp.int32)
    done = done | (remaining <= 0)
    stop = jnp.asarray(tuple(stop_tokens), jnp.int32)

    def step(carry, _):
        cache, tok, pos, key, was_done, rem = carry
        key, sub = jax.random.split(key)
        logits, cache = SV.decode_step(cfg, par, params, cache, {"tokens": tok},
                                       pos, n_host_chunks=n_host_chunks)
        lv = logits[:, : cfg.vocab_size]
        nxt = sample_token(lv, sub, sampling)
        rem = rem - jnp.where(was_done, 0, 1)
        emit = jnp.where(was_done, pad_id, nxt)  # the stop token itself is emitted
        done = was_done | jnp.isin(nxt, stop) | (rem <= 0)
        pos = jnp.where(was_done, pos, pos + 1)
        return (cache, emit[:, None], pos, key, done, rem), (
            emit, lv if collect_logits else None)

    carry0 = (cache, tok.astype(jnp.int32), pos, key, done, remaining)
    (cache, tok, pos, key, done, remaining), (toks, logits) = jax.lax.scan(
        step, carry0, None, length=num_steps)
    aux = {"cache": cache, "tok": tok, "pos": pos, "key": key,
           "done": done, "remaining": remaining}
    if collect_logits:
        aux["logits"] = logits
    return toks.T, aux


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------


def _batch_axis(path) -> int:
    """Batch-dim axis of a cache leaf: stacked cycle leaves are [C, b, ...],
    tail leaves [b, ...] (mirrors ``SV.cache_shardings``)."""
    names = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
    return 0 if names[0] == "tail" else 1


def insert_slot(cache: Params, one: Params, i) -> Params:
    """Write a single-sequence (b=1) cache ``one`` into batch slot ``i`` of
    ``cache`` — the slot-reuse primitive of continuous batching."""
    def put(path, cb, c1):
        return jax.lax.dynamic_update_slice_in_dim(
            cb, c1.astype(cb.dtype), i, axis=_batch_axis(path))

    return jax.tree_util.tree_map_with_path(put, cache, one)


def reset_slot(cache: Params, i) -> Params:
    """Invalidate batch slot ``i`` before chunked prefill streams a new
    prompt into it: ``kpos`` rows go to -1 (no stale attention entries can
    leak into the new sequence — chunk writes only cover the new prompt's
    positions, unlike the old full-row ``insert_slot`` refill) and
    recurrent state rows (conv/ssm/h) go to 0.  k/v payloads stay — they
    are unreachable once ``kpos`` is -1."""

    def fix(path, leaf):
        names = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
        kind = names[-1]
        if kind not in ("kpos", "conv", "ssm", "h"):
            return leaf
        ax = _batch_axis(path)
        shape = list(leaf.shape)
        shape[ax] = 1
        fill = -1 if kind == "kpos" else 0
        row = jnp.full(shape, fill, leaf.dtype)
        return jax.lax.dynamic_update_slice_in_dim(leaf, row, i, axis=ax)

    return jax.tree_util.tree_map_with_path(fix, cache)


# per-slot scheduler states (traced int32)
FREE, PREFILL, DECODE = 0, 1, 2


def per_engine(fn, telemetry: Optional[TM.Telemetry] = None,
               name: Optional[str] = None):
    """Per-engine jit identity wrapper.  ``jax.jit``'s dispatch cache is
    global, keyed by (function, jit params): two engines built with EQUAL
    shardings over the same module-level function would pool their compile
    counts, corrupting the ``compiled_programs()`` bounded-set accounting
    (an engine would "inherit" another engine's compilations).  Wrapping
    in a fresh function object keeps the count engine-local.

    With a ``telemetry``, the wrapper doubles as the compile probe: the
    wrapped python function only executes while jax *traces* — i.e. once
    per new compiled program — so each call records one ``compile.<name>``
    event, and growth past the engine's bounded-program budget surfaces
    as a telemetry alert instead of only a slow-test assert."""
    label = name or fn.__name__

    def wrapped(*args):
        if telemetry is not None:
            telemetry.compile_event(label)
        return fn(*args)

    wrapped.__name__ = fn.__name__
    return wrapped


def mixed_segment(cfg: ModelConfig, par: Optional[ParallelContext], params: Params,
                  cache: Params, mode: jnp.ndarray, tok: jnp.ndarray,
                  pos: jnp.ndarray, key: jnp.ndarray, rem: jnp.ndarray,
                  pfill: jnp.ndarray, pend: jnp.ndarray, plen: jnp.ndarray, *,
                  num_steps: int, prefill_chunk: int, n_host_chunks: int = 0,
                  sampling: SamplingConfig = GREEDY,
                  stop_tokens: Sequence[int] = (), pad_id: int = 0,
                  table: Optional[jnp.ndarray] = None):
    """Run ``num_steps`` fused mixed steps in ONE ``lax.scan``.

    Per step, each slot does what its traced state says:
      PREFILL — consume the next ``prefill_chunk``-token chunk of its
                pending prompt (``chunk_step`` at offset ``pfill``; the
                final partial chunk is position-masked and recurrent state
                is gathered at the true length).  When the prompt is
                exhausted the slot samples its first token from the chunk
                logits, emits it, and transitions to DECODE (or straight
                to FREE on a stop token / empty budget);
      DECODE  — decode one token (emit, advance ``pos``, burn budget;
                stop token or exhausted budget -> FREE);
      FREE    — no-op (live=0 in the chunk program: nothing is written).

    The step is ONE compiled program: a ``lax.cond`` between the unified
    chunk program (any slot prefilling — decode slots ride it as live=1
    windows) and the plain ``decode_step`` fast path (nobody prefilling —
    steady-state decode pays zero chunk overhead).  Both branches are
    traced once, so program size is flat in chunk length, cache capacity,
    and step count.

    Carry (shape/dtype-stable): ``(cache, mode, tok, pos, key, rem,
    pfill)``; ``pend [b, P]``/``plen [b]`` (the staged prompts) and the
    optional paged-pool page ``table`` ([b, max_pages] int32, see
    ``runtime/paged.py`` — threaded into both step bodies so attention
    gathers/scatters K/V through it) are scan-invariant.  Returns
    ``(emit [b, num_steps], valid [b, num_steps], aux)`` where ``aux`` is
    the final carry as a dict — segments chain by feeding it back, and
    the host harvests ``emit`` where ``valid``.
    """
    b = tok.shape[0]
    cp = int(prefill_chunk)
    P = pend.shape[1]
    stop = jnp.asarray(tuple(stop_tokens), jnp.int32)
    V = cfg.vocab_size

    def step(carry, _):
        cache, mode, tok, pos, key, rem, pfill = carry
        key, sub = jax.random.split(key)
        is_pf = mode == PREFILL

        def chunk_branch(cache, tok):
            off = jnp.where(is_pf, pfill, pos)
            live = jnp.where(is_pf, jnp.clip(plen - pfill, 0, cp),
                             jnp.where(mode == DECODE, 1, 0))
            idx = jnp.clip(off[:, None] + jnp.arange(cp)[None, :], 0, P - 1)
            toks = jnp.take_along_axis(pend, idx, axis=1)
            toks = jnp.where(is_pf[:, None], toks, tok)  # decode rows: col 0 = tok
            return SV.chunk_step(cfg, par, params, cache, toks, off, live,
                                 n_host_chunks=n_host_chunks, table=table)

        def decode_branch(cache, tok):
            return SV.decode_step(cfg, par, params, cache, {"tokens": tok},
                                  pos, n_host_chunks=n_host_chunks, table=table)

        logits, cache = jax.lax.cond(jnp.any(is_pf), chunk_branch,
                                     decode_branch, cache, tok)
        nxt = sample_token(logits[:, :V], sub, sampling)
        pfill = jnp.where(is_pf, jnp.minimum(pfill + cp, plen), pfill)
        fin_pf = is_pf & (pfill >= plen)  # prompt exhausted THIS step
        is_dec = mode == DECODE
        emitting = is_dec | fin_pf
        valid = emitting & (rem > 0)
        emit = jnp.where(valid, nxt, pad_id)
        rem = rem - valid.astype(jnp.int32)
        hit_stop = valid & jnp.isin(nxt, stop)
        now_free = emitting & (hit_stop | (rem <= 0))
        mode = jnp.where(now_free, FREE, jnp.where(fin_pf, DECODE, mode))
        pos = jnp.where(fin_pf, plen,
                        jnp.where(is_dec & ~now_free, pos + 1, pos))
        tok = jnp.where(emitting, nxt, tok[:, 0])[:, None]
        return (cache, mode, tok, pos, key, rem, pfill), (emit, valid)

    carry0 = (cache, jnp.asarray(mode, jnp.int32), tok.astype(jnp.int32),
              jnp.asarray(pos, jnp.int32), key, jnp.asarray(rem, jnp.int32),
              jnp.asarray(pfill, jnp.int32))
    (cache, mode, tok, pos, key, rem, pfill), (emits, valids) = jax.lax.scan(
        step, carry0, None, length=num_steps)
    aux = {"cache": cache, "mode": mode, "tok": tok, "pos": pos, "key": key,
           "rem": rem, "pfill": pfill}
    return emits.T, valids.T, aux


def segment_shardings(cfg, par: Optional[ParallelContext], cache, *,
                      table: bool = False):
    """``(in_shardings, out_shardings)`` for the mixed-segment jit on a
    mesh, or ``None`` off-mesh.

    The cache pytree follows ``models/serve.py::cache_shardings`` (paged
    pool kv-heads over ``model``, per-slot rows over ``data``); every
    scheduler scalar/row (mode/tok/pos/key/rem/pfill/pend/plen, and the
    page ``table`` when present) is explicitly replicated — explicit
    ``par.ns()`` rather than ``None`` so jit never has to guess.
    ``NamedSharding`` is shape-free, so ONE jitted segment still serves
    every workload capacity: the exactly-2-programs guarantee survives
    meshing.  ``cache`` may be real arrays or ``jax.eval_shape`` structs —
    only shapes are read."""
    if par is None or par.mesh is None:
        return None
    csh = SV.cache_shardings(cfg, par, cache)
    r = par.ns()
    in_sh = (csh,) + (r,) * (8 + (1 if table else 0))
    out_sh = (r, r, {"cache": csh, "mode": r, "tok": r, "pos": r, "key": r,
                     "rem": r, "pfill": r})
    return in_sh, out_sh


class ServeEngine:
    """Continuous batching over ``slots`` concurrent cache rows, scheduled
    by the fused mixed step (``mixed_segment``).

    Queued prompts are staged into a per-slot pending buffer and streamed
    into the cache chunk-by-chunk (``prefill_chunk`` tokens per step)
    *while the other slots keep decoding* — refill never stops the world,
    and any layout joins variable-length continuous batching (recurrent
    blocks ride the state-at-length gather; see ``models/serve.py``).
    Prompts of any length > 0 are accepted: the pending buffer and cache
    capacity derive from ``max(bucket, longest prompt)``, so ``bucket`` is
    the floor that keeps program shapes stable across calls, not a limit.

    Exactly TWO compiled programs regardless of workload mix: the mixed
    segment (one ``lax.scan`` of fused steps) and ``reset_slot`` (row
    invalidation at assignment) — ``compiled_programs()`` reports the live
    count so tests can pin it.

    The slot-lifecycle points are overridable hooks (``_begin`` /
    ``_admit`` / ``_dispatch`` / ``_post_dispatch`` / ``_release`` /
    ``_end``) so ``runtime/paged.py::PagedServeEngine`` can swap the dense
    per-slot cache for the slot-shared paged pool without touching the
    scheduler itself.  An ``_admit`` override may dispatch extra
    cache-maintenance work from its admit plan (page invalidation, COW
    copies, promote-from-spill scatters) — each through ONE jitted
    program, so the compiled set stays bounded at segment + reset (+ the
    paged engine's copy and promote).
    """

    def __init__(self, cfg: ModelConfig, params: Params, *, slots: int,
                 bucket: int, max_new_tokens: int, prefill_chunk: int = 0,
                 n_host_chunks: int = 0, sampling: SamplingConfig = GREEDY,
                 stop_tokens: Sequence[int] = (), pad_id: int = 0,
                 segment: int = 8, par: Optional[ParallelContext] = None):
        self.cfg, self.params, self.par = cfg, params, par
        self.slots, self.bucket = slots, bucket
        self.max_new = max_new_tokens
        self.sampling, self.pad_id = sampling, pad_id
        self.segment = segment
        self.n_host_chunks = n_host_chunks
        self.cp = int(prefill_chunk) if prefill_chunk else min(bucket, 64)
        self._stop = tuple(stop_tokens)
        self.telemetry = TM.Telemetry(component="engine")
        self.last_stats: Dict[str, Any] = self.telemetry.stats_view()
        self._build_programs()

    # -- compiled programs (subclass hook) -------------------------------
    def _segment_shardings(self):
        """``segment_shardings`` over a representative cache, or ``None``
        off-mesh.  Bucket-capacity shapes stand in for every workload —
        ``NamedSharding`` carries no shape, so the sharded jit still serves
        all capacities with the same two programs."""
        if self.par is None or self.par.mesh is None:
            return None
        _, S = self._capacity([[0]])
        cache = jax.eval_shape(lambda: SV.init_cache(self.cfg, self.slots, S))
        return segment_shardings(self.cfg, self.par, cache)

    def _build_programs(self) -> None:
        cfg, par, params = self.cfg, self.par, self.params

        def seg(cache, mode, tok, pos, key, rem, pfill, pend, plen):
            return mixed_segment(cfg, par, params, cache, mode, tok, pos, key,
                                 rem, pfill, pend, plen, num_steps=self.segment,
                                 prefill_chunk=self.cp,
                                 n_host_chunks=self.n_host_chunks,
                                 sampling=self.sampling, stop_tokens=self._stop,
                                 pad_id=self.pad_id)

        tel = self.telemetry
        sh = self._segment_shardings()
        if sh is None:
            self._cache_sh = None
            self._segment = jax.jit(per_engine(seg, tel, "segment"))
            self._reset = jax.jit(per_engine(reset_slot, tel, "reset"))
        else:
            in_sh, out_sh = sh
            csh, r = in_sh[0], self.par.ns()
            self._cache_sh = csh
            self._segment = jax.jit(per_engine(seg, tel, "segment"),
                                    in_shardings=in_sh,
                                    out_shardings=out_sh)
            self._reset = jax.jit(per_engine(reset_slot, tel, "reset"),
                                  in_shardings=(csh, r), out_shardings=csh)

    # -- helpers ---------------------------------------------------------
    def compiled_programs(self) -> Dict[str, int]:
        """Live compile count per engine program (bounded-set assertion for
        tests: one mixed segment + one reset, no per-bucket/per-length
        specializations within a workload)."""
        return {"segment": self._segment._cache_size(),
                "reset": self._reset._cache_size()}

    def _validate(self, prompts: Sequence[Sequence[int]]) -> None:
        for i, p in enumerate(prompts):
            if len(p) == 0:
                raise ValueError(f"prompt {i} is empty; prompts must have "
                                 f"length > 0 (any length — prompts longer "
                                 f"than bucket={self.bucket} just take more "
                                 f"prefill chunks)")

    def _capacity(self, prompts: Sequence[Sequence[int]]) -> Tuple[int, int]:
        """(P, S): pending-buffer length and cache capacity for a workload —
        the bucket floor or the longest prompt, rounded up to whole prefill
        chunks (and S to whole host-KV slabs when streaming)."""
        longest = max((len(p) for p in prompts), default=1)
        P = -(-max(self.bucket, longest) // self.cp) * self.cp
        S = P + self.max_new
        if self.n_host_chunks:
            S = -(-S // self.n_host_chunks) * self.n_host_chunks
        return P, S

    # -- slot-lifecycle hooks (overridden by the paged engine) -----------
    def _begin(self, B: int, P: int, S: int):
        """Start a workload: return the cache the segments will carry.
        On a mesh, committed to the segment's cache sharding up front —
        every reset/segment call then sees one input signature, keeping
        the compiled-program set at exactly two."""
        cache = SV.init_cache(self.cfg, B, S)
        if self._cache_sh is not None:
            cache = jax.device_put(cache, self._cache_sh)
        return cache

    def _admit(self, cache, s: int, idx: int, prompt, active: bool,
               budget: Optional[int] = None):
        """Claim slot ``s`` for request ``idx``: invalidate the slot's rows
        and return ``(cache, resume)`` where ``resume`` is how many prompt
        tokens are ALREADY cached (prefill starts there; dense: 0).  May
        return ``None`` to defer the request when resources are
        momentarily exhausted — only legal while other slots are still
        ``active`` (they will free resources); otherwise raise.  Any
        device work the admission implies (paged: fresh-page resets, COW
        copies, spill-tier promote scatters) is dispatched here, before
        the slot's first segment sees the cache.  ``budget`` is the
        decode-token reservation (``None`` → ``max_new_tokens``); a
        preemption-resuming scheduler passes the request's REMAINING
        budget so re-admission doesn't over-reserve pages."""
        self.last_stats["resets"] += 1
        return self._reset(cache, s), 0

    def _dispatch(self, cache, mode, tok, pos, key, rem, pfill, pend, plen):
        return self._segment(cache, mode, tok, pos, key, rem, pfill, pend, plen)

    def _post_dispatch(self, mode, pfill, plen, pend, owner) -> None:
        """Host-side bookkeeping after each segment (paged: radix publish)."""

    def _release(self, s: int) -> None:
        """Slot ``s`` went FREE and its owner was harvested."""

    def _end(self, cache) -> None:
        """Workload drained (every slot released)."""

    # -- the scheduler ---------------------------------------------------
    def generate(self, prompts: Sequence[Sequence[int]],
                 key: Optional[jnp.ndarray] = None) -> List[List[int]]:
        """Run every prompt to completion (stop token or ``max_new_tokens``),
        re-using slots as sequences finish.  Returns one generated-token
        list per prompt (stop token included when one fired), in order.

        Per-dispatch timing/occupancy lands in ``self.last_stats`` —
        ``steps`` is a list of ``{ms, prefilling, emitted}`` records (one
        per segment dispatch; run with ``segment=1`` for true per-step
        inter-token latencies), plus ``dispatches``/``resets`` counters.
        """
        self._validate(prompts)
        key = jax.random.PRNGKey(0) if key is None else key
        queue = collections.deque(enumerate(prompts))
        out: List[List[int]] = [[] for _ in prompts]
        B = self.slots
        P, S = self._capacity(prompts)
        stats = self.telemetry.stats_view(
            {"steps": self.telemetry.steps_ring(), "dispatches": 0,
             "resets": 0, "capacity": S, "pending_len": P})
        self.last_stats = stats
        cache = self._begin(B, P, S)
        mode = np.full(B, FREE, np.int32)
        tok = np.zeros((B, 1), np.int32)
        pos = np.zeros(B, np.int32)
        rem = np.zeros(B, np.int32)
        pfill = np.zeros(B, np.int32)
        pend = np.full((B, P), self.pad_id, np.int32)
        plen = np.ones(B, np.int32)
        owner: List[Optional[int]] = [None] * B

        while True:
            for s in range(B):
                if owner[s] is not None or not queue:
                    continue
                idx, prompt = queue[0]
                active = any(o is not None for o in owner)
                admitted = self._admit(cache, s, idx, prompt, active)
                if admitted is None:  # deferred (pool pressure): retry later
                    break
                cache, resume = admitted
                queue.popleft()
                owner[s] = idx
                n = len(prompt)
                self.telemetry.event(
                    "request.admit", request=idx, slot=s,
                    step=stats["dispatches"], prompt_len=n,
                    prefix_hit=int(resume))
                pend[s, :n] = list(prompt)
                pend[s, n:] = self.pad_id
                plen[s], pfill[s], mode[s] = n, resume, PREFILL
                rem[s], pos[s], tok[s] = self.max_new, 0, self.pad_id
            if all(o is None for o in owner):
                break
            key, sub = jax.random.split(key)
            n_prefilling = int((mode == PREFILL).sum())
            with TM.timed_dispatch(self.telemetry, stats,
                                   prefilling=n_prefilling) as td:
                emits, valids, aux = self._dispatch(
                    cache, mode, tok, pos, sub, rem, pfill, pend, plen)
                cache = aux["cache"]
                mode, tok, pos, rem, pfill, em, va = (
                    np.array(x) for x in jax.device_get(
                        (aux["mode"], aux["tok"], aux["pos"], aux["rem"],
                         aux["pfill"], emits, valids)))
                td.emitted = int(va.sum())
            self._post_dispatch(mode, pfill, plen, pend, owner)
            for s in range(B):
                if owner[s] is None:
                    continue
                got = [int(t) for t, v in zip(em[s], va[s]) if v]
                out[owner[s]].extend(got)
                if got:
                    self.telemetry.event(
                        "request.emit", request=owner[s], slot=s,
                        step=stats["dispatches"], n=len(got))
                if mode[s] == FREE:
                    self._release(s)
                    self.telemetry.event(
                        "request.complete", request=owner[s], slot=s,
                        step=stats["dispatches"], n=len(out[owner[s]]))
                    owner[s] = None
        self._end(cache)
        return out


class BlockingServeEngine:
    """The PR 3 continuous-batching engine, kept as the measured baseline
    the fused scheduler is compared against (``benchmarks/serve_bench.py``).

    Prompts are right-padded into a fixed ``bucket`` length and prefilled
    position-masked (``prefill_step(..., lengths=...)``), decode runs in
    jitted ``decode_tokens`` segments of ``segment`` steps, and between
    segments finished rows are harvested and their slots re-prefilled with
    queued prompts — three compiled programs total (batched prefill,
    single-row refill prefill, decode segment), but every refill STOPS THE
    WORLD: all other slots sit idle for a full-bucket prefill.

    Variable prompt lengths require a pure global-attention layout (see
    ``prefill_step``); recurrent archs can only use this engine when every
    prompt exactly fills the bucket.  ``ServeEngine`` lifts both limits.
    """

    def __init__(self, cfg: ModelConfig, params: Params, *, slots: int,
                 bucket: int, max_new_tokens: int,
                 n_host_chunks: int = 0, sampling: SamplingConfig = GREEDY,
                 stop_tokens: Sequence[int] = (), pad_id: int = 0,
                 segment: int = 8, par: Optional[ParallelContext] = None):
        self.cfg, self.params, self.par = cfg, params, par
        self.slots, self.bucket = slots, bucket
        self.max_new = max_new_tokens
        self.max_len = bucket + max_new_tokens
        self.sampling, self.pad_id = sampling, pad_id
        self.segment = segment
        stop_tokens = tuple(stop_tokens)
        self._stop_set = frozenset(int(t) for t in stop_tokens)
        # three-program engine, and prefill legitimately compiles twice
        # (batched initial fill + single-row refill) — alert past that
        self.telemetry = TM.Telemetry(component="blocking-engine",
                                      program_limit=2)
        self.last_stats: Dict[str, Any] = self.telemetry.stats_view()
        if n_host_chunks and self.max_len % n_host_chunks:
            # models/serve.py silently falls back to on-device attention for
            # non-dividing chunk counts — the operator would be serving a
            # different program than requested
            raise ValueError(
                f"n_host_chunks={n_host_chunks} does not divide the cache "
                f"length bucket+max_new_tokens={self.max_len}; host-KV "
                f"streaming requires equal slabs")

        def prefill(toks, lengths):
            return SV.prefill_step(cfg, par, params, {"tokens": toks},
                                   max_len=self.max_len, lengths=lengths)

        self._prefill = jax.jit(per_engine(prefill, self.telemetry,
                                           "prefill"))

        def decode_seg(cache, tok, pos, key, done, rem):
            return decode_tokens(cfg, par, params, cache, tok, pos,
                                 num_steps=segment, n_host_chunks=n_host_chunks,
                                 sampling=sampling, stop_tokens=stop_tokens,
                                 pad_id=pad_id, key=key, done=done,
                                 remaining=rem)

        self._decode = jax.jit(per_engine(decode_seg, self.telemetry,
                                          "decode"))
        self._insert = jax.jit(per_engine(insert_slot, self.telemetry,
                                          "insert"))

    # -- helpers ---------------------------------------------------------
    def _pad(self, rows: List[List[int]]) -> Tuple[jnp.ndarray, jnp.ndarray]:
        lengths = [len(r) for r in rows]
        for i, n in enumerate(lengths):
            if not 0 < n <= self.bucket:
                raise ValueError(
                    f"prompt {i} has length {n}; the blocking engine "
                    f"requires lengths in (0, bucket={self.bucket}] — use "
                    f"ServeEngine for longer prompts (chunked prefill)")
        toks = jnp.asarray(
            [list(r) + [self.pad_id] * (self.bucket - len(r)) for r in rows],
            jnp.int32)
        return toks, jnp.asarray(lengths, jnp.int32)

    # -- the scheduler ---------------------------------------------------
    def generate(self, prompts: Sequence[Sequence[int]],
                 key: Optional[jnp.ndarray] = None) -> List[List[int]]:
        """Run every prompt to completion (stop token or ``max_new_tokens``),
        re-using slots as sequences finish.  Returns one generated-token
        list per prompt (stop token included when one fired), in order."""
        key = jax.random.PRNGKey(0) if key is None else key
        queue = collections.deque(enumerate(prompts))
        out: List[List[int]] = [[] for _ in prompts]
        B = self.slots
        stats = self.telemetry.stats_view(
            {"steps": self.telemetry.steps_ring(), "dispatches": 0,
             "refills": 0})
        self.last_stats = stats

        # initial fill: pad the first B prompts into one batched prefill;
        # short queues fill trailing slots with a dummy row that starts done
        first = [queue.popleft() for _ in range(min(B, len(queue)))]
        rows = [list(p) for _, p in first] + [[self.pad_id] * self.bucket] * (B - len(first))
        toks, lengths = self._pad(rows)
        # no pad tokens -> unmasked prefill (lengths=None): this is the path
        # recurrent layouts can take, since prefill_step refuses lengths=...
        no_pads = all(len(r) == self.bucket for r in rows)
        logits, cache = self._prefill(toks, None if no_pads else lengths)
        stats["dispatches"] += 1
        key, sub = jax.random.split(key)
        tok = sample_token(logits[:, : self.cfg.vocab_size], sub, self.sampling)
        owner: List[Optional[int]] = [i for i, _ in first] + [None] * (B - len(first))
        for s, o in enumerate(owner):
            if o is not None:
                out[o].append(int(tok[s]))
        pos = lengths
        # a prefill-sampled first token may itself be a stop token (or the
        # whole budget): such rows start done and are refilled at the next
        # harvest, never entering the scan as live
        done = jnp.asarray([o is None or int(tok[s]) in self._stop_set
                            or self.max_new <= 1
                            for s, o in enumerate(owner)])
        rem = jnp.full((B,), self.max_new - 1, jnp.int32)
        tok = tok[:, None]

        while not all(o is None for o in owner):
            # the span times the whole stop-the-world segment: decode +
            # harvest + any synchronous refill prefills (the stall the
            # fused engine is measured against)
            with TM.timed_dispatch(self.telemetry, stats) as td:
                n_refills = 0
                rem_before = rem
                toks_seg, aux = self._decode(cache, tok, pos, key, done, rem)
                cache, tok, pos, key = aux["cache"], aux["tok"], aux["pos"], aux["key"]
                done, rem = aux["done"], aux["remaining"]
                emitted = jax.device_get(rem_before - rem)
                seg_host = jax.device_get(toks_seg)
                done_host = jax.device_get(done)
                for s in range(B):
                    if owner[s] is None:
                        continue
                    out[owner[s]].extend(int(t) for t in seg_host[s, : emitted[s]])
                    if not done_host[s]:
                        continue
                    if not queue:  # finished, nothing queued: park the slot
                        owner[s] = None
                        continue
                    # slot reuse: single-row position-masked prefill + insert —
                    # synchronous: every other slot stalls for the full prefill
                    idx, prompt = queue.popleft()
                    toks1, len1 = self._pad([list(prompt)])
                    logits1, cache1 = self._prefill(
                        toks1, None if len(prompt) == self.bucket else len1)
                    key, sub = jax.random.split(key)
                    t0tok = sample_token(logits1[:, : self.cfg.vocab_size], sub,
                                         self.sampling)
                    cache = self._insert(cache, cache1, s)
                    n_refills += 1
                    stats["dispatches"] += 2
                    owner[s] = idx
                    out[idx].append(int(t0tok[0]))
                    tok = tok.at[s].set(t0tok)
                    pos = pos.at[s].set(len1[0])
                    done = done.at[s].set(int(t0tok[0]) in self._stop_set
                                          or self.max_new <= 1)
                    rem = rem.at[s].set(self.max_new - 1)
                jax.block_until_ready(tok)
                stats["refills"] += n_refills
                td.prefilling = n_refills
                td.emitted = int(emitted.sum())
        return out
