"""Two-tier paged KV-cache pool with radix-tree prefix reuse.

The dense serve engine gives every slot a ``[b, max_len]`` cache row:
memory scales with ``slots x worst-case-prompt`` even when most rows are
short, ``reset_slot`` invalidates a whole row, and two requests sharing a
long prefix prefill it twice.  This module replaces the per-slot rows of
full-attention blocks with one slot-SHARED pool of fixed-size pages
(``models/serve.py::init_paged_cache``) plus host-side metadata:

* ``PagePool`` — free-list allocator with refcounts over ``n_pages``
  physical pages; a page is free iff its refcount is 0.
* ``RadixTree`` — prefix index at full-page granularity, keyed on the
  page's token content (one tree node per page; the path from the root
  spells the prefix, so lookups chain page keys exactly like a rolling
  hash).  Matching a new prompt maps its longest previously-prefilled
  full-page prefix to the physical pages that already hold its KV —
  copy-free sharing; the tree holds one refcount per page it references,
  so cached prefixes survive the requests that created them until evicted
  (LRU leaves first, and only pages nobody else maps).
* ``PagedCacheManager`` — per-slot page tables (``[slots, max_pages]``
  int32; ``-1`` = unmapped, FREE rows point at the trash page), admission
  control (a request's full page reserve is allocated up front, so the
  table is invariant across a whole segment and pool exhaustion is a
  clean admit-time error, never a mid-flight one), copy-on-write
  (``ensure_writable``: a shared page is copied before its owner may
  write, so no page is ever reachable from two tables once they diverge),
  and the radix publish/evict lifecycle.  Pure host metadata — device
  work (page invalidation, COW copies) is returned as work lists the
  engine dispatches through its jitted ``paged_reset``/``copy_page``
  programs.
* ``PagedServeEngine`` — ``ServeEngine`` subclass: the fused mixed-step
  scheduler is untouched; attention simply gathers/scatters K/V through
  the page table (``models/serve.py`` paged twins, host-streamed page by
  page via ``fori_double_buffered`` when ``n_host_chunks > 0``), admit
  maps radix-hit pages and starts prefill AFTER them (a shared prefix is
  never recomputed), and release returns the slot's pages to the pool.

See ``docs/serving.md`` (paged-pool section) for the lifecycle diagram.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ModelConfig
from repro.core.parallel import ParallelContext
from repro.models import serve as SV
from repro.models.transformer import layout_of
from repro.runtime import decode_loop as DL

Params = Dict[str, Any]


class PoolExhausted(ValueError):
    """No free pages for an admission.  A ``ValueError`` so it surfaces
    cleanly when raised to callers, but catchable separately so the engine
    can defer a request while other slots still hold pages."""


# ---------------------------------------------------------------------------
# page allocator
# ---------------------------------------------------------------------------


class PagePool:
    """Free-list page allocator with refcounts.

    Invariants (property-tested in ``tests/test_paged.py``):
      * a page is on the free list iff its refcount is 0;
      * ``alloc`` never hands out a page twice without an intervening
        release to zero;
      * ``share``/``release`` only touch live (refcount > 0) pages.
    """

    def __init__(self, n_pages: int):
        if n_pages < 1:
            raise ValueError(f"n_pages must be >= 1, got {n_pages}")
        self.n_pages = n_pages
        self.refcount = np.zeros(n_pages, np.int64)
        self._free = list(range(n_pages - 1, -1, -1))  # stack: page 0 first

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def used_count(self) -> int:
        return self.n_pages - len(self._free)

    def alloc(self) -> int:
        if not self._free:
            raise PoolExhausted(f"all {self.n_pages} pages in use")
        pid = self._free.pop()
        self.refcount[pid] = 1
        return pid

    def share(self, pid: int) -> None:
        if self.refcount[pid] <= 0:
            raise ValueError(f"share of free page {pid}")
        self.refcount[pid] += 1

    def release(self, pid: int) -> None:
        rc = int(self.refcount[pid])
        if rc <= 0:
            raise ValueError(f"release of free page {pid}")
        self.refcount[pid] = rc - 1
        if rc == 1:
            self._free.append(pid)


# ---------------------------------------------------------------------------
# radix tree (full-page prefix index)
# ---------------------------------------------------------------------------


def _page_key(tokens) -> bytes:
    """Exact content key of one page of prompt tokens (the dict lookup
    hashes it, chaining parent keys along the tree path)."""
    return np.asarray(tokens, np.int32).tobytes()


class _Node:
    __slots__ = ("children", "parent", "key", "page", "last_used")

    def __init__(self, parent: Optional["_Node"] = None,
                 key: Optional[bytes] = None):
        self.children: Dict[bytes, "_Node"] = {}
        self.parent, self.key = parent, key
        self.page = -1
        self.last_used = 0


class RadixTree:
    """Full-page-granularity prefix index over a ``PagePool``.

    Only FULL pages are indexed — a prompt's partial tail page is private
    to its slot, so shared pages are immutable by construction (writes
    only ever target the suffix a request prefills itself, or go through
    copy-on-write).  The tree owns one refcount per referenced page.
    """

    def __init__(self, page_size: int, pool: PagePool):
        self.page_size, self.pool = page_size, pool
        self.root = _Node()
        self._clock = 0
        self.pages = 0  # pages the tree currently references

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def match(self, tokens: Sequence[int]) -> List[int]:
        """Physical pages holding the longest already-indexed full-page
        prefix of ``tokens``.  Touches LRU stamps; takes NO refcounts —
        the caller shares what it actually maps."""
        ps = self.page_size
        node, pids, t = self.root, [], self._tick()
        for i in range(len(tokens) // ps):
            child = node.children.get(_page_key(tokens[i * ps:(i + 1) * ps]))
            if child is None:
                break
            child.last_used = t
            pids.append(child.page)
            node = child
        return pids

    def insert(self, tokens: Sequence[int], pids: Sequence[int]) -> int:
        """Index ``pids`` as holding the leading full pages of ``tokens``.
        Existing nodes win (first prefill published; contents are
        identical by construction) and take no extra reference.  Returns
        how many pages were newly indexed."""
        ps = self.page_size
        node, t, added = self.root, self._tick(), 0
        for i, pid in enumerate(pids):
            key = _page_key(tokens[i * ps:(i + 1) * ps])
            child = node.children.get(key)
            if child is None:
                child = _Node(parent=node, key=key)
                child.page = int(pid)
                node.children[key] = child
                self.pool.share(int(pid))
                self.pages += 1
                added += 1
            child.last_used = t
            node = child
        return added

    def _evictable_leaves(self) -> List[_Node]:
        out, stack = [], [self.root]
        while stack:
            nd = stack.pop()
            for c in nd.children.values():
                if c.children:
                    stack.append(c)
                elif int(self.pool.refcount[c.page]) == 1:  # tree-only ref
                    out.append(c)
        return out

    def evict(self, n_pages: int) -> int:
        """Drop up to ``n_pages`` least-recently-used leaf pages whose only
        reference is the tree's own.  Interior nodes become evictable as
        their children go (suffix-first, so a surviving node always has
        its whole prefix chain intact).  Returns pages actually freed."""
        freed = 0
        while freed < n_pages:
            leaves = self._evictable_leaves()
            if not leaves:
                break
            victim = min(leaves, key=lambda nd: nd.last_used)
            del victim.parent.children[victim.key]
            self.pool.release(victim.page)
            self.pages -= 1
            freed += 1
        return freed


# ---------------------------------------------------------------------------
# page tables + admission + COW
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class AdmitPlan:
    """Host-side result of admitting one request: what the engine must
    dispatch to the device before the slot's first segment."""

    resume: int                        # prompt tokens already cached (skip)
    fresh_pages: List[int]             # newly allocated -> need invalidation
    cow: List[Tuple[int, int]]         # (src, dst) page copies to dispatch
    hit_pages: int                     # full pages served from the tree


class PagedCacheManager:
    """Page tables, admission control, COW, and the radix lifecycle.

    The manager never touches device arrays: ``admit`` returns an
    ``AdmitPlan`` naming the pages to invalidate/copy, and ``table`` is a
    plain int32 numpy array the engine ships with every dispatch.  A
    request's worst-case page reserve (``ceil((plen + budget) / ps)``) is
    allocated at admit, so the table is segment-invariant and the pool can
    never run dry mid-flight — exhaustion is an admit-time
    ``PoolExhausted``.
    """

    def __init__(self, n_pages: int, page_size: int, use_radix: bool = True):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.pool = PagePool(n_pages)
        self.page_size = page_size
        self.radix = RadixTree(page_size, self.pool) if use_radix else None
        self.trash = n_pages  # physical index of the FREE-slot write sink
        self.table: Optional[np.ndarray] = None
        self._slot_pages: List[List[int]] = []

    def begin(self, slots: int, max_pages: int) -> None:
        """Start a workload: fresh all-FREE tables.  Slots a previous
        workload left admitted (an exception mid-``generate`` — the engine
        is long-lived, so it must not stay wedged) are released here;
        radix-indexed pages persist either way."""
        for s, pages in enumerate(self._slot_pages):
            if pages:
                self.release(s)
        self.table = np.full((slots, max_pages), self.trash, np.int32)
        self._slot_pages = [[] for _ in range(slots)]

    # -- admission -------------------------------------------------------
    def admit(self, slot: int, tokens: Sequence[int], budget: int,
              label: str = "request") -> AdmitPlan:
        """Map slot ``slot`` for a prompt of ``tokens`` plus ``budget``
        generated tokens.  Radix-matched prefix pages are mapped shared
        (copy-free); the rest of the reserve is allocated fresh.  When the
        match covers the whole prompt, the last matched page is taken via
        copy-on-write instead — the resumed prefill must recompute (and
        rewrite) the final token to produce first-token logits, and a
        shared page must never be written."""
        if self._slot_pages[slot]:
            raise ValueError(f"slot {slot} admitted twice without release")
        ps = self.page_size
        plen = len(tokens)
        need = max(-(-(plen + budget) // ps), 1)
        if need > self.table.shape[1]:
            raise ValueError(
                f"{label}: needs {need} pages ({plen} prompt + {budget} new "
                f"tokens at page_size={ps}) but the table is only "
                f"{self.table.shape[1]} pages wide")
        matched = self.radix.match(tokens) if self.radix is not None else []
        m = len(matched)
        resume = min(m * ps, max(plen - 1, 0))
        n_shared = m if resume == m * ps else m - 1
        shared = matched[:n_shared]
        cow_src = matched[n_shared:]  # 0 or 1 page (the full-cover case)
        # take refs on EVERY matched page first — the shared ones we keep
        # AND the COW source (its protective ref is dropped once the copy
        # pair is recorded) — so eviction can't free a page the plan reads
        for pid in (*shared, *cow_src):
            self.pool.share(pid)
        fresh_needed = need - n_shared
        if self.pool.free_count < fresh_needed and self.radix is not None:
            self.radix.evict(fresh_needed - self.pool.free_count)
        if self.pool.free_count < fresh_needed:
            for pid in (*shared, *cow_src):
                self.pool.release(pid)
            raise PoolExhausted(
                f"{label}: needs {fresh_needed} free pages ({plen} prompt + "
                f"{budget} new tokens at page_size={ps}, {n_shared} prefix "
                f"pages shared) but only {self.pool.free_count} of "
                f"{self.pool.n_pages} are free")
        cow: List[Tuple[int, int]] = []
        pids = list(shared)
        if cow_src:
            dst = self.pool.alloc()
            cow.append((int(cow_src[0]), dst))
            pids.append(dst)
            self.pool.release(int(cow_src[0]))  # drop the protective ref
        fresh = [self.pool.alloc() for _ in range(need - len(pids))]
        pids.extend(fresh)
        self.table[slot, :] = -1
        self.table[slot, :need] = pids
        self._slot_pages[slot] = pids
        return AdmitPlan(resume=resume, fresh_pages=fresh, cow=cow,
                         hit_pages=m)

    def release(self, slot: int) -> None:
        """Return the slot's pages (tree-shared ones survive via their
        tree refcount) and park the row on the trash page."""
        for pid in self._slot_pages[slot]:
            self.pool.release(pid)
        self._slot_pages[slot] = []
        self.table[slot, :] = self.trash

    # -- copy-on-write ---------------------------------------------------
    def ensure_writable(self, slot: int, logical_j: int
                        ) -> Optional[Tuple[int, int]]:
        """Make logical page ``logical_j`` of ``slot`` exclusively owned.
        Returns the ``(src, dst)`` device copy to dispatch when the page
        was shared (after which no page is reachable from two tables),
        ``None`` when it already was exclusive."""
        pid = int(self.table[slot, logical_j])
        if pid < 0 or pid == self.trash:
            raise ValueError(f"slot {slot} logical page {logical_j} unmapped")
        if int(self.pool.refcount[pid]) <= 1:
            return None
        dst = self.pool.alloc()
        self.pool.release(pid)
        self.table[slot, logical_j] = dst
        self._slot_pages[slot][logical_j] = dst
        return pid, dst

    # -- radix lifecycle -------------------------------------------------
    def complete_prefill(self, slot: int, tokens: Sequence[int]) -> int:
        """Prefill finished: publish the prompt's full pages so future
        requests sharing the prefix map them copy-free."""
        if self.radix is None:
            return 0
        full = len(tokens) // self.page_size
        if not full:
            return 0
        return self.radix.insert(list(tokens)[: full * self.page_size],
                                 self._slot_pages[slot][:full])

    @property
    def pages_in_use(self) -> int:
        return self.pool.used_count


# ---------------------------------------------------------------------------
# jitted page maintenance programs
# ---------------------------------------------------------------------------


def _leaf_names(path) -> List[str]:
    return [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]


def paged_reset(cache: Params, i, page_ids: jnp.ndarray) -> Params:
    """Slot + page invalidation in one program: slot ``i``'s per-slot rows
    reset exactly like ``reset_slot`` (dense ``kpos`` -> -1, recurrent
    state -> 0), and the pool's ``pkpos`` rows at ``page_ids`` go to -1 —
    newly allocated pages may hold a previous owner's entries, which must
    not alias the new sequence's positions.  ``page_ids`` is fixed-width;
    pad with any out-of-range id (they scatter with ``mode="drop"``)."""
    cache = DL.reset_slot(cache, i)

    def fix(path, leaf):
        names = _leaf_names(path)
        if names[-1] != "pkpos":
            return leaf
        if names[0] == "tail":
            return leaf.at[page_ids].set(-1, mode="drop")
        return leaf.at[:, page_ids].set(-1, mode="drop")

    return jax.tree_util.tree_map_with_path(fix, cache)


def copy_page(cache: Params, src, dst, drop_from) -> Params:
    """Copy physical page ``src`` -> ``dst`` in every attention layer (the
    COW primitive).  Entries at in-page offsets ``>= drop_from`` are
    invalidated in the copy: they are the COW'd tail the resumed prefill
    will recompute and rewrite, and leaving them valid would double-count
    against the chunk program's own intra-window keys."""
    keep = None

    def fix(path, leaf):
        nonlocal keep
        names = _leaf_names(path)
        kind = names[-1]
        if kind not in ("pk", "pv", "pkpos"):
            return leaf
        stacked = names[0] != "tail"
        row = leaf[:, src] if stacked else leaf[src]
        if kind == "pkpos":
            ps = leaf.shape[-1]
            if keep is None:
                keep = jnp.arange(ps) < drop_from
            row = jnp.where(keep, row, -1)
        return leaf.at[:, dst].set(row) if stacked else leaf.at[dst].set(row)

    return jax.tree_util.tree_map_with_path(fix, cache)


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------


class PagedServeEngine(DL.ServeEngine):
    """Continuous batching over the slot-shared paged pool.

    Same fused mixed-step scheduler as ``ServeEngine`` — the segment
    program just reads/writes attention K/V through the page table, so
    ``compiled_programs()`` stays a bounded set (one segment, one
    reset-and-invalidate, one COW copy) and program size is flat in
    ``n_pages`` (the pool only changes array DIMENSIONS; the page loop is
    ``fori_double_buffered`` over logical pages).  What changes is the
    slot lifecycle:

      admit   — radix-match the prompt, map shared prefix pages copy-free
                (prefill resumes AFTER them), allocate the rest of the
                worst-case reserve, invalidate fresh pages, dispatch COW
                copies.  A request that cannot fit defers while other
                slots hold pages and raises ``ValueError`` (naming it)
                when the pool could never take it.
      release — refcount-release the slot's pages; radix-published prefix
                pages survive for future requests (two-tier: with
                ``n_host_chunks > 0`` the pool itself is host-resident
                and pages stream device-ward inside attention).

    ``radix=True`` only takes effect for pure global-attention layouts:
    recurrent blocks (ssm/rglru/local_attn ring) integrate the whole
    prefix into per-slot state that a mapped page cannot restore, so
    prefix skipping would be silently wrong — those layouts still get the
    paged pool, just with ``resume = 0``.

    The pool (and its radix-indexed contents) persists across
    ``generate`` calls — a shared system prompt served in one workload is
    a prefix hit in the next.
    """

    def __init__(self, cfg: ModelConfig, params: Params, *, slots: int,
                 bucket: int, max_new_tokens: int, page_size: int = 16,
                 n_pages: int = 0, radix: bool = True,
                 prefill_chunk: int = 0, n_host_chunks: int = 0,
                 sampling: DL.SamplingConfig = DL.GREEDY,
                 stop_tokens: Sequence[int] = (), pad_id: int = 0,
                 segment: int = 8, par: Optional[ParallelContext] = None):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.page_size = int(page_size)
        if n_pages <= 0:  # default: dense-equivalent capacity
            n_pages = slots * -(-(bucket + max_new_tokens) // self.page_size)
        self.n_pages = int(n_pages)
        pat, _, tail = layout_of(cfg)
        self.radix_enabled = bool(radix) and all(
            k == "attn" for k in (*pat, *tail))
        self.kv = PagedCacheManager(self.n_pages, self.page_size,
                                    use_radix=self.radix_enabled)
        self._pool_cache = SV.init_paged_cache(cfg, slots, self.n_pages,
                                               self.page_size)
        self._table_dev = None  # device copy, refreshed at admit/release
        self._inserted = [True] * slots
        super().__init__(cfg, params, slots=slots, bucket=bucket,
                         max_new_tokens=max_new_tokens,
                         prefill_chunk=prefill_chunk,
                         n_host_chunks=n_host_chunks, sampling=sampling,
                         stop_tokens=stop_tokens, pad_id=pad_id,
                         segment=segment, par=par)
        if self.cp % self.page_size and self.page_size % self.cp:
            raise ValueError(
                f"prefill_chunk={self.cp} and page_size={self.page_size} "
                f"must divide one another: radix prefix hits resume prefill "
                f"at a page boundary, and only a mutually-dividing grid "
                f"keeps every chunk window inside the slot's allocated page "
                f"reserve")
        # two-tier placement: the cold pool lives host-side; attention
        # streams gathered pages device-ward (no-op on CPU)
        self._pool_cache = self._offload_pool(self._pool_cache)

    def _offload_pool(self, cache):
        """Park the pool's K/V leaves in the offload tier when the engine
        is host-streaming — applied at init AND after every dispatch (the
        segment's outputs land in default memory; re-offloading mirrors
        ``launch/steps.py``'s per-step cache re-offload)."""
        if self.par is None or not self.n_host_chunks:
            return cache

        # host-placement custom-calls reject PARTIAL replication: on a
        # mesh the parked pool must shard over EVERY axis, so spread the
        # in-page dim across all of them (pages always divide evenly when
        # ps does); off-mesh the spec is empty and to_host is a plain put
        spec = ()
        if self.par.mesh is not None:
            all_axes = tuple(self.par.mesh.axis_names)
            if self.page_size % self.par.mesh.size == 0:
                spec = (None, all_axes, None, None)

        def offload(path, leaf):
            names = _leaf_names(path)
            if names[-1] not in ("pk", "pv"):
                return leaf
            lead = (None,) if names[0] != "tail" else ()
            return self.par.to_host(leaf, *(lead + spec if spec else ()))

        return jax.tree_util.tree_map_with_path(offload, cache)

    # -- compiled programs ----------------------------------------------
    def _segment_shardings(self):
        """Pool-layout shardings over the CONCRETE pool (its shapes never
        change — capacity lives in the page table, not the arrays), plus a
        replicated page-table argument."""
        if self.par is None or self.par.mesh is None:
            return None
        return DL.segment_shardings(self.cfg, self.par, self._pool_cache,
                                    table=True)

    def _build_programs(self) -> None:
        cfg, par, params = self.cfg, self.par, self.params

        def seg(cache, mode, tok, pos, key, rem, pfill, pend, plen, table):
            return DL.mixed_segment(cfg, par, params, cache, mode, tok, pos,
                                    key, rem, pfill, pend, plen,
                                    num_steps=self.segment,
                                    prefill_chunk=self.cp,
                                    n_host_chunks=self.n_host_chunks,
                                    sampling=self.sampling,
                                    stop_tokens=self._stop,
                                    pad_id=self.pad_id, table=table)

        sh = self._segment_shardings()
        if sh is None:
            self._cache_sh = None
            self._segment = jax.jit(seg)
            self._reset = jax.jit(paged_reset)
            self._copy = jax.jit(copy_page)
        else:
            # page copy/COW become sharded programs over the same pool
            # layout — each device moves only its own head (or in-page)
            # slice, no gather to one device
            in_sh, out_sh = sh
            csh, r = in_sh[0], par.ns()
            self._cache_sh = csh
            self._segment = jax.jit(seg, in_shardings=in_sh,
                                    out_shardings=out_sh)
            self._reset = jax.jit(paged_reset, in_shardings=(csh, r, r),
                                  out_shardings=csh)
            self._copy = jax.jit(copy_page, in_shardings=(csh, r, r, r),
                                 out_shardings=csh)
            # commit the persistent pool to its sharding NOW: the first
            # admit otherwise sees uncommitted arrays and compiles a second
            # reset signature, breaking the bounded-program guarantee
            self._pool_cache = jax.device_put(self._pool_cache, csh)

    def compiled_programs(self) -> Dict[str, int]:
        return {"segment": self._segment._cache_size(),
                "reset": self._reset._cache_size(),
                "copy": self._copy._cache_size()}

    # -- slot lifecycle --------------------------------------------------
    def _begin(self, B: int, P: int, S: int):
        max_pages = -(-(P + self.max_new) // self.page_size)
        self.kv.begin(B, max_pages)
        self._table_dev = None
        self._inserted = [True] * B
        self.last_stats.update({
            "page_size": self.page_size, "n_pages": self.n_pages,
            "max_pages": max_pages, "radix": self.radix_enabled,
            "prompt_tokens": 0, "prefilled_tokens": 0,
            "prefix_hit_tokens": 0, "cow_copies": 0, "deferrals": 0,
            "pages_peak": 0, "radix_pages": 0,
        })
        return self._pool_cache

    def _admit(self, cache, s: int, idx: int, prompt, active: bool):
        st = self.last_stats
        try:
            plan = self.kv.admit(s, list(prompt), self.max_new,
                                 label=f"request {idx}")
        except PoolExhausted as e:
            if active:  # running slots will release pages; retry next round
                st["deferrals"] += 1
                return None
            raise ValueError(str(e)) from None
        ids = np.full(self.n_pages, self.n_pages + 1, np.int32)  # pad -> OOB
        ids[: len(plan.fresh_pages)] = plan.fresh_pages
        cache = self._reset(cache, s, jnp.asarray(ids))
        for src, dst in plan.cow:
            cache = self._copy(cache, jnp.int32(src), jnp.int32(dst),
                               jnp.int32(plan.resume % self.page_size))
            st["cow_copies"] += 1
        self._table_dev = None  # table changed: re-ship at next dispatch
        st["resets"] += 1
        st["prompt_tokens"] += len(prompt)
        st["prefilled_tokens"] += len(prompt) - plan.resume
        st["prefix_hit_tokens"] += plan.resume
        st["pages_peak"] = max(st["pages_peak"], self.kv.pages_in_use)
        self._inserted[s] = False
        return cache, plan.resume

    def _dispatch(self, cache, mode, tok, pos, key, rem, pfill, pend, plen):
        if self._table_dev is None:
            self._table_dev = jnp.asarray(self.kv.table)
        emits, valids, aux = self._segment(cache, mode, tok, pos, key, rem,
                                           pfill, pend, plen, self._table_dev)
        aux["cache"] = self._offload_pool(aux["cache"])
        return emits, valids, aux

    def _post_dispatch(self, mode, pfill, plen, pend, owner) -> None:
        for s in range(self.slots):
            if owner[s] is None or self._inserted[s] or pfill[s] < plen[s]:
                continue
            self._inserted[s] = True
            self.kv.complete_prefill(s, [int(t) for t in pend[s, : plen[s]]])

    def _release(self, s: int) -> None:
        self.kv.release(s)
        self._table_dev = None  # table changed: re-ship at next dispatch

    def _end(self, cache) -> None:
        # the pool (radix-shared prefixes included) persists across calls
        self._pool_cache = cache
        if self.kv.radix is not None:
            self.last_stats["radix_pages"] = self.kv.radix.pages
