"""Two-tier paged KV-cache pool with radix-tree prefix reuse.

The dense serve engine gives every slot a ``[b, max_len]`` cache row:
memory scales with ``slots x worst-case-prompt`` even when most rows are
short, ``reset_slot`` invalidates a whole row, and two requests sharing a
long prefix prefill it twice.  This module replaces the per-slot rows of
full-attention blocks with one slot-SHARED pool of fixed-size pages
(``models/serve.py::init_paged_cache``) plus host-side metadata:

* ``PagePool`` — free-list allocator with refcounts over ``n_pages``
  physical pages; a page is free iff its refcount is 0.
* ``RadixTree`` — prefix index at full-page granularity, keyed on the
  page's token content (one tree node per page; the path from the root
  spells the prefix, so lookups chain page keys exactly like a rolling
  hash).  Matching a new prompt maps its longest previously-prefilled
  full-page prefix to the physical pages that already hold its KV —
  copy-free sharing; the tree holds one refcount per page it references,
  so cached prefixes survive the requests that created them until evicted
  (LRU leaves first, and only pages nobody else maps).
* ``SpillPool`` — host-resident spill tier behind the radix tree
  (Mooncake-style tiered KV): with ``spill_pages > 0`` eviction DEMOTES a
  cold prefix page's payload host-side instead of dropping it, the next
  prefix hit PROMOTES it back into a fresh device page through one jitted
  ``promote_page`` scatter, and ``save``/``restore`` persist the whole
  prefix cache (tree + payloads) across engine restarts — a second
  process serving the same system prompt starts with radix hits, not
  cold prefills.
* ``PagedCacheManager`` — per-slot page tables (``[slots, max_pages]``
  int32; ``-1`` = unmapped, FREE rows point at the trash page), admission
  control (a request's full page reserve is allocated up front, so the
  table is invariant across a whole segment and pool exhaustion is a
  clean admit-time error, never a mid-flight one), copy-on-write
  (``ensure_writable``: a shared page is copied before its owner may
  write, so no page is ever reachable from two tables once they diverge),
  and the radix publish/evict lifecycle.  Pure host metadata — device
  work (page invalidation, COW copies) is returned as work lists the
  engine dispatches through its jitted ``paged_reset``/``copy_page``
  programs.
* ``PagedServeEngine`` — ``ServeEngine`` subclass: the fused mixed-step
  scheduler is untouched; attention simply gathers/scatters K/V through
  the page table (``models/serve.py`` paged twins, host-streamed page by
  page via ``fori_double_buffered`` when ``n_host_chunks > 0``), admit
  maps radix-hit pages and starts prefill AFTER them (a shared prefix is
  never recomputed), and release returns the slot's pages to the pool.

See ``docs/serving.md`` (paged-pool section) for the lifecycle diagram.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ModelConfig
from repro.core.parallel import ParallelContext
from repro.models import serve as SV
from repro.models.transformer import layout_of
from repro.runtime import decode_loop as DL
from repro.runtime import telemetry as TM

Params = Dict[str, Any]


class PoolExhausted(ValueError):
    """No free pages for an admission.  A ``ValueError`` so it surfaces
    cleanly when raised to callers, but catchable separately so the engine
    can defer a request while other slots still hold pages."""


# ---------------------------------------------------------------------------
# page allocator
# ---------------------------------------------------------------------------


class PagePool:
    """Free-list page allocator with refcounts.

    Invariants (property-tested in ``tests/test_paged.py``):
      * a page is on the free list iff its refcount is 0;
      * ``alloc`` never hands out a page twice without an intervening
        release to zero;
      * ``share``/``release`` only touch live (refcount > 0) pages.
    """

    def __init__(self, n_pages: int):
        if n_pages < 1:
            raise ValueError(f"n_pages must be >= 1, got {n_pages}")
        self.n_pages = n_pages
        self.refcount = np.zeros(n_pages, np.int64)
        self._free = list(range(n_pages - 1, -1, -1))  # stack: page 0 first

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def used_count(self) -> int:
        return self.n_pages - len(self._free)

    def alloc(self) -> int:
        if not self._free:
            raise PoolExhausted(f"all {self.n_pages} pages in use")
        pid = self._free.pop()
        self.refcount[pid] = 1
        return pid

    def share(self, pid: int) -> None:
        if self.refcount[pid] <= 0:
            raise ValueError(f"share of free page {pid}")
        self.refcount[pid] += 1

    def release(self, pid: int) -> None:
        rc = int(self.refcount[pid])
        if rc <= 0:
            raise ValueError(f"release of free page {pid}")
        self.refcount[pid] = rc - 1
        if rc == 1:
            self._free.append(pid)


# ---------------------------------------------------------------------------
# host-resident spill tier
# ---------------------------------------------------------------------------


class SpillPool:
    """Host-resident spill tier: ``n_spill`` page-payload slots in plain
    host (numpy) buffers, one buffer per pool leaf, allocated lazily from
    the first demoted page's rows so the pool knows nothing about model
    shapes.  The radix tree demotes cold evicted pages here instead of
    dropping them and promotes them back into device pages on the next
    prefix hit; payloads round-trip ``RadixTree.save``/``restore`` so a
    prefix cache survives engine restarts.  Refcount-free by design: the
    tree is the sole owner of every spill entry."""

    def __init__(self, n_spill: int):
        if n_spill < 1:
            raise ValueError(f"n_spill must be >= 1, got {n_spill}")
        self.n_spill = n_spill
        self._free = list(range(n_spill - 1, -1, -1))  # stack: slot 0 first
        self.data: Dict[str, np.ndarray] = {}  # leaf path -> [n_spill, ...]
        self.demotions = 0  # payload writes to date (demotes + restores)

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def used_count(self) -> int:
        return self.n_spill - len(self._free)

    def alloc(self) -> int:
        """A free spill slot, or ``-1`` when the tier is full — the caller
        then falls back to dropping the page, so spill never blocks
        eviction."""
        return self._free.pop() if self._free else -1

    def free(self, sid: int) -> None:
        if sid in self._free:
            raise ValueError(f"free of unallocated spill slot {sid}")
        self._free.append(sid)

    def write(self, sid: int, rows: Dict[str, np.ndarray]) -> None:
        """Store one page's rows (``models/serve.py::page_rows`` keys)."""
        for k, row in rows.items():
            buf = self.data.get(k)
            if buf is None:
                row = np.asarray(row)
                buf = np.zeros((self.n_spill, *row.shape), row.dtype)
                self.data[k] = buf
            buf[sid] = row
        self.demotions += 1

    def read(self, sid: int) -> Dict[str, np.ndarray]:
        return {k: buf[sid] for k, buf in self.data.items()}


# ---------------------------------------------------------------------------
# radix tree (full-page prefix index)
# ---------------------------------------------------------------------------


def _page_key(tokens) -> bytes:
    """Exact content key of one page of prompt tokens (the dict lookup
    hashes it, chaining parent keys along the tree path)."""
    return np.asarray(tokens, np.int32).tobytes()


class _Node:
    __slots__ = ("children", "parent", "key", "page", "spill", "last_used")

    def __init__(self, parent: Optional["_Node"] = None,
                 key: Optional[bytes] = None):
        self.children: Dict[bytes, "_Node"] = {}
        self.parent, self.key = parent, key
        self.page = -1   # device page, or -1 when demoted to the spill tier
        self.spill = -1  # spill slot, or -1 when device-resident
        self.last_used = 0


class RadixTree:
    """Full-page-granularity prefix index over a ``PagePool``.

    Only FULL pages are indexed — a prompt's partial tail page is private
    to its slot, so shared pages are immutable by construction (writes
    only ever target the suffix a request prefills itself, or go through
    copy-on-write).  The tree owns one refcount per referenced page.

    With a :class:`SpillPool`, every node is either device-resident
    (``page >= 0``) or spilled (``spill >= 0``); along any root-to-leaf
    path the resident nodes form a prefix (demotion runs suffix-first,
    promotion re-admits a whole matched chain), so the device tier is
    always a connected top slice of the tree.  Demotion copies a page's
    payload host-side through ``read_page`` — set by the engine, it
    fetches one physical page of the live pool — at evict time, BEFORE
    the freed device page can be reallocated.
    """

    def __init__(self, page_size: int, pool: PagePool,
                 spill: Optional[SpillPool] = None):
        self.page_size, self.pool = page_size, pool
        self.spill = spill
        self.read_page: Optional[Callable[[int], Dict[str, np.ndarray]]] = None
        self.root = _Node()
        self._clock = 0
        self.pages = 0  # device pages the tree currently references
        # set by the owning engine: demote/evict decisions trace here
        self.telemetry: Optional[TM.Telemetry] = None

    @property
    def spilled(self) -> int:
        return self.spill.used_count if self.spill is not None else 0

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def match_nodes(self, tokens: Sequence[int]) -> List[_Node]:
        """Node chain of the longest already-indexed full-page prefix of
        ``tokens`` — entries may be device-resident (``page >= 0``) or
        spilled (``spill >= 0``; the manager promotes those at admit).
        Touches LRU stamps; takes NO refcounts — the caller shares what it
        actually maps."""
        ps = self.page_size
        node, out, t = self.root, [], self._tick()
        for i in range(len(tokens) // ps):
            child = node.children.get(_page_key(tokens[i * ps:(i + 1) * ps]))
            if child is None:
                break
            child.last_used = t
            out.append(child)
            node = child
        return out

    def match(self, tokens: Sequence[int]) -> List[int]:
        """Physical pages holding the longest already-indexed full-page
        prefix of ``tokens`` (device view: spilled entries report -1)."""
        return [nd.page for nd in self.match_nodes(tokens)]

    def insert(self, tokens: Sequence[int], pids: Sequence[int]) -> int:
        """Index ``pids`` as holding the leading full pages of ``tokens``.
        Existing resident nodes win (first prefill published; contents are
        identical by construction) and take no extra reference; an
        existing SPILLED twin is re-pointed at the freshly prefilled
        device page instead (a free promotion — the host copy is dropped).
        Returns how many pages were newly device-indexed."""
        ps = self.page_size
        node, t, added = self.root, self._tick(), 0
        for i, pid in enumerate(pids):
            key = _page_key(tokens[i * ps:(i + 1) * ps])
            child = node.children.get(key)
            if child is None:
                child = _Node(parent=node, key=key)
                child.page = int(pid)
                node.children[key] = child
                self.pool.share(int(pid))
                self.pages += 1
                added += 1
            elif child.page < 0:
                child.page = int(pid)
                self.pool.share(int(pid))
                self.pages += 1
                if self.spill is not None:
                    self.spill.free(child.spill)
                child.spill = -1
                added += 1
            child.last_used = t
            node = child
        return added

    def promote(self, nd: _Node, pid: int) -> int:
        """Re-admit spilled node ``nd`` at device page ``pid`` (the tree
        takes over the caller's freshly allocated reference).  Returns the
        spill slot whose payload must be scattered into ``pid`` — the
        caller frees it only AFTER that copy is dispatched."""
        sid = nd.spill
        nd.page, nd.spill = int(pid), -1
        self.pages += 1
        return sid

    def _evictable(self, nd: _Node) -> bool:
        """Device-resident, tree-only reference, and no device-resident
        child — residency is a path prefix (see class docstring), so
        childless-in-the-device-tier means leaf of the device tier."""
        return (nd.page >= 0
                and int(self.pool.refcount[nd.page]) == 1
                and not any(c.page >= 0 for c in nd.children.values()))

    def _evictable_leaves(self) -> List[_Node]:
        out, stack = [], [self.root]
        while stack:
            nd = stack.pop()
            stack.extend(nd.children.values())
            if nd is not self.root and self._evictable(nd):
                out.append(nd)
        return out

    def evict(self, n_pages: int) -> int:
        """Free up to ``n_pages`` least-recently-used device pages whose
        only reference is the tree's own, in ONE pass: the evictable set
        is collected once and maintained incrementally on a heap (a parent
        joins when its last device-resident child leaves) instead of
        re-walking the whole tree per freed page.  Candidate stamps are
        unique — equal stamps only occur along one ancestor chain, never
        between two simultaneously evictable nodes — so the heap
        reproduces the old rescan-per-page order exactly (property-pinned
        in ``tests/test_paged.py``).

        With a spill tier, each victim's payload is demoted host-side
        through ``read_page`` (synchronously — the freed device page may
        be reallocated and overwritten within the same admit) and the node
        survives as a spilled entry; without one, or when the tier is
        full, the node is dropped as before (a node whose spilled children
        would be stranded by a drop stays resident instead).  Returns
        device pages actually freed."""
        heap: List[Tuple[int, int, _Node]] = []
        n = 0

        def push(nd: _Node) -> None:
            nonlocal n
            if nd is not self.root and self._evictable(nd):
                n += 1
                heapq.heappush(heap, (nd.last_used, n, nd))

        stack = [self.root]
        while stack:
            nd = stack.pop()
            stack.extend(nd.children.values())
            push(nd)
        can_spill = self.spill is not None and self.read_page is not None
        freed = 0
        while freed < n_pages and heap:
            _, _, victim = heapq.heappop(heap)
            if not self._evictable(victim):
                continue  # stale heap entry
            sid = self.spill.alloc() if can_spill else -1
            if sid >= 0:
                self.spill.write(sid, self.read_page(victim.page))
                if self.telemetry is not None:
                    self.telemetry.registry.counter("pool_demotions").inc()
                    self.telemetry.event("pool.demote", page=int(victim.page),
                                         spill=int(sid))
                self.pool.release(victim.page)
                victim.page, victim.spill = -1, sid
            elif victim.children:
                continue  # drop would strand spilled descendants: keep
            else:
                if self.telemetry is not None:
                    self.telemetry.registry.counter("pool_evictions").inc()
                    self.telemetry.event("pool.evict", page=int(victim.page))
                del victim.parent.children[victim.key]
                self.pool.release(victim.page)
            self.pages -= 1
            freed += 1
            push(victim.parent)
        return freed

    # -- persistence -----------------------------------------------------
    def save(self, path: str, read_page: Optional[Callable] = None) -> int:
        """Serialize the whole prefix cache — tree structure, LRU stamps,
        and every indexed page's KV payload (spilled entries straight from
        the spill tier, device-resident ones fetched through
        ``read_page``) — into one ``.npz``.  Format (docs/serving.md):
        ``page_size`` scalar, ``parent`` [N] int64 node-list indices
        (-1 = root; parents always precede children), ``tokens`` [N, ps]
        int32 page keys, ``last_used`` [N] int64, plus one
        ``rows/<leaf path>`` [N, ...] array per pool leaf.  Returns the
        number of pages saved."""
        read_page = read_page or self.read_page
        order: List[_Node] = []
        index: Dict[int, int] = {id(self.root): -1}
        stack = [self.root]
        while stack:
            nd = stack.pop()
            for c in nd.children.values():
                index[id(c)] = len(order)
                order.append(c)
                stack.append(c)
        ps = self.page_size
        payload: Dict[str, List[np.ndarray]] = {}
        for nd in order:
            if nd.page >= 0:
                if read_page is None:
                    raise ValueError(
                        "save needs a read_page callback to fetch "
                        "device-resident pages (the engine's page reader)")
                rows = read_page(nd.page)
            else:
                rows = self.spill.read(nd.spill)
            for k, v in rows.items():
                payload.setdefault(k, []).append(np.asarray(v))
        # extension dtypes (bfloat16, fp8) round-trip npz as opaque void —
        # store them bit-cast to a same-width uint plus the dtype name
        stacks = {}
        for k, v in payload.items():
            arr = np.stack(v)
            if arr.dtype.kind not in "fiub":
                stacks[f"dtype/{k}"] = np.str_(arr.dtype.name)
                arr = arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
            stacks[f"rows/{k}"] = arr
        np.savez(
            path, page_size=np.int64(ps),
            parent=np.array([index[id(nd.parent)] for nd in order], np.int64),
            tokens=(np.stack([np.frombuffer(nd.key, np.int32) for nd in order])
                    if order else np.zeros((0, ps), np.int32)),
            last_used=np.array([nd.last_used for nd in order], np.int64),
            **stacks)
        return len(order)

    def restore(self, path: str) -> int:
        """Load a saved prefix cache.  Every restored page lands in the
        SPILL tier — no device pages are touched; payloads promote on
        their first prefix hit — and live entries win over colliding saved
        ones.  Entries beyond the tier's free slots are dropped (children
        of a dropped node follow it).  Returns pages actually restored."""
        if self.spill is None:
            raise ValueError(
                "restore needs a spill tier (spill_pages > 0): restored "
                "pages are host-resident until their first prefix hit")
        data = np.load(path)
        ps = int(data["page_size"])
        if ps != self.page_size:
            raise ValueError(f"kv store was saved at page_size={ps}; this "
                             f"pool uses page_size={self.page_size}")
        parents, tokens = data["parent"], data["tokens"]
        stamps = data["last_used"]
        row_keys = [k for k in data.files if k.startswith("rows/")]
        dtypes = {}
        for k in row_keys:
            dk = "dtype/" + k[len("rows/"):]
            if dk in data.files:  # bit-cast extension dtype (e.g. bfloat16)
                import ml_dtypes  # jax dependency

                dtypes[k] = np.dtype(getattr(ml_dtypes, str(data[dk])))

        def rows_at(i: int) -> Dict[str, np.ndarray]:
            return {k[len("rows/"):]:
                    (data[k][i].view(dtypes[k]) if k in dtypes else data[k][i])
                    for k in row_keys}
        nodes: List[Optional[_Node]] = [None] * len(parents)
        restored = 0
        for i in range(len(parents)):
            pnode = self.root if parents[i] < 0 else nodes[int(parents[i])]
            if pnode is None:  # parent dropped/unrestorable: drop subtree
                continue
            key = tokens[i].tobytes()
            child = pnode.children.get(key)
            if child is not None:  # live entry wins over the stored twin
                nodes[i] = child
                continue
            sid = self.spill.alloc()
            if sid < 0:
                continue  # tier full: drop (descendants follow)
            self.spill.write(sid, rows_at(i))
            child = _Node(parent=pnode, key=key)
            child.spill = sid
            child.last_used = int(stamps[i])
            pnode.children[key] = child
            nodes[i] = child
            restored += 1
        if len(stamps):
            self._clock = max(self._clock, int(stamps.max()) + 1)
        return restored


# ---------------------------------------------------------------------------
# page tables + admission + COW
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class AdmitPlan:
    """Host-side result of admitting one request: what the engine must
    dispatch to the device before the slot's first segment.  Demotions
    never appear here — eviction copies payloads host-side synchronously
    (the freed page may be reallocated within this very plan); promotions
    are work lists because the scatter targets freshly allocated device
    pages this plan owns."""

    resume: int                        # prompt tokens already cached (skip)
    fresh_pages: List[int]             # newly allocated -> need invalidation
    cow: List[Tuple[int, int]]         # (src, dst) page copies to dispatch
    hit_pages: int                     # full pages served from the tree
    # (spill slot, dst page, keep-below offset) scatters to dispatch:
    # promote-from-spill re-admissions (keep = page_size) and the
    # spilled-COW variant (keep = resume % page_size, tail recomputed)
    promote: List[Tuple[int, int, int]] = dataclasses.field(
        default_factory=list)
    # spill slots to return once the promote scatters are dispatched
    free_spill: List[int] = dataclasses.field(default_factory=list)


class PagedCacheManager:
    """Page tables, admission control, COW, and the radix lifecycle.

    The manager never touches device arrays: ``admit`` returns an
    ``AdmitPlan`` naming the pages to invalidate/copy, and ``table`` is a
    plain int32 numpy array the engine ships with every dispatch.  A
    request's worst-case page reserve (``ceil((plen + budget) / ps)``) is
    allocated at admit, so the table is segment-invariant and the pool can
    never run dry mid-flight — exhaustion is an admit-time
    ``PoolExhausted``.
    """

    def __init__(self, n_pages: int, page_size: int, use_radix: bool = True,
                 spill_pages: int = 0):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.pool = PagePool(n_pages)
        self.page_size = page_size
        self.spill = (SpillPool(spill_pages)
                      if use_radix and spill_pages > 0 else None)
        self.radix = (RadixTree(page_size, self.pool, spill=self.spill)
                      if use_radix else None)
        self.trash = n_pages  # physical index of the FREE-slot write sink
        self.table: Optional[np.ndarray] = None
        self._slot_pages: List[List[int]] = []

    def set_page_reader(self, read_page: Callable[[int], Dict[str, np.ndarray]]
                        ) -> None:
        """Register the engine's device->host page fetch
        (``models/serve.py::page_rows`` over the live pool) — eviction
        demotes and ``save`` serializes resident pages through it."""
        if self.radix is not None:
            self.radix.read_page = read_page

    def begin(self, slots: int, max_pages: int) -> None:
        """Start a workload: fresh all-FREE tables.  Slots a previous
        workload left admitted (an exception mid-``generate`` — the engine
        is long-lived, so it must not stay wedged) are released here;
        radix-indexed pages persist either way."""
        for s, pages in enumerate(self._slot_pages):
            if pages:
                self.release(s)
        self.table = np.full((slots, max_pages), self.trash, np.int32)
        self._slot_pages = [[] for _ in range(slots)]

    # -- admission -------------------------------------------------------
    def admit(self, slot: int, tokens: Sequence[int], budget: int,
              label: str = "request") -> AdmitPlan:
        """Map slot ``slot`` for a prompt of ``tokens`` plus ``budget``
        generated tokens.  Radix-matched prefix pages are mapped shared
        (copy-free); the rest of the reserve is allocated fresh.  When the
        match covers the whole prompt, the last matched page is taken via
        copy-on-write instead — the resumed prefill must recompute (and
        rewrite) the final token to produce first-token logits, and a
        shared page must never be written."""
        if self._slot_pages[slot]:
            raise ValueError(f"slot {slot} admitted twice without release")
        ps = self.page_size
        plen = len(tokens)
        need = max(-(-(plen + budget) // ps), 1)
        if need > self.table.shape[1]:
            raise ValueError(
                f"{label}: needs {need} pages ({plen} prompt + {budget} new "
                f"tokens at page_size={ps}) but the table is only "
                f"{self.table.shape[1]} pages wide")
        nodes = (self.radix.match_nodes(tokens)
                 if self.radix is not None else [])
        m = len(nodes)
        resume = min(m * ps, max(plen - 1, 0))
        n_shared = m if resume == m * ps else m - 1
        shared = nodes[:n_shared]
        cow_src = nodes[n_shared:]  # 0 or 1 node (the full-cover case)
        # take refs on every RESIDENT matched page first — the shared ones
        # we keep AND the COW source (its protective ref is dropped once
        # the copy pair is recorded) — so eviction can't free a page the
        # plan reads.  Spilled entries have no device page to protect, and
        # eviction never touches the spill tier.
        for nd in (*shared, *cow_src):
            if nd.page >= 0:
                self.pool.share(nd.page)
        # device pages to allocate: the non-shared remainder of the
        # reserve, plus one promote target per spilled shared page
        fresh_needed = (need - n_shared
                        + sum(1 for nd in shared if nd.page < 0))
        if self.pool.free_count < fresh_needed and self.radix is not None:
            self.radix.evict(fresh_needed - self.pool.free_count)
        if self.pool.free_count < fresh_needed:
            for nd in (*shared, *cow_src):
                if nd.page >= 0:
                    self.pool.release(nd.page)
            raise PoolExhausted(
                f"{label}: needs {fresh_needed} free pages ({plen} prompt + "
                f"{budget} new tokens at page_size={ps}, {n_shared} prefix "
                f"pages shared) but only {self.pool.free_count} of "
                f"{self.pool.n_pages} are free")
        promote: List[Tuple[int, int, int]] = []
        free_spill: List[int] = []
        pids: List[int] = []
        for nd in shared:
            if nd.page < 0:  # spilled prefix page: promote back on-device
                pid = self.pool.alloc()        # becomes the tree's reference
                sid = self.radix.promote(nd, pid)
                promote.append((sid, pid, ps))  # keep the whole page
                free_spill.append(sid)
                self.pool.share(pid)           # the slot's reference
            pids.append(nd.page)
        cow: List[Tuple[int, int]] = []
        if cow_src:
            nd = cow_src[0]
            dst = self.pool.alloc()
            if nd.page < 0:
                # spilled COW source: scatter the payload STRAIGHT into the
                # slot's private dst page (the tree's copy stays spilled)
                promote.append((nd.spill, dst, resume % ps))
            else:
                cow.append((nd.page, dst))
                self.pool.release(nd.page)  # drop the protective ref
            pids.append(dst)
        fresh = [self.pool.alloc() for _ in range(need - len(pids))]
        pids.extend(fresh)
        self.table[slot, :] = -1
        self.table[slot, :need] = pids
        self._slot_pages[slot] = pids
        return AdmitPlan(resume=resume, fresh_pages=fresh, cow=cow,
                         hit_pages=m, promote=promote, free_spill=free_spill)

    def release(self, slot: int) -> None:
        """Return the slot's pages (tree-shared ones survive via their
        tree refcount) and park the row on the trash page."""
        for pid in self._slot_pages[slot]:
            self.pool.release(pid)
        self._slot_pages[slot] = []
        self.table[slot, :] = self.trash

    # -- copy-on-write ---------------------------------------------------
    def ensure_writable(self, slot: int, logical_j: int
                        ) -> Optional[Tuple[int, int]]:
        """Make logical page ``logical_j`` of ``slot`` exclusively owned.
        Returns the ``(src, dst)`` device copy to dispatch when the page
        was shared (after which no page is reachable from two tables),
        ``None`` when it already was exclusive."""
        pid = int(self.table[slot, logical_j])
        if pid < 0 or pid == self.trash:
            raise ValueError(f"slot {slot} logical page {logical_j} unmapped")
        if int(self.pool.refcount[pid]) <= 1:
            return None
        dst = self.pool.alloc()
        self.pool.release(pid)
        self.table[slot, logical_j] = dst
        self._slot_pages[slot][logical_j] = dst
        return pid, dst

    # -- radix lifecycle -------------------------------------------------
    def complete_prefill(self, slot: int, tokens: Sequence[int]) -> int:
        """Prefill finished: publish the prompt's full pages so future
        requests sharing the prefix map them copy-free."""
        if self.radix is None:
            return 0
        full = len(tokens) // self.page_size
        if not full:
            return 0
        return self.radix.insert(list(tokens)[: full * self.page_size],
                                 self._slot_pages[slot][:full])

    @property
    def pages_in_use(self) -> int:
        return self.pool.used_count

    @property
    def spilled_pages(self) -> int:
        return self.spill.used_count if self.spill is not None else 0

    # -- persistence -----------------------------------------------------
    def save(self, path: str,
             read_page: Optional[Callable] = None) -> int:
        """Persist the prefix cache (radix tree + page payloads) to
        ``path``; see ``RadixTree.save`` for the format."""
        if self.radix is None:
            raise ValueError("save: this pool has no radix prefix cache "
                             "(use_radix=False)")
        return self.radix.save(path, read_page)

    def restore(self, path: str) -> int:
        """Load a persisted prefix cache into the spill tier (requires
        ``spill_pages > 0``); pages promote on their first prefix hit."""
        if self.radix is None:
            raise ValueError("restore: this pool has no radix prefix cache "
                             "(use_radix=False)")
        return self.radix.restore(path)


# ---------------------------------------------------------------------------
# jitted page maintenance programs
# ---------------------------------------------------------------------------


def _leaf_names(path) -> List[str]:
    return [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]


def paged_reset(cache: Params, i, page_ids: jnp.ndarray) -> Params:
    """Slot + page invalidation in one program: slot ``i``'s per-slot rows
    reset exactly like ``reset_slot`` (dense ``kpos`` -> -1, recurrent
    state -> 0), and the pool's ``pkpos`` rows at ``page_ids`` go to -1 —
    newly allocated pages may hold a previous owner's entries, which must
    not alias the new sequence's positions.  ``page_ids`` is fixed-width;
    pad with any out-of-range id (they scatter with ``mode="drop"``)."""
    cache = DL.reset_slot(cache, i)

    def fix(path, leaf):
        names = _leaf_names(path)
        if names[-1] != "pkpos":
            return leaf
        if names[0] == "tail":
            return leaf.at[page_ids].set(-1, mode="drop")
        return leaf.at[:, page_ids].set(-1, mode="drop")

    return jax.tree_util.tree_map_with_path(fix, cache)


def copy_page(cache: Params, src, dst, drop_from) -> Params:
    """Copy physical page ``src`` -> ``dst`` in every attention layer (the
    COW primitive).  Entries at in-page offsets ``>= drop_from`` are
    invalidated in the copy: they are the COW'd tail the resumed prefill
    will recompute and rewrite, and leaving them valid would double-count
    against the chunk program's own intra-window keys."""
    keep = None

    def fix(path, leaf):
        nonlocal keep
        names = _leaf_names(path)
        kind = names[-1]
        if kind not in ("pk", "pv", "pkpos"):
            return leaf
        stacked = names[0] != "tail"
        row = leaf[:, src] if stacked else leaf[src]
        if kind == "pkpos":
            ps = leaf.shape[-1]
            if keep is None:
                keep = jnp.arange(ps) < drop_from
            row = jnp.where(keep, row, -1)
        return leaf.at[:, dst].set(row) if stacked else leaf.at[dst].set(row)

    return jax.tree_util.tree_map_with_path(fix, cache)


def promote_page(cache: Params, dst, rows: Dict[str, jnp.ndarray],
                 keep_below) -> Params:
    """Scatter one spilled page's host rows into physical page ``dst`` —
    the spill tier's re-admit primitive, the exact inverse of the
    ``models/serve.py::page_rows`` demotion gather (``rows`` is keyed by
    ``pool_leaf_key``).  ``pkpos`` entries at in-page offsets
    ``>= keep_below`` are invalidated in the scatter: a plain re-admit
    passes ``page_size`` (keep everything), the spilled-COW path passes
    the resume offset so the tail the resumed prefill recomputes is not
    double-counted (mirroring ``copy_page``'s ``drop_from``)."""
    keep = None

    def fix(path, leaf):
        nonlocal keep
        names = _leaf_names(path)
        kind = names[-1]
        if kind not in ("pk", "pv", "pkpos"):
            return leaf
        row = jnp.asarray(rows[SV.pool_leaf_key(path)])
        if kind == "pkpos":
            ps = leaf.shape[-1]
            if keep is None:
                keep = jnp.arange(ps) < keep_below
            row = jnp.where(keep, row, -1)
        stacked = names[0] != "tail"
        return leaf.at[:, dst].set(row) if stacked else leaf.at[dst].set(row)

    return jax.tree_util.tree_map_with_path(fix, cache)


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------


class PagedServeEngine(DL.ServeEngine):
    """Continuous batching over the slot-shared paged pool.

    Same fused mixed-step scheduler as ``ServeEngine`` — the segment
    program just reads/writes attention K/V through the page table, so
    ``compiled_programs()`` stays a bounded set (one segment, one
    reset-and-invalidate, one COW copy, one promote-from-spill scatter)
    and program size is flat in ``n_pages`` (the pool only changes array
    DIMENSIONS; the page loop is ``fori_double_buffered`` over logical
    pages).  What changes is the slot lifecycle:

      admit   — radix-match the prompt, map shared prefix pages copy-free
                (prefill resumes AFTER them; spilled prefix pages are
                promoted back into fresh device pages first), allocate
                the rest of the worst-case reserve, invalidate fresh
                pages, dispatch COW copies and promote scatters.  A
                request that cannot fit defers while other slots hold
                pages and raises ``ValueError`` (naming it) when the pool
                could never take it.
      release — refcount-release the slot's pages; radix-published prefix
                pages survive for future requests (two-tier: with
                ``n_host_chunks > 0`` the pool itself is host-resident
                and pages stream device-ward inside attention).

    With ``spill_pages > 0`` eviction demotes cold radix pages into the
    host-resident :class:`SpillPool` instead of dropping them, and
    ``save_kv_store``/``restore_kv_store`` persist the prefix cache
    across engine restarts — a second process serving the same system
    prompt gets radix hits, not cold prefills.

    ``radix=True`` only takes effect for pure global-attention layouts:
    recurrent blocks (ssm/rglru/local_attn ring) integrate the whole
    prefix into per-slot state that a mapped page cannot restore, so
    prefix skipping would be silently wrong — those layouts still get the
    paged pool, just with ``resume = 0``.

    The pool (and its radix-indexed contents) persists across
    ``generate`` calls — a shared system prompt served in one workload is
    a prefix hit in the next.
    """

    def __init__(self, cfg: ModelConfig, params: Params, *, slots: int,
                 bucket: int, max_new_tokens: int, page_size: int = 16,
                 n_pages: int = 0, radix: bool = True, spill_pages: int = 0,
                 prefill_chunk: int = 0, n_host_chunks: int = 0,
                 sampling: DL.SamplingConfig = DL.GREEDY,
                 stop_tokens: Sequence[int] = (), pad_id: int = 0,
                 segment: int = 8, par: Optional[ParallelContext] = None):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.page_size = int(page_size)
        if n_pages <= 0:  # default: dense-equivalent capacity
            n_pages = slots * -(-(bucket + max_new_tokens) // self.page_size)
        self.n_pages = int(n_pages)
        pat, _, tail = layout_of(cfg)
        self.radix_enabled = bool(radix) and all(
            k == "attn" for k in (*pat, *tail))
        self.kv = PagedCacheManager(
            self.n_pages, self.page_size, use_radix=self.radix_enabled,
            spill_pages=spill_pages if self.radix_enabled else 0)
        self.kv.set_page_reader(self._read_page)
        self._pool_cache = SV.init_paged_cache(cfg, slots, self.n_pages,
                                               self.page_size)
        # freshest pool view for host-side page reads (demotion at evict
        # time, save_kv_store): re-pointed after every program that writes
        # the pool so read_page never sees a stale page payload
        self._cur_cache = self._pool_cache
        self._table_dev = None  # device copy, refreshed at admit/release
        self._inserted = [True] * slots
        super().__init__(cfg, params, slots=slots, bucket=bucket,
                         max_new_tokens=max_new_tokens,
                         prefill_chunk=prefill_chunk,
                         n_host_chunks=n_host_chunks, sampling=sampling,
                         stop_tokens=stop_tokens, pad_id=pad_id,
                         segment=segment, par=par)
        if self.kv.radix is not None:
            self.kv.radix.telemetry = self.telemetry
        if self.cp % self.page_size and self.page_size % self.cp:
            raise ValueError(
                f"prefill_chunk={self.cp} and page_size={self.page_size} "
                f"must divide one another: radix prefix hits resume prefill "
                f"at a page boundary, and only a mutually-dividing grid "
                f"keeps every chunk window inside the slot's allocated page "
                f"reserve")
        # two-tier placement: the cold pool lives host-side; attention
        # streams gathered pages device-ward (no-op on CPU)
        self._pool_cache = self._offload_pool(self._pool_cache)
        self._cur_cache = self._pool_cache

    def _read_page(self, pid: int) -> Dict[str, np.ndarray]:
        """Fetch one physical page's K/V rows host-side (demotion + save)."""
        return SV.page_rows(self._cur_cache, int(pid))

    # -- prefix-cache persistence ----------------------------------------
    # cumulative counters (kv_store_saved_pages / kv_store_restored_pages)
    # let the serve tier report how much failover recovery actually moved
    # through the store, across however many publish/restore rounds
    def save_kv_store(self, path: str) -> int:
        """Persist the radix tree + every cached page payload to ``path``."""
        n = self.kv.save(path, self._read_page)
        self.kv_store_saved_pages = getattr(
            self, "kv_store_saved_pages", 0) + n
        self.telemetry.registry.counter("kvstore_saved_pages").inc(n)
        self.telemetry.event("kvstore.save", pages=n)
        return n

    def restore_kv_store(self, path: str) -> int:
        """Load a persisted prefix cache into the spill tier (pages promote
        to device lazily, on their first radix hit)."""
        n = self.kv.restore(path)
        self.kv_store_restored_pages = getattr(
            self, "kv_store_restored_pages", 0) + n
        self.telemetry.registry.counter("kvstore_restored_pages").inc(n)
        self.telemetry.event("kvstore.restore", pages=n)
        return n

    def _offload_pool(self, cache):
        """Park the pool's K/V leaves in the offload tier when the engine
        is host-streaming — applied at init AND after every dispatch (the
        segment's outputs land in default memory; re-offloading mirrors
        ``launch/steps.py``'s per-step cache re-offload)."""
        if self.par is None or not self.n_host_chunks:
            return cache

        # host-placement custom-calls reject PARTIAL replication: on a
        # mesh the parked pool must shard over EVERY axis.  Prefer the
        # in-page dim (pages always divide evenly when ps does), fall back
        # to kv heads, then the page-count dim; when NO dim divides, a
        # single-device spec would silently gather a mesh-sharded pool to
        # one host buffer — skip the offload instead and say so once
        spec = ()
        if self.par.mesh is not None:
            n = self.par.mesh.size
            all_axes = tuple(self.par.mesh.axis_names)
            if self.page_size % n == 0:
                spec = (None, all_axes, None, None)
            elif self.cfg.num_kv_heads % n == 0:
                spec = (None, None, all_axes, None)
            elif (self.n_pages + 1) % n == 0:
                spec = (all_axes, None, None, None)
            else:
                from repro.runtime.placement import _warn_once
                _warn_once(
                    "paged-offload-indivisible",
                    f"pool offload skipped: no pool dim (page_size="
                    f"{self.page_size}, kv_heads={self.cfg.num_kv_heads}, "
                    f"pages+1={self.n_pages + 1}) divides mesh size {n}; "
                    f"the pool stays in device memory")
                return cache

        def offload(path, leaf):
            names = _leaf_names(path)
            if names[-1] not in ("pk", "pv"):
                return leaf
            lead = (None,) if names[0] != "tail" else ()
            return self.par.to_host(leaf, *(lead + spec if spec else ()))

        return jax.tree_util.tree_map_with_path(offload, cache)

    # -- compiled programs ----------------------------------------------
    def _segment_shardings(self):
        """Pool-layout shardings over the CONCRETE pool (its shapes never
        change — capacity lives in the page table, not the arrays), plus a
        replicated page-table argument."""
        if self.par is None or self.par.mesh is None:
            return None
        return DL.segment_shardings(self.cfg, self.par, self._pool_cache,
                                    table=True)

    def _build_programs(self) -> None:
        cfg, par, params = self.cfg, self.par, self.params

        def seg(cache, mode, tok, pos, key, rem, pfill, pend, plen, table):
            return DL.mixed_segment(cfg, par, params, cache, mode, tok, pos,
                                    key, rem, pfill, pend, plen,
                                    num_steps=self.segment,
                                    prefill_chunk=self.cp,
                                    n_host_chunks=self.n_host_chunks,
                                    sampling=self.sampling,
                                    stop_tokens=self._stop,
                                    pad_id=self.pad_id, table=table)

        tel = self.telemetry
        sh = self._segment_shardings()
        if sh is None:
            self._cache_sh = None
            self._segment = jax.jit(DL.per_engine(seg, tel, "segment"))
            self._reset = jax.jit(DL.per_engine(paged_reset, tel, "reset"))
            self._copy = jax.jit(DL.per_engine(copy_page, tel, "copy"))
            self._promote = jax.jit(
                DL.per_engine(promote_page, tel, "promote"))
        else:
            # page copy/COW become sharded programs over the same pool
            # layout — each device moves only its own head (or in-page)
            # slice, no gather to one device
            in_sh, out_sh = sh
            csh, r = in_sh[0], par.ns()
            self._cache_sh = csh
            self._segment = jax.jit(DL.per_engine(seg, tel, "segment"),
                                    in_shardings=in_sh,
                                    out_shardings=out_sh)
            self._reset = jax.jit(DL.per_engine(paged_reset, tel, "reset"),
                                  in_shardings=(csh, r, r), out_shardings=csh)
            self._copy = jax.jit(DL.per_engine(copy_page, tel, "copy"),
                                 in_shardings=(csh, r, r, r),
                                 out_shardings=csh)
            # the promoted rows dict gets `r` as a pytree PREFIX: every
            # host-staged row enters replicated, the scatter re-shards it
            # into the pool's own layout
            self._promote = jax.jit(
                DL.per_engine(promote_page, tel, "promote"),
                in_shardings=(csh, r, r, r),
                out_shardings=csh)
            # commit the persistent pool to its sharding NOW: the first
            # admit otherwise sees uncommitted arrays and compiles a second
            # reset signature, breaking the bounded-program guarantee
            self._pool_cache = jax.device_put(self._pool_cache, csh)

    def compiled_programs(self) -> Dict[str, int]:
        return {"segment": self._segment._cache_size(),
                "reset": self._reset._cache_size(),
                "copy": self._copy._cache_size(),
                "promote": self._promote._cache_size()}

    # -- slot lifecycle --------------------------------------------------
    def _begin(self, B: int, P: int, S: int):
        max_pages = -(-(P + self.max_new) // self.page_size)
        self.kv.begin(B, max_pages)
        self._table_dev = None
        self._inserted = [True] * B
        self.last_stats.update({
            "page_size": self.page_size, "n_pages": self.n_pages,
            "max_pages": max_pages, "radix": self.radix_enabled,
            "prompt_tokens": 0, "prefilled_tokens": 0,
            "prefix_hit_tokens": 0, "cow_copies": 0, "deferrals": 0,
            "pages_peak": 0, "radix_pages": 0,
            "spill_pages":
                0 if self.kv.spill is None else self.kv.spill.n_spill,
            "spill_promotes": 0, "spilled_pages": self.kv.spilled_pages,
        })
        return self._pool_cache

    def _admit(self, cache, s: int, idx: int, prompt, active: bool,
               budget: Optional[int] = None):
        st = self.last_stats
        budget = self.max_new if budget is None else int(budget)
        self._cur_cache = cache  # eviction may demote: read the live pool
        try:
            plan = self.kv.admit(s, list(prompt), budget,
                                 label=f"request {idx}")
        except PoolExhausted as e:
            if active:  # running slots will release pages; retry next round
                st["deferrals"] += 1
                self.telemetry.event("pool.defer", request=idx, slot=s)
                return None
            raise ValueError(str(e)) from None
        ids = np.full(self.n_pages, self.n_pages + 1, np.int32)  # pad -> OOB
        ids[: len(plan.fresh_pages)] = plan.fresh_pages
        cache = self._reset(cache, s, jnp.asarray(ids))
        for sid, dst, keep in plan.promote:
            rows = {k: jnp.asarray(v)
                    for k, v in self.kv.spill.read(sid).items()}
            cache = self._promote(cache, jnp.int32(dst), rows,
                                  jnp.int32(keep))
            st["spill_promotes"] += 1
        if plan.promote:
            self.telemetry.event("pool.promote", request=idx, slot=s,
                                 n=len(plan.promote))
        for sid in plan.free_spill:  # scatter dispatched: slot reusable
            self.kv.spill.free(sid)
        for src, dst in plan.cow:
            cache = self._copy(cache, jnp.int32(src), jnp.int32(dst),
                               jnp.int32(plan.resume % self.page_size))
            st["cow_copies"] += 1
            self.telemetry.event("pool.cow", request=idx, slot=s,
                                 src=int(src), dst=int(dst))
        # crash consistency: the radix tree now points at the promoted /
        # reset pages, so the pool holding them must survive even if this
        # workload dies before _end (a dispatch failure must not strand
        # the tree on data that only lived in the lost functional value)
        self._pool_cache = self._cur_cache = cache
        self._table_dev = None  # table changed: re-ship at next dispatch
        st["resets"] += 1
        st["prompt_tokens"] += len(prompt)
        st["prefilled_tokens"] += len(prompt) - plan.resume
        st["prefix_hit_tokens"] += plan.resume
        st["pages_peak"] = max(st["pages_peak"], self.kv.pages_in_use)
        self._inserted[s] = False
        return cache, plan.resume

    def _dispatch(self, cache, mode, tok, pos, key, rem, pfill, pend, plen):
        if self._table_dev is None:
            self._table_dev = jnp.asarray(self.kv.table)
        emits, valids, aux = self._segment(cache, mode, tok, pos, key, rem,
                                           pfill, pend, plen, self._table_dev)
        aux["cache"] = self._offload_pool(aux["cache"])
        # keep the persistent pool pointing at the freshest value: pages
        # published to the radix tree mid-workload must survive a failure
        # on a LATER segment dispatch
        self._pool_cache = self._cur_cache = aux["cache"]
        return emits, valids, aux

    def _post_dispatch(self, mode, pfill, plen, pend, owner) -> None:
        for s in range(self.slots):
            if owner[s] is None or self._inserted[s] or pfill[s] < plen[s]:
                continue
            self._inserted[s] = True
            self.kv.complete_prefill(s, [int(t) for t in pend[s, : plen[s]]])

    def _release(self, s: int) -> None:
        self.kv.release(s)
        self._table_dev = None  # table changed: re-ship at next dispatch

    def _end(self, cache) -> None:
        # the pool (radix-shared prefixes included) persists across calls
        self._pool_cache = cache
        self._cur_cache = cache
        if self.kv.radix is not None:
            self.last_stats["radix_pages"] = self.kv.radix.pages
            self.last_stats["spilled_pages"] = self.kv.spilled_pages


# ---------------------------------------------------------------------------
# SLO-aware scheduling
# ---------------------------------------------------------------------------


class SLOPagedServeEngine(PagedServeEngine):
    """Priority/deadline-aware admission with spill-backed preemption over
    the paged pool.

    The compiled programs are UNTOUCHED — scheduling is pure host Python
    around the same {segment, reset, copy, promote} set, exploiting two
    properties of the substrate:

      * **Preempt = publish + release.**  A DECODE slot's cached KV covers
        the token stream ``prompt + emitted`` up to ``pos`` exactly, so
        preemption is ``complete_prefill(s, stream[:pos])`` (publish the
        full pages into the radix tree — idempotent over the already-
        published prompt prefix) followed by ``release(s)``.  The tree
        keeps the pages; under later pool pressure they demote through
        the existing :class:`SpillPool` evict path.  Resume is a plain
        re-admission of ``prompt + emitted`` with the REMAINING token
        budget: the radix match maps the cached pages back (promoting
        spilled ones through the promote scatter) and prefill restarts at
        the match boundary — the ordinary ``_admit`` resume contract.  If
        eviction dropped the pages entirely, resume re-prefills them;
        under greedy sampling the output is token-identical either way.
      * **Pause = point the row at the trash page.**  A FREE slot and a
        mid-prefill slot whose table row maps every logical page to the
        trash page are indistinguishable at the program level (the fused
        step freezes ``pos``/``pfill``/``tok`` for FREE rows and their
        dummy writes land on the trash page), so a long prefill that has
        burned its per-request chunk budget is paused by saving its table
        row, trashing it, and flipping ``mode`` to FREE — the next
        dispatch takes the pure-decode fast path, protecting co-resident
        decodes' inter-token latency.  Resume restores the row.

    Requests are :class:`repro.runtime.decode_loop.Request` (raw token
    sequences are coerced with ``priority=1``/no deadline).  ``policy``:

      ``"slo"``  — admission order ``(priority, itl_slo, arrival)``;
                   lower-priority slots (decoding OR mid-prefill — a
                   part-prefilled slot publishes ``stream[:pfill]`` and
                   resumes at the last page boundary) are preempted when
                   a strictly-higher-priority request waits; prefill-chunk
                   budgets (``Request.prefill_chunks`` or the engine-wide
                   ``prefill_budget``) pause long prefills between bursts.
      ``"fifo"`` — arrival order, no preemption, no budgets: the measured
                   baseline, byte-identical outputs to ``"slo"`` under
                   greedy sampling.

    Both policies gate admission on ``Request.arrival`` (in dispatch
    steps): a request is invisible to the scheduler before it arrives, so
    a seeded trace replays identically — goodput-under-SLO comparisons in
    ``benchmarks/serve_bench.py`` are deterministic, not wall-clock-noisy.

    Recurrent layouts (ssm/rglru) are REFUSED: preemption restores a slot
    from mapped pages, but recurrent blocks fold the whole prefix into
    per-slot state a page cannot restore (the carried ROADMAP item
    "radix reuse for recurrent layouts").
    """

    def __init__(self, cfg: ModelConfig, params: Params, *,
                 policy: str = "slo", prefill_budget: int = 0, **kw):
        if policy not in ("slo", "fifo"):
            raise ValueError(f"policy must be 'slo' or 'fifo', got "
                             f"{policy!r}")
        kw.setdefault("radix", True)
        super().__init__(cfg, params, **kw)
        if not self.radix_enabled:
            pat, _, tail = layout_of(cfg)
            kinds = sorted({k for k in (*pat, *tail) if k != "attn"})
            if kinds:
                raise ValueError(
                    f"SLOPagedServeEngine: layout contains recurrent blocks "
                    f"{kinds}; preemption resumes a request from its mapped "
                    f"KV pages, but recurrent state is integrated over the "
                    f"whole prefix and cannot be restored from a page — "
                    f"resumed output would silently diverge.  Serve this "
                    f"layout with PagedServeEngine (FIFO, no preemption); "
                    f"see the carried ROADMAP item 'radix reuse for "
                    f"recurrent layouts'")
            raise ValueError(
                "SLOPagedServeEngine requires radix=True: preempted "
                "requests resume through radix prefix matching")
        self.policy = policy
        self.prefill_budget = int(prefill_budget)

    def _capacity(self, prompts: Sequence[Sequence[int]]) -> Tuple[int, int]:
        """A preempted request re-admits ``prompt + emitted`` as its
        pending buffer, so P must cover ``longest + max_new`` (the base
        engine only needs ``longest``)."""
        longest = max((len(p) for p in prompts), default=1)
        P = -(-max(self.bucket, longest + self.max_new) // self.cp) * self.cp
        S = P + self.max_new
        if self.n_host_chunks:
            S = -(-S // self.n_host_chunks) * self.n_host_chunks
        return P, S

    def _key(self, r: DL.Request, seq: int, ridx: int) -> Tuple:
        if self.policy == "slo":
            return (r.priority, r.itl_slo, seq, ridx)
        return (seq, ridx)

    # -- the scheduler ---------------------------------------------------
    def generate(self, prompts: Sequence[Union[DL.Request, Sequence[int]]],
                 key: Optional[jnp.ndarray] = None) -> List[List[int]]:
        """Run every request to completion, honouring arrivals, priorities
        and budgets.  Returns one generated-token list per request, in
        input order (preempted requests' outputs are stitched across
        incarnations — token-identical to an uninterrupted run under
        greedy sampling).

        ``last_stats`` gains ``policy``/``preemptions``/``prefill_pauses``
        and a per-request ``requests`` list ({arrival, admit_step,
        first_emit, last_emit, max_gap, preemptions, n_emitted, priority,
        tier, prompt_len} — all step-indexed, so SLO attainment is
        deterministic given the trace)."""
        reqs = [DL.as_request(p) for p in prompts]
        self._validate([r.tokens for r in reqs])
        key = jax.random.PRNGKey(0) if key is None else key
        n = len(reqs)
        B = self.slots
        P, S = self._capacity([r.tokens for r in reqs])
        stats = self.telemetry.stats_view({
            "steps": self.telemetry.steps_ring(), "dispatches": 0,
            "resets": 0, "capacity": S,
            "pending_len": P, "policy": self.policy, "preemptions": 0,
            "prefill_pauses": 0,
            "requests": [{"arrival": int(r.arrival), "priority": r.priority,
                          "tier": r.tier, "prompt_len": len(r.tokens),
                          "admit_step": None, "first_emit": None,
                          "last_emit": None, "max_gap": 0, "preemptions": 0,
                          "n_emitted": 0} for r in reqs]})
        self.last_stats = stats
        rstat = stats["requests"]
        cache = self._begin(B, P, S)
        mode = np.full(B, DL.FREE, np.int32)
        tok = np.zeros((B, 1), np.int32)
        pos = np.zeros(B, np.int32)
        rem = np.zeros(B, np.int32)
        pfill = np.zeros(B, np.int32)
        pend = np.full((B, P), self.pad_id, np.int32)
        plen = np.ones(B, np.int32)
        owner: List[Optional[int]] = [None] * B
        emitted: List[List[int]] = [[] for _ in reqs]
        paused = [False] * B        # mid-prefill, parked on the trash row
        saved_rows: List[Optional[np.ndarray]] = [None] * B
        skip = [0] * B              # paused rounds left before resume
        burst = [0] * B             # prefill chunks since admit/resume
        order = sorted(range(n), key=lambda i: (reqs[i].arrival, i))
        fptr = 0
        ready: List[Tuple] = []     # heap of self._key(...) entries
        seq = n                     # requeue seqnos, past all initial ones
        step = 0                    # dispatch-step clock

        def preempt(s: int) -> None:
            ridx = owner[s]
            stream = list(reqs[ridx].tokens) + emitted[ridx]
            # KV is cached for positions [0, pos) when decoding and
            # [0, pfill) mid-prefill: publish that prefix's full pages,
            # then release — the radix tree keeps them, so re-admission
            # resumes at the last page boundary instead of restarting
            cached = int(pos[s]) if mode[s] == DL.DECODE else int(pfill[s])
            self.kv.complete_prefill(s, stream[:cached])
            self._release(s)
            owner[s] = None
            mode[s] = DL.FREE
            burst[s] = 0
            rstat[ridx]["preemptions"] += 1
            stats["preemptions"] += 1
            self.telemetry.event("request.preempt", request=ridx, slot=s,
                                 step=step, session=reqs[ridx].session,
                                 cached=cached)
            nonlocal seq
            heapq.heappush(ready, self._key(reqs[ridx], seq, ridx))
            seq += 1

        def preempt_for(head_pri: int) -> bool:
            if self.policy != "slo":
                return False
            cands = [s for s in range(B)
                     if owner[s] is not None and not paused[s]
                     and mode[s] in (DL.DECODE, DL.PREFILL)
                     and reqs[owner[s]].priority > head_pri]
            if not cands:
                return False
            preempt(max(cands, key=lambda s: (reqs[owner[s]].priority, s)))
            return True

        while True:
            # resume paused prefills (one full round parked first: the
            # intervening dispatch takes the decode fast path)
            for s in range(B):
                if not paused[s]:
                    continue
                if skip[s] > 0:
                    skip[s] -= 1
                    continue
                self.kv.table[s, :] = saved_rows[s]
                self._table_dev = None
                mode[s] = DL.PREFILL
                paused[s] = False
                burst[s] = 0
                self.telemetry.event("request.pause_resume",
                                     request=owner[s], slot=s, step=step)
            # arrivals up to the current step become schedulable
            while fptr < n and reqs[order[fptr]].arrival <= step:
                ridx = order[fptr]
                fptr += 1
                self.telemetry.event(
                    "request.queued", request=ridx,
                    session=reqs[ridx].session,
                    step=int(reqs[ridx].arrival),
                    priority=reqs[ridx].priority, tier=reqs[ridx].tier)
                heapq.heappush(ready, self._key(reqs[ridx], ridx, ridx))
            # admission: fill free slots from the ready heap, preempting
            # lower-priority decodes when the head outranks them
            progress = True
            while ready and progress:
                progress = False
                free = [s for s in range(B) if owner[s] is None]
                if not free:
                    progress = preempt_for(reqs[ready[0][-1]].priority)
                    continue
                s = free[0]
                entry = heapq.heappop(ready)
                ridx = entry[-1]
                r = reqs[ridx]
                pending = list(r.tokens) + emitted[ridx]
                budget = self.max_new - len(emitted[ridx])
                active = any(o is not None for o in owner)
                admitted = self._admit(cache, s, ridx, pending, active,
                                       budget=budget)
                if admitted is None:  # pool-exhausted: retry after preempt
                    heapq.heappush(ready, entry)
                    progress = preempt_for(r.priority)
                    continue
                cache, resume = admitted
                owner[s] = ridx
                np_ = len(pending)
                pend[s, :np_] = pending
                pend[s, np_:] = self.pad_id
                plen[s], pfill[s], mode[s] = np_, resume, DL.PREFILL
                rem[s], pos[s], tok[s] = budget, 0, self.pad_id
                burst[s] = 0
                first_admit = rstat[ridx]["admit_step"] is None
                if first_admit:
                    rstat[ridx]["admit_step"] = step
                self.telemetry.event(
                    "request.admit" if first_admit else "request.resume",
                    request=ridx, slot=s, step=step, session=r.session,
                    prompt_len=np_, prefix_hit=int(resume))
                progress = True
            if all(o is None for o in owner):
                if fptr < n:  # idle: jump the clock to the next arrival
                    step = max(step, int(reqs[order[fptr]].arrival))
                    continue
                break
            key, sub = jax.random.split(key)
            n_prefilling = int((mode == DL.PREFILL).sum())
            with TM.timed_dispatch(self.telemetry, stats,
                                   prefilling=n_prefilling, step=step) as td:
                emits, valids, aux = self._dispatch(
                    cache, mode, tok, pos, sub, rem, pfill, pend, plen)
                cache = aux["cache"]
                mode, tok, pos, rem, pfill, em, va = (
                    np.array(x) for x in jax.device_get(
                        (aux["mode"], aux["tok"], aux["pos"], aux["rem"],
                         aux["pfill"], emits, valids)))
                td.emitted = int(va.sum())
            self._post_dispatch(mode, pfill, plen, pend, owner)
            for s in range(B):
                if owner[s] is None:
                    continue
                ridx = owner[s]
                toks = [int(t) for t, v in zip(em[s], va[s]) if v]
                if toks:
                    rs = rstat[ridx]
                    if rs["first_emit"] is None:
                        rs["first_emit"] = step
                    if rs["last_emit"] is not None:
                        rs["max_gap"] = max(rs["max_gap"],
                                            step - rs["last_emit"])
                    rs["last_emit"] = step
                    emitted[ridx].extend(toks)
                    self.telemetry.event(
                        "request.emit", request=ridx, slot=s, step=step,
                        session=reqs[ridx].session, n=len(toks))
                if paused[s]:  # parked: FREE at program level, still owned
                    continue
                if mode[s] == DL.FREE:
                    self._release(s)
                    self.telemetry.event(
                        "request.complete", request=ridx, slot=s, step=step,
                        session=reqs[ridx].session, n=len(emitted[ridx]))
                    owner[s] = None
            # prefill-chunk budgets: park a long prefill so co-resident
            # decodes get a pure-decode dispatch before it continues
            if self.policy == "slo":
                any_decode = any(int(m) == DL.DECODE for m in mode)
                for s in range(B):
                    if owner[s] is None or paused[s] or mode[s] != DL.PREFILL:
                        continue
                    burst[s] += 1
                    r = reqs[owner[s]]
                    b = r.prefill_chunks or self.prefill_budget
                    if b and burst[s] >= b and any_decode:
                        saved_rows[s] = self.kv.table[s].copy()
                        self.kv.table[s, :] = self.kv.trash
                        self._table_dev = None
                        mode[s] = DL.FREE
                        paused[s] = True
                        skip[s] = 1
                        stats["prefill_pauses"] += 1
                        self.telemetry.event(
                            "request.pause", request=owner[s], slot=s,
                            step=step, session=r.session)
            step += 1
        self._end(cache)
        for i in range(n):
            rstat[i]["n_emitted"] = len(emitted[i])
        return emitted
