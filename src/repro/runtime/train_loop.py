"""Training runtime: jitted step builder + fault-tolerant loop.

Fault tolerance for 1000+ nodes (DESIGN.md §7):
  * periodic async checkpoints (params, optimizer, data-iterator step);
  * SIGTERM/SIGINT triggers a blocking final checkpoint (preemption-safe);
  * `resume="auto"` restores the newest complete checkpoint, including onto
    a *different* mesh (elastic restart after losing nodes);
  * heartbeat/straggler monitor: per-step wall times are z-scored; a
    persistent outlier raises a StragglerAlert so the launcher can re-mesh
    (simulated multi-host demo in examples/fault_tolerance_demo.py).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ModelConfig
from repro.core.parallel import ParallelContext
from repro.models import transformer as T
from repro.optim import adamw
from repro.optim import compression as comp
from repro.runtime import placement
from repro.runtime import telemetry as TM


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    ckpt_every: int = 50
    log_every: int = 10
    grad_accum: int = 1
    compress_grads: bool = False  # int8 + error feedback (cross-pod traffic)
    straggler_zscore: float = 4.0
    straggler_patience: int = 3


class StragglerAlert(RuntimeError):
    pass


# ---------------------------------------------------------------------------
# step builder
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, par: Optional[ParallelContext],
                    oc: adamw.OptConfig, tc: Optional[TrainConfig] = None):
    """(params, opt_state, batch[, residuals]) -> (params, opt_state, metrics)."""
    tc = tc or TrainConfig()

    def loss(p, b):
        return T.loss_fn(cfg, par, p, b)

    def step(params, opt_state, batch, residuals=None):
        if tc.grad_accum > 1:
            def micro(i, carry):
                gsum, lsum = carry
                mb = jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * (x.shape[0] // tc.grad_accum), x.shape[0] // tc.grad_accum, 0
                    ),
                    batch,
                )
                (l, _), g = jax.value_and_grad(loss, has_aux=True)(params, mb)
                return jax.tree.map(jnp.add, gsum, g), lsum + l

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, lsum = jax.lax.fori_loop(0, tc.grad_accum, micro, (zeros, jnp.float32(0)))
            grads = jax.tree.map(lambda g: g / tc.grad_accum, grads)
            lval = lsum / tc.grad_accum
            metrics = {"loss": lval}
        else:
            (lval, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params, batch)
        if tc.compress_grads and residuals is not None:
            grads, residuals = comp.tree_quantize_with_feedback(grads, residuals)
        params, opt_state, om = adamw.apply(oc, params, grads, opt_state)
        metrics = dict(metrics)
        metrics.update(om)
        out = (params, opt_state, metrics)
        return out + ((residuals,) if residuals is not None else ())

    return step


# ---------------------------------------------------------------------------
# loop with fault tolerance
# ---------------------------------------------------------------------------


class HeartbeatMonitor:
    """Detects persistent stragglers from per-step wall time."""

    def __init__(self, zscore: float, patience: int):
        self.times: list = []
        self.z = zscore
        self.patience = patience
        self.bad = 0

    def record(self, dt: float) -> None:
        self.times.append(dt)
        hist = self.times[:-1][-100:]
        if len(hist) >= 10:
            mu, sd = float(np.mean(hist)), float(np.std(hist)) + 1e-9
            if (dt - mu) / sd > self.z:
                self.bad += 1
            else:
                self.bad = 0
        if self.bad >= self.patience:
            raise StragglerAlert(
                f"step time {dt:.3f}s is a persistent outlier (mu={np.mean(hist):.3f})"
            )


class TrainLoop:
    def __init__(self, cfg, par, oc, tc, step_fn, data_iter, ckpt_mgr=None):
        self.cfg, self.par, self.oc, self.tc = cfg, par, oc, tc
        self.step_fn = step_fn
        self.data = data_iter
        self.ckpt = ckpt_mgr
        self.monitor = HeartbeatMonitor(tc.straggler_zscore, tc.straggler_patience)
        self._stop = False
        self.history: list = []
        self.telemetry = TM.Telemetry(component="train")

    def _install_signals(self):
        def handler(signum, frame):
            self._stop = True

        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                signal.signal(sig, handler)
            except ValueError:
                pass  # not main thread

    def run(self, params, opt_state, start_step: int = 0, put_batch=None):
        self._install_signals()
        if put_batch is None:
            # default batch staging routes through the placement layer
            pol = self.par.pol if self.par is not None else placement.default_policy()
            put_batch = lambda b: {k: pol.put(jnp.asarray(v)) for k, v in b.items()}  # noqa: E731
        step = start_step
        self.data.restore(start_step)
        while step < self.tc.steps and not self._stop:
            t0 = time.perf_counter()
            batch = next(self.data)
            batch = put_batch(batch)
            params, opt_state, metrics = self.step_fn(params, opt_state, batch)[:3]
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            step += 1
            self.history.append({"step": step, "loss": float(metrics["loss"]), "dt": dt})
            self.telemetry.registry.histogram("train_step_ms").observe(dt * 1e3)
            self.telemetry.registry.counter("train_steps").inc()
            self.telemetry.registry.gauge("train_loss").set(float(metrics["loss"]))
            self.telemetry.event("train.step", step=step, dur_ms=dt * 1e3,
                                 loss=float(metrics["loss"]))
            if step % self.tc.log_every == 0:
                print(f"step {step:6d} loss {float(metrics['loss']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} {dt*1000:.0f}ms")
            if self.ckpt and step % self.tc.ckpt_every == 0:
                self.ckpt.save(step, {"params": params, "opt": opt_state},
                               extra={"data_step": self.data.state()})
            try:
                self.monitor.record(dt)
            except StragglerAlert as e:
                print(f"[ft] straggler detected: {e}; requesting re-mesh")
                break
        if self.ckpt and (self._stop or step >= self.tc.steps):
            # preemption or completion: blocking final save
            self.ckpt.save(step, {"params": params, "opt": opt_state},
                           extra={"data_step": self.data.state()}, blocking=True)
        return params, opt_state, step
