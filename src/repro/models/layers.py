"""Common model layers: norms, RoPE, attention projections, MLP.

Everything is a pure function over explicit param pytrees; parameter
initialization lives next to each layer.  Sharding is expressed by the
caller via ``repro.core.parallel.shard`` constraints — layer code is
mesh-agnostic.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig

Params = Dict[str, Any]


def _dense_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[0]
    return (jax.random.normal(key, shape) / math.sqrt(fan_in)).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(cfg: ModelConfig, dtype) -> Params:
    if cfg.norm == "layernorm":
        return {"w": jnp.ones((cfg.d_model,), dtype), "b": jnp.zeros((cfg.d_model,), dtype)}
    return {"w": jnp.ones((cfg.d_model,), dtype)}


def apply_norm(cfg: ModelConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
        return (y * p["w"].astype(jnp.float32) + p["b"].astype(jnp.float32)).astype(x.dtype)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + 1e-6)
    return (y * p["w"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [b, s, h, d]; positions: [s] or [b, s] global token positions."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # [d/2]
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [..., s, d/2]
    if ang.ndim == 2:  # [s, d/2] -> broadcast over batch
        ang = ang[None]
    cos = jnp.cos(ang)[:, :, None, :]  # [b, s, 1, d/2]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos_emb(seq_len: int, d_model: int, offset: int = 0) -> jnp.ndarray:
    pos = jnp.arange(offset, offset + seq_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d_model)
    out = jnp.zeros((seq_len, d_model), jnp.float32)
    out = out.at[:, 0::2].set(jnp.sin(ang)).at[:, 1::2].set(jnp.cos(ang))
    return out


# ---------------------------------------------------------------------------
# Attention projections (the mixer itself is injected — ulysses/fpdt/cp)
# ---------------------------------------------------------------------------


def init_attn(cfg: ModelConfig, key, dtype) -> Params:
    ks = jax.random.split(key, 4)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    p = {
        "wq": _dense_init(ks[0], (d, qd), dtype),
        "wk": _dense_init(ks[1], (d, kvd), dtype),
        "wv": _dense_init(ks[2], (d, kvd), dtype),
        "wo": _dense_init(ks[3], (qd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((qd,), dtype)
        p["bk"] = jnp.zeros((kvd,), dtype)
        p["bv"] = jnp.zeros((kvd,), dtype)
    return p


def qkv_proj(cfg: ModelConfig, p: Params, x: jnp.ndarray):
    """x [b,s,d] -> q [b,s,hq,dh], k,v [b,s,hkv,dh]."""
    b, s, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(b, s, cfg.num_heads, cfg.head_dim)
    k = k.reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    return q, k, v


def out_proj(cfg: ModelConfig, p: Params, o: jnp.ndarray) -> jnp.ndarray:
    b, s = o.shape[:2]
    return o.reshape(b, s, cfg.q_dim) @ p["wo"]


# ---------------------------------------------------------------------------
# MLP (dense; MoE lives in models/moe.py). Chunked per the paper §5.4.
# ---------------------------------------------------------------------------


def init_mlp(cfg: ModelConfig, key, dtype) -> Params:
    d, ff = cfg.d_model, cfg.d_ff
    if cfg.mlp_act == "swiglu":
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "wg": _dense_init(k1, (d, ff), dtype),
            "wu": _dense_init(k2, (d, ff), dtype),
            "wd": _dense_init(k3, (ff, d), dtype),
        }
    k1, k2 = jax.random.split(key, 2)
    return {"wu": _dense_init(k1, (d, ff), dtype), "wd": _dense_init(k2, (ff, d), dtype)}


def mlp_block(cfg: ModelConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.mlp_act == "swiglu":
        return (jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])) @ p["wd"]
    return jax.nn.gelu(x @ p["wu"]) @ p["wd"]


def mlp_chunked(cfg: ModelConfig, p: Params, x: jnp.ndarray, n_chunks: int) -> jnp.ndarray:
    """Paper §5.4: token-wise ops chunked along the sequence (no offload —
    O(N) compute can never hide transfer latency).  Implemented as a
    rematerialized lax.scan over sequence chunks so both forward peak memory
    and backward recompute are bounded by one chunk."""
    if n_chunks <= 1 or x.shape[1] % n_chunks != 0:
        return mlp_block(cfg, p, x)
    b, s, d = x.shape
    xs = x.reshape(b, n_chunks, s // n_chunks, d).transpose(1, 0, 2, 3)

    @jax.checkpoint
    def step(_, xc):
        return None, mlp_block(cfg, p, xc)

    _, ys = jax.lax.scan(step, None, xs)
    return ys.transpose(1, 0, 2, 3).reshape(b, s, d)
