"""RG-LRU recurrent block (recurrentgemma-9b), built on the Pallas
``linear_scan`` kernel.

h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
a_t = exp(-c * softplus(Lambda) * sigmoid(r_t)),  c = 8
with per-channel input gate i_t and recurrence gate r_t.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

import jax.lax as lax

from repro.configs import ModelConfig
from repro.kernels.linear_scan import ops as scan_ops
from repro.models.layers import _dense_init
from repro.models.mamba import causal_conv1d


def _compose(left, right):
    a1, b1 = left
    a2, b2 = right
    return a1 * a2, b2 + a2 * b1


def dist_linear_scan(a, b, n_shards: int, h0=None):
    """Sequence-parallel linear scan: local inclusive scans per shard +
    an exclusive prefix-combine over per-shard summaries (KB-scale
    collectives instead of full-activation reshards).  Exact (§Perf A2)."""
    B, S, C = a.shape
    n = n_shards
    assert S % n == 0
    ar = a.astype(jnp.float32).reshape(B, n, S // n, C)
    br = b.astype(jnp.float32).reshape(B, n, S // n, C)
    A_loc, B_loc = lax.associative_scan(_compose, (ar, br), axis=2)
    A_sum, B_sum = A_loc[:, :, -1], B_loc[:, :, -1]  # [B, n, C] summaries
    A_pref, B_pref = lax.associative_scan(_compose, (A_sum, B_sum), axis=1)
    h_in = jnp.concatenate(
        [jnp.zeros_like(B_pref[:, :1]), B_pref[:, :-1]], axis=1)  # state entering shard i
    if h0 is not None:
        # fold an initial state through every shard's entering state
        A_in = jnp.concatenate([jnp.ones_like(A_pref[:, :1]), A_pref[:, :-1]], axis=1)
        h_in = h_in + A_in * h0.astype(jnp.float32)[:, None]
    h = B_loc + A_loc * h_in[:, :, None]
    return h.reshape(B, S, C)

Params = Dict[str, Any]
C_FACTOR = 8.0


def init_rglru(cfg: ModelConfig, key, dtype) -> Params:
    d = cfg.d_model
    di = cfg.d_inner  # lru_width (expand=1 for RG-9B -> di == d)
    ks = jax.random.split(key, 6)
    # Lambda init so a^c in (0.9, 0.999) at r=1
    import numpy as np

    u = jax.random.uniform(ks[5], (di,), minval=0.9**2, maxval=0.999**2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / (2 * C_FACTOR)))  # softplus^-1
    return {
        "w_y": _dense_init(ks[0], (d, di), dtype),
        "w_gate": _dense_init(ks[1], (d, di), dtype),
        "conv_w": _dense_init(ks[2], (cfg.d_conv, di), dtype, fan_in=cfg.d_conv),
        "conv_b": jnp.zeros((di,), dtype),
        "w_a": _dense_init(ks[3], (di, di), dtype),
        "b_a": jnp.zeros((di,), jnp.float32),
        "w_i": _dense_init(ks[4], (di, di), dtype),
        "b_i": jnp.zeros((di,), jnp.float32),
        "lam": lam.astype(jnp.float32),
        "w_out": _dense_init(jax.random.fold_in(key, 7), (di, d), dtype),
    }


def _gates(p: Params, x: jnp.ndarray):
    r = jax.nn.sigmoid((x @ p["w_a"]).astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid((x @ p["w_i"]).astype(jnp.float32) + p["b_i"])
    log_a = -C_FACTOR * jax.nn.softplus(p["lam"]) * r  # [b, s, di]
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        i * x.astype(jnp.float32)
    )
    return a, gated


def rglru_mixer(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                state: Optional[dict] = None, shard=None, scan_impl: str = "pallas",
                n_shards: int = 1):
    """x [b, s, d] -> (y [b, s, d], new_state {conv, h}).

    Distributed mode (n_shards > 1): the mixer stays SEQUENCE-sharded —
    projections/gates/conv are token-parallel and the recurrence runs as a
    distributed prefix scan (dist_linear_scan).  The earlier channel-sharded
    design all-to-all'd activations in and psum'd full fp32 activations out;
    measured in §Perf A2, this path replaces GBs of collectives per layer
    with per-shard summaries.  The conv halo (3 tokens) is handled by GSPMD
    for the shifted adds.  Single-device: Pallas linear_scan kernel."""
    y = x @ p["w_y"]
    gate = jax.nn.gelu(x @ p["w_gate"])
    if shard is not None:
        y = shard(y, "seq3")
        gate = shard(gate, "seq3")
    y, conv_state = causal_conv1d(y, p["conv_w"], p["conv_b"],
                                  state["conv"] if state else None)
    a, gated = _gates(p, y)
    h0 = state["h"] if state else None
    if n_shards > 1:
        h = dist_linear_scan(a, gated, n_shards, h0)
    else:
        h = scan_ops.linear_scan(a, gated, h0, impl=scan_impl)  # [b, s, di] fp32
    out = (h.astype(x.dtype) * gate) @ p["w_out"]
    if shard is not None:
        out = shard(out, "seq")
    return out, {"conv": conv_state, "h": h[:, -1]}


def rglru_chunk_step(cfg: ModelConfig, p: Params, x: jnp.ndarray, state: dict,
                     live: jnp.ndarray):
    """Chunked prefill step with state-at-length gather (see
    ``mamba.mamba_chunk_step`` for the contract).  Pad positions are
    forced to identity transitions (a = 1, gated input = 0) so the scan's
    last state is the state after exactly ``live`` real tokens; the conv
    carry is gathered at ``live``.  Pad-position outputs are garbage."""
    from repro.models.mamba import _conv_state_at

    b, s, _ = x.shape
    k = cfg.d_conv
    y = x @ p["w_y"]
    gate = jax.nn.gelu(x @ p["w_gate"])
    xp = jnp.concatenate([state["conv"].astype(y.dtype), y], axis=1)
    y = sum(xp[:, i : i + s] * p["conv_w"][i] for i in range(k)) + p["conv_b"]
    new_conv = _conv_state_at(xp, live, k)
    a, gated = _gates(p, y)
    dead = (jnp.arange(s)[None, :] >= live[:, None])[..., None]  # [b, cp, 1]
    a = jnp.where(dead, 1.0, a)
    gated = jnp.where(dead, 0.0, gated)
    h = scan_ops.linear_scan(a, gated, state["h"], impl="xla")  # [b, s, di] fp32
    out = (h.astype(x.dtype) * gate) @ p["w_out"]
    return out, {"conv": new_conv, "h": h[:, -1]}


def rglru_decode_step(cfg: ModelConfig, p: Params, x: jnp.ndarray, state: dict):
    y = x @ p["w_y"]
    gate = jax.nn.gelu(x @ p["w_gate"])
    y, conv_state = causal_conv1d(y, p["conv_w"], p["conv_b"], state["conv"])
    a, gated = _gates(p, y)
    h = a[:, 0] * state["h"] + gated[:, 0]
    out = (h[:, None].astype(x.dtype) * gate) @ p["w_out"]
    return out, {"conv": conv_state, "h": h}
