"""Mamba-1 selective SSM mixer (falcon-mamba-7b).

TPU adaptation (DESIGN.md §3): the O(b·s·d_inner·d_state) discretized
transition tensor is never materialized in HBM — the scan runs as a blocked
lax.scan over sequence blocks, computing a/b on the fly per block and
carrying the [b, d_inner, d_state] state (this is also exactly the FPDT
chunk boundary).  Within a block the inclusive scan is a vectorized
associative scan; the block compute can optionally route through the Pallas
``linear_scan`` kernel when the per-shard channel count fits VMEM.

Under sequence parallelism the mixer uses the "Ulysses for SSMs" layout
swap: outside [b, s/P, d] -> inside [b, s, d_inner/P] (all-to-all induced by
sharding constraints), because the scan/conv are sequential in s but
elementwise in channels.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models.layers import _dense_init

Params = Dict[str, Any]


def init_mamba(cfg: ModelConfig, key, dtype) -> Params:
    d, di, ds, dtr = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank_actual
    ks = jax.random.split(key, 6)
    dt_init = jnp.exp(
        jax.random.uniform(ks[5], (di,)) * (math.log(0.1) - math.log(0.001)) + math.log(0.001)
    )
    return {
        "w_in": _dense_init(ks[0], (d, 2 * di), dtype),
        "conv_w": _dense_init(ks[1], (cfg.d_conv, di), dtype, fan_in=cfg.d_conv),
        "conv_b": jnp.zeros((di,), dtype),
        "w_x": _dense_init(ks[2], (di, dtr + 2 * ds), dtype),
        "w_dt": _dense_init(ks[3], (dtr, di), dtype),
        # softplus^-1(dt_init)
        "b_dt": jnp.log(jnp.expm1(dt_init)).astype(jnp.float32),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, 1))),
        "D": jnp.ones((di,), jnp.float32),
        "w_out": _dense_init(ks[4], (di, d), dtype),
    }


def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                  state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv. x [b, s, c]; w [k, c]. Returns (y, new_state).

    ``state`` is the last k-1 inputs of the previous chunk ([b, k-1, c]) —
    the FPDT chunk handoff for the conv."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1) :] if k > 1 else state
    return y + b, new_state


def selective_scan(
    xc: jnp.ndarray,  # [b, s, di] conv+silu output
    dt: jnp.ndarray,  # [b, s, di] (post-softplus)
    A_log: jnp.ndarray,  # [di, ds]
    B: jnp.ndarray,  # [b, s, ds]
    C: jnp.ndarray,  # [b, s, ds]
    h0: Optional[jnp.ndarray] = None,  # [b, di, ds]
    *,
    block_s: int = 256,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y [b, s, di] fp32, h_last [b, di, ds] fp32)."""
    b, s, di = xc.shape
    ds = A_log.shape[1]
    A = -jnp.exp(A_log.astype(jnp.float32))  # [di, ds]
    if h0 is None:
        h0 = jnp.zeros((b, di, ds), jnp.float32)
    block_s = min(block_s, s)
    assert s % block_s == 0
    nb = s // block_s

    def blockify(t):
        return t.reshape(b, nb, block_s, *t.shape[2:]).transpose(1, 0, 2, *range(3, t.ndim + 1))

    xb, dtb, Bb, Cb = map(blockify, (xc.astype(jnp.float32), dt.astype(jnp.float32),
                                     B.astype(jnp.float32), C.astype(jnp.float32)))

    def compose(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, b2 + a2 * b1

    @jax.checkpoint
    def step(h, inp):
        xj, dtj, Bj, Cj = inp  # [b, bs, di], ..., [b, bs, ds]
        a = jnp.exp(dtj[..., None] * A)  # [b, bs, di, ds]
        bb = (dtj * xj)[..., None] * Bj[:, :, None, :]
        Acum, Bcum = jax.lax.associative_scan(compose, (a, bb), axis=1)
        hs = Bcum + Acum * h[:, None]  # [b, bs, di, ds]
        y = jnp.einsum("bsdn,bsn->bsd", hs, Cj)
        return hs[:, -1], y

    h_last, yb = jax.lax.scan(step, h0.astype(jnp.float32), (xb, dtb, Bb, Cb))
    y = yb.transpose(1, 0, 2, 3).reshape(b, s, di)
    return y, h_last


def selective_scan_dist(
    xc, dt, A_log, B, C, h0=None, *, block_s: int = 256, n_shards: int = 1,
):
    """Two-pass sequence-parallel selective scan (§Perf A3, beyond-paper).

    Pass 1: each sequence shard scans its blocks locally with zero initial
    state (fully parallel across the model axis).  Shard summaries
    (A-products from sum(dt), final local states) are prefix-combined — a
    [b, n, di, ds]-sized collective instead of full-activation reshards.
    Pass 2 adds the correction C_t . (exp(A*cumsum(dt)) * h_in) blockwise.
    Exact; ~1.5x the scan's elementwise FLOPs (scan cost is a small share of
    the mamba block)."""
    b, s, di = xc.shape
    ds = A_log.shape[1]
    A = -jnp.exp(A_log.astype(jnp.float32))  # [di, ds]
    m = n_shards
    assert s % m == 0
    sl = s // m
    # bound the [b, m, bs, di, ds] fp32 block state (peak-memory governor:
    # block_s=256 at d_inner=8192 peaked 48 GiB/device on falcon train_4k)
    block_s = min(block_s, max(16, sl // 8))
    block_s = min(block_s, sl)
    while sl % block_s:
        block_s -= 1
    nb = sl // block_s

    def rs(t):
        return (t.astype(jnp.float32)
                .reshape(b, m, nb, block_s, *t.shape[2:])
                .transpose(2, 0, 1, 3, *range(4, t.ndim + 2)))

    xb, dtb, Bb, Cb = map(rs, (xc, dt, B, C))  # [nb, b, m, bs, ...]

    def compose(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, b2 + a2 * b1

    def pass1(carry, inp):
        h, cum = carry  # h [b, m, di, ds]; cum(dt) [b, m, di]
        xj, dtj, Bj, Cj = inp  # [b, m, bs, di] / [b, m, bs, ds]
        a = jnp.exp(dtj[..., None] * A)  # [b, m, bs, di, ds]
        bb = (dtj * xj)[..., None] * Bj[:, :, :, None, :]
        Ac, Bc = jax.lax.associative_scan(compose, (a, bb), axis=2)
        hs = Bc + Ac * h[:, :, None]
        y = jnp.einsum("bmtdn,bmtn->bmtd", hs, Cj)
        return (hs[:, :, -1], cum + dtj.sum(2)), y

    z_h = jnp.zeros((b, m, di, ds), jnp.float32)
    z_c = jnp.zeros((b, m, di), jnp.float32)
    (h_loc, sum_dt), y_loc = jax.lax.scan(
        jax.checkpoint(pass1), (z_h, z_c), (xb, dtb, Bb, Cb))

    # shard-level prefix: h entering shard k
    A_shard = jnp.exp(sum_dt[..., None] * A)  # [b, m, di, ds]
    A_pref, H_pref = jax.lax.associative_scan(compose, (A_shard, h_loc), axis=1)
    h_in = jnp.concatenate([jnp.zeros_like(H_pref[:, :1]), H_pref[:, :-1]], axis=1)
    if h0 is not None:
        A_in = jnp.concatenate([jnp.ones_like(A_pref[:, :1]), A_pref[:, :-1]], axis=1)
        h_in = h_in + A_in * h0.astype(jnp.float32)[:, None]
    # final state: last shard's local state advanced over its entering state
    h_last = A_shard[:, -1] * h_in[:, -1] + h_loc[:, -1]

    def pass2(cum, inp):
        dtj, Cj, yj = inp
        cumj = cum[:, :, None] + jnp.cumsum(dtj, axis=2)  # [b, m, bs, di]
        factor = jnp.exp(cumj[..., None] * A)  # [b, m, bs, di, ds]
        corr = jnp.einsum("bmtdn,bmtn->bmtd", factor * h_in[:, :, None], Cj)
        return cum + dtj.sum(2), yj + corr

    _, yb = jax.lax.scan(jax.checkpoint(pass2), z_c, (dtb, Cb, y_loc))
    y = yb.transpose(1, 2, 0, 3, 4).reshape(b, s, di)
    return y, h_last


def mamba_mixer(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                state: Optional[dict] = None, shard=None, n_shards: int = 1):
    """x [b, s, d] -> (y [b, s, d], new_state).

    state = {"conv": [b, k-1, di], "ssm": [b, di, ds]} (None = zeros).
    Distributed (n_shards > 1): stays sequence-sharded end to end and uses
    the two-pass parallel scan — no channel all-to-all, no activation psum
    (the channel-sharded v1 cost 25.8 s/step of collectives on
    falcon-mamba-7b train_4k, §Perf A3)."""
    dtr, ds = cfg.dt_rank_actual, cfg.ssm_state
    xz = x @ p["w_in"]
    if shard is not None:
        xz = shard(xz, "seq3")
    xc, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = causal_conv1d(xc, p["conv_w"], p["conv_b"],
                                   state["conv"] if state else None)
    xc = jax.nn.silu(xc)
    dbc = xc @ p["w_x"]
    dt = jax.nn.softplus(dbc[..., :dtr] @ p["w_dt"] + p["b_dt"])
    B = dbc[..., dtr : dtr + ds]
    C = dbc[..., dtr + ds :]
    if n_shards > 1:
        y, h_last = selective_scan_dist(xc, dt, p["A_log"], B, C,
                                        state["ssm"] if state else None,
                                        n_shards=n_shards)
    else:
        y, h_last = selective_scan(xc, dt, p["A_log"], B, C,
                                   state["ssm"] if state else None)
    y = y + p["D"] * xc.astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = y @ p["w_out"]
    if shard is not None:
        out = shard(out, "seq")
    return out, {"conv": conv_state, "ssm": h_last}


def _conv_state_at(xp: jnp.ndarray, live: jnp.ndarray, k: int) -> jnp.ndarray:
    """State-at-length gather for the causal-conv carry.

    ``xp`` [b, k-1+s, c] is the conv input with the previous state
    prepended (index i holds chunk position i-(k-1)); after consuming
    ``live`` tokens of the chunk the carry is the k-1 inputs ending at
    position ``live - 1``, i.e. ``xp[:, live : live+k-1]`` — per-row
    traced, so a partial final chunk hands off the state at the TRUE
    length instead of integrating pad tokens (``live = 0`` reproduces the
    incoming state exactly)."""
    b = xp.shape[0]
    idx = live[:, None] + jnp.arange(k - 1)[None, :]  # [b, k-1]
    return xp[jnp.arange(b)[:, None], idx]


def mamba_chunk_step(cfg: ModelConfig, p: Params, x: jnp.ndarray, state: dict,
                     live: jnp.ndarray):
    """Chunked prefill step with state-at-length gather.

    x [b, cp, d]; state {"conv": [b, k-1, di], "ssm": [b, di, ds]};
    live [b] int32 — tokens of the chunk that are real (the rest is
    right-padding).  Returns (y [b, cp, d], new_state) where ``new_state``
    is the recurrent state after exactly ``live`` tokens: pad positions
    are forced to identity transitions (``dt = 0`` -> a = exp(0) = 1,
    b-term = 0) so the scan's final state IS the state at the true
    length, and the conv carry is gathered at ``live``.  Outputs at pad
    positions are garbage and must be masked by the caller."""
    dtr, ds = cfg.dt_rank_actual, cfg.ssm_state
    b, s, _ = x.shape
    k = cfg.d_conv
    xz = x @ p["w_in"]
    xin, z = jnp.split(xz, 2, axis=-1)
    xp = jnp.concatenate([state["conv"].astype(xin.dtype), xin], axis=1)
    xc = sum(xp[:, i : i + s] * p["conv_w"][i] for i in range(k)) + p["conv_b"]
    new_conv = _conv_state_at(xp, live, k)
    xc = jax.nn.silu(xc)
    dbc = xc @ p["w_x"]
    dt = jax.nn.softplus(dbc[..., :dtr] @ p["w_dt"] + p["b_dt"])
    dead = jnp.arange(s)[None, :] >= live[:, None]  # [b, cp]
    dt = jnp.where(dead[..., None], 0.0, dt)  # identity transition at pads
    B = dbc[..., dtr : dtr + ds]
    C = dbc[..., dtr + ds :]
    y, h_last = selective_scan(xc, dt, p["A_log"], B, C, state["ssm"])
    y = y + p["D"] * xc.astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    return y @ p["w_out"], {"conv": new_conv, "ssm": h_last}


def mamba_decode_step(cfg: ModelConfig, p: Params, x: jnp.ndarray, state: dict):
    """Single-token decode. x [b, 1, d]; state carries conv + ssm."""
    dtr, ds = cfg.dt_rank_actual, cfg.ssm_state
    xz = x @ p["w_in"]
    xc, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = causal_conv1d(xc, p["conv_w"], p["conv_b"], state["conv"])
    xc = jax.nn.silu(xc)
    dbc = xc @ p["w_x"]
    dt = jax.nn.softplus(dbc[..., :dtr] @ p["w_dt"] + p["b_dt"])
    B, C = dbc[..., dtr : dtr + ds], dbc[..., dtr + ds :]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dt[:, 0, :, None].astype(jnp.float32) * A)  # [b, di, ds]
    bb = (dt * xc)[:, 0, :, None].astype(jnp.float32) * B[:, 0, None, :].astype(jnp.float32)
    h = a * state["ssm"] + bb
    y = jnp.einsum("bdn,bn->bd", h, C[:, 0].astype(jnp.float32)) + p["D"] * xc[:, 0].astype(jnp.float32)
    y = y[:, None].astype(x.dtype) * jax.nn.silu(z)
    return y @ p["w_out"], {"conv": conv_state, "ssm": h}
