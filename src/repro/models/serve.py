"""Serving path: cache init, prefill, and single-token decode.

Cache layouts (stacked over layer cycles C so decode scans one cycle body):
  attn        {"k","v": [C, b, S, hkv, dh], "kpos": [C, b, S] filled positions}
  local_attn  same with S = window (ring buffer; entry positions in "kpos")
  ssm         {"conv": [C, b, k-1, di], "ssm": [C, b, di, ds]}
  rglru       {"conv": [C, b, k-1, di], "h": [C, b, di]}

Decode positions are per-sequence: every entry point here accepts ``pos``
as a scalar or an int32 ``[b]`` vector, so a batch may hold sequences at
different depths (the continuous-batching engine in
``runtime/decode_loop.py`` relies on this).  ``kpos`` entries of ``-1``
mark unfilled/invalid cache slots; attention masks on ``kpos`` rather than
on slot index, which is what makes position-masked (padded) prefill exact.

Sharding: Ulysses archs shard cache *heads* over the model axis; CP archs
shard cache *sequence*; SSM/RG states shard channels.  With
``n_host_chunks > 0`` the attention KV cache lives in host memory (when
the backend's placement policy supports it) and decode streams it
chunk-by-chunk through the online-softmax merge via
``runtime.placement.fori_double_buffered`` — the same scan-carry Fig. 6
pipeline the training path uses, so decode program size is flat in the
chunk count and dead (unfilled) chunks skip both the host fetch and the
merge.  This is the FPDT pipeline applied to inference (the EXTRA
long_500k cell); see ``docs/serving.md``.

Paged layout (``init_paged_cache`` + ``table=...`` on the step entry
points): full-attention blocks swap the per-slot ``[b, S]`` rows for one
slot-SHARED page pool ``pk``/``pv`` ``[n_pages+1, page_size, hkv, dh]``
(+ ``pkpos [n_pages+1, page_size]`` filled positions) indexed through a
per-slot page table ``[b, max_pages] int32`` owned by
``runtime/paged.py``: entry ``-1`` = unmapped (masked out of attention),
and the extra physical page (index ``n_pages``, the *trash* page) is
where a FREE slot's table row points so its dummy decode writes land
harmlessly.  Two slots may map the same physical page (radix prefix
reuse) — reads are free to share; the manager guarantees written pages
are exclusively owned (copy-on-write).  Recurrent states and local_attn
rings stay per-slot dense — they are O(1)/O(window) per slot, paging
buys nothing.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ModelConfig
from repro.core.online_softmax import NEG_INF, SoftmaxState, finalize, merge, zero_state
from repro.core.parallel import ParallelContext
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import rglru as R
from repro.models.transformer import (
    attn_kind,
    head_matrix,
    layout_of,
    pattern_of,
)
from repro.runtime.placement import fori_double_buffered

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# cache init
# ---------------------------------------------------------------------------


def _attn_cache(cfg, b, s, dtype):
    return {
        "k": jnp.zeros((b, s, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((b, s, cfg.num_kv_heads, cfg.head_dim), dtype),
        "kpos": jnp.full((b, s), -1, jnp.int32),
    }


def _block_cache(cfg: ModelConfig, kind: str, b: int, max_len: int, dtype):
    if kind == "attn":
        return _attn_cache(cfg, b, max_len, dtype)
    if kind == "local_attn":
        return _attn_cache(cfg, b, min(cfg.window, max_len), dtype)
    if kind == "ssm":
        return {
            "conv": jnp.zeros((b, cfg.d_conv - 1, cfg.d_inner), dtype),
            "ssm": jnp.zeros((b, cfg.d_inner, cfg.ssm_state), jnp.float32),
        }
    if kind == "rglru":
        return {
            "conv": jnp.zeros((b, cfg.d_conv - 1, cfg.d_inner), dtype),
            "h": jnp.zeros((b, cfg.d_inner), jnp.float32),
        }
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, b: int, max_len: int) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    pat, n_cycles, tail = layout_of(cfg)

    def stack(make):
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (n_cycles, *x.shape)), make())

    cache = {
        f"pos{i}": stack(functools.partial(_block_cache, cfg, kind, b, max_len, dtype))
        for i, kind in enumerate(pat)
    }
    if tail:
        cache["tail"] = [_block_cache(cfg, kind, b, max_len, dtype) for kind in tail]
    return cache


def _paged_attn_cache(cfg: ModelConfig, n_pages: int, page_size: int, dtype):
    """Slot-shared page pool for one attention layer.  ``n_pages + 1``
    physical pages: the last one is the TRASH page — FREE slots' table rows
    point every logical page at it, so their dummy decode writes land
    somewhere harmless; it is never mapped by a live slot.  ``pkpos = -1``
    marks unfilled page entries, exactly like the dense ``kpos``."""
    return {
        "pk": jnp.zeros((n_pages + 1, page_size, cfg.num_kv_heads, cfg.head_dim), dtype),
        "pv": jnp.zeros((n_pages + 1, page_size, cfg.num_kv_heads, cfg.head_dim), dtype),
        "pkpos": jnp.full((n_pages + 1, page_size), -1, jnp.int32),
    }


def init_paged_cache(cfg: ModelConfig, b: int, n_pages: int, page_size: int) -> Params:
    """Paged twin of ``init_cache``: full-attention blocks share ONE page
    pool across all ``b`` slots (memory scales with pages actually used,
    not ``slots x worst-case length``; pages are mapped per slot through
    the ``runtime/paged.py`` page table, and a shared prompt prefix maps
    the same physical pages copy-free).  local_attn rings and recurrent
    ssm/rglru states keep their per-slot dense layouts."""
    dtype = jnp.dtype(cfg.param_dtype)
    pat, n_cycles, tail = layout_of(cfg)
    cap = n_pages * page_size  # pool token capacity bounds the ring window

    def make(kind):
        if kind == "attn":
            return _paged_attn_cache(cfg, n_pages, page_size, dtype)
        return _block_cache(cfg, kind, b, cap, dtype)

    def stack(kind):
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (n_cycles, *x.shape)),
                            make(kind))

    cache = {f"pos{i}": stack(kind) for i, kind in enumerate(pat)}
    if tail:
        cache["tail"] = [make(kind) for kind in tail]
    return cache


def dense_kv_spec(par: ParallelContext, shape) -> tuple:
    """Spec components for one dense K/V leaf ``[b, s, h, dh]`` — THE
    shape-aware rule: batch over dp and heads over model when divisible
    (Ulysses-style), else fall back to sequence sharding (CP-style); a dim
    that divides nothing stays replicated."""
    b, s, h, _ = shape
    dp = par.dp_axes if b % par.dp == 0 and b >= par.dp else None
    if h % par.sp == 0 and h >= par.sp:
        return (dp, None, par.sp_axis, None)
    sp = par.sp_axis if s % par.sp == 0 and s >= par.sp else None
    return (dp, sp, None, None)


def paged_pool_spec(par: ParallelContext, page_size: int, hkv: int) -> tuple:
    """Spec components for one pool K/V leaf ``[n_pages+1, page_size, hkv,
    dh]`` — the dense rule transposed to the paged layout: kv heads over
    the model axis when divisible, else the in-page sequence dim.  The
    physical-page dim is ``n_pages + 1`` (trash page) and the page table
    maps pages to slots arbitrarily, so it is NEVER sharded — every device
    holds its head (or in-page) slice of every page, and the pool stays
    replicated over the data axis (it has no batch dim; slots split over
    data through the per-slot dense leaves instead)."""
    if hkv % par.sp == 0 and hkv >= par.sp:
        return (None, None, par.sp_axis, None)
    if page_size % par.sp == 0 and page_size >= par.sp:
        return (None, par.sp_axis, None, None)
    return (None, None, None, None)


def cache_shardings(cfg: ModelConfig, par: ParallelContext, cache):
    """NamedShardings for a cache pytree (heads/seq/channels per DESIGN.md).

    Shape-aware: a dim is only sharded when divisible by its axis (kv heads
    smaller than the model axis fall back to sequence sharding; batch=1
    long-context decode leaves batch unsharded).  Covers BOTH layouts:
    dense per-slot rows (``init_cache``) and the slot-shared paged pool
    (``init_paged_cache`` — ``pk``/``pv`` follow ``paged_pool_spec``,
    ``pkpos`` co-shards its in-page dim with them)."""

    def dp_if(n):
        return par.dp_axes if n % par.dp == 0 and n >= par.dp else None

    def sp_if(n):
        return par.sp_axis if n % par.sp == 0 and n >= par.sp else None

    def spec_for(path, leaf):
        names = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
        stacked = names[0] != "tail"
        lead = (None,) if stacked else ()
        off = 1 if stacked else 0
        shape = leaf.shape[off:]
        if names[-1] in ("pk", "pv"):  # [*, n_pages+1, ps, hkv, dh]
            return par.ns(*lead, *paged_pool_spec(par, shape[1], shape[2]))
        if "pkpos" in names:  # [*, n_pages+1, ps]
            sub = paged_pool_spec(par, shape[1], cfg.num_kv_heads)
            return par.ns(*lead, None, sub[1])
        if "kpos" in names:  # [*, b, s]
            return par.ns(*lead, dp_if(shape[0]), None)
        if names[-1] in ("k", "v"):  # [*, b, s, h, dh]
            return par.ns(*lead, *dense_kv_spec(par, shape))
        if "conv" in names:  # [*, b, k-1, di]
            return par.ns(*lead, dp_if(shape[0]), None, sp_if(shape[2]))
        if "ssm" in names:  # [*, b, di, ds]
            return par.ns(*lead, dp_if(shape[0]), sp_if(shape[1]), None)
        if names[-1] == "h":  # [*, b, di]
            return par.ns(*lead, dp_if(shape[0]), sp_if(shape[1]))
        return par.ns()

    return jax.tree_util.tree_map_with_path(spec_for, cache)


def pool_leaf_key(path) -> str:
    """Stable string key for one pool-cache leaf path — ``'pos0/pk'``,
    ``'tail/0/pkpos'``...  Used wherever a page payload crosses the
    pytree boundary into plain host dicts (the spill tier, ``page_rows``,
    ``runtime/paged.py::promote_page``): dict keys sort, so one key scheme
    means ONE pytree structure and therefore one compiled promote
    program.  Handles every path-entry flavour (``DictKey.key``,
    ``SequenceKey.idx``, attr ``name``)."""
    parts = []
    for p in path:
        for attr in ("key", "idx", "name"):
            if hasattr(p, attr):
                parts.append(str(getattr(p, attr)))
                break
    return "/".join(parts)


def page_rows(cache, pid: int) -> Dict[str, Any]:
    """One physical page's payload as host numpy rows keyed by
    ``pool_leaf_key`` — the demotion/persistence read path.  Only pool
    leaves (``pk``/``pv``/``pkpos``) appear; per-slot dense leaves carry
    no page state.  Stacked leaves keep their leading cycle dim, so a row
    is ``[C, ps, hkv, dh]`` / ``[C, ps]`` (or without ``C`` for tail)."""
    leaves = jax.tree_util.tree_flatten_with_path(cache)[0]
    rows = {}
    for path, leaf in leaves:
        names = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
        if names[-1] not in ("pk", "pv") and "pkpos" not in names:
            continue
        row = leaf[:, pid] if names[0] != "tail" else leaf[pid]
        rows[pool_leaf_key(path)] = np.asarray(jax.device_get(row))
    return rows


# ---------------------------------------------------------------------------
# decode attention (single new token against the cache)
# ---------------------------------------------------------------------------


def _decode_attention(cfg: ModelConfig, par: Optional[ParallelContext], p: Params,
                      x: jnp.ndarray, cache: Params, pos, *, window: int = 0,
                      n_host_chunks: int = 0):
    """x [b, 1, d]; pos scalar or [b]; returns (attn_out [b, 1, qd], new cache)."""
    b = x.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))  # per-sequence
    q, k, v = L.qkv_proj(cfg, p, x)  # [b, 1, h, dh]
    q = L.apply_rope(q, pos[:, None], cfg.rope_theta)
    k = L.apply_rope(k, pos[:, None], cfg.rope_theta)
    S = cache["k"].shape[1]
    slot = jnp.where(window > 0, pos % S, jnp.minimum(pos, S - 1))  # [b]
    bi = jnp.arange(b)
    ck = cache["k"].at[bi, slot].set(k[:, 0].astype(cache["k"].dtype))
    cv = cache["v"].at[bi, slot].set(v[:, 0].astype(cache["v"].dtype))
    kpos = cache["kpos"].at[bi, slot].set(pos)
    if par is not None and par.mesh is not None:
        dspec = dense_kv_spec(par, ck.shape)
        ck = par.constrain(ck, *dspec)
        cv = par.constrain(cv, *dspec)

    g = cfg.num_heads // cfg.num_kv_heads
    qf = q[:, 0].astype(jnp.float32)  # [b, hq, dh]
    scale = cfg.head_dim ** -0.5

    def attend(kc, vc, kp):
        """Partial online-softmax state [b, h, 1, d] of q against this KV slab."""
        ke = jnp.repeat(kc.astype(jnp.float32), g, axis=2) if g > 1 else kc.astype(jnp.float32)
        ve = jnp.repeat(vc.astype(jnp.float32), g, axis=2) if g > 1 else vc.astype(jnp.float32)
        s = jnp.einsum("bhd,bshd->bhs", qf, ke) * scale
        ok = (kp >= 0) & (kp <= pos[:, None])
        if window:
            ok = ok & (kp > (pos - window)[:, None])
        s = jnp.where(ok[:, None, :], s, NEG_INF)
        m = jnp.max(s, axis=-1)
        pr = jnp.where(s <= NEG_INF / 2, 0.0, jnp.exp(s - m[..., None]))
        l = pr.sum(-1)
        acc = jnp.einsum("bhs,bshd->bhd", pr, ve)
        return SoftmaxState(acc[:, :, None, :], m[:, :, None], l[:, :, None])

    if n_host_chunks and S % n_host_chunks == 0:
        # FPDT-for-inference: stream host-resident KV chunk by chunk through
        # the scan-carry Fig. 6 pipeline — the chunk body is traced ONCE, so
        # decode program size is flat in n_host_chunks (the generator-based
        # double_buffered this replaced emitted one merge per chunk).
        cs = S // n_host_chunks
        # slab placement: seq over ALL axes (host<->device moves must not be
        # partially replicated), else unsharded
        slab_spec = None
        if par is not None and par.mesh is not None:
            all_axes = tuple(par.mesh.axis_names)
            if cs % par.mesh.size == 0:
                slab_spec = (None, all_axes, None, None)

        def fetch(c):
            kc = jax.lax.dynamic_slice_in_dim(ck, c * cs, cs, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(cv, c * cs, cs, axis=1)
            kp = jax.lax.dynamic_slice_in_dim(kpos, c * cs, cs, axis=1)
            if par is not None:
                kc = par.to_device(kc, *(slab_spec or ()))
                vc = par.to_device(vc, *(slab_spec or ()))
            return kc, vc, kp

        # Liveness: full-attn slots fill [0, pos] in order (this path is
        # never taken for the windowed ring buffer), so a chunk whose first
        # slot lies beyond every sequence's position holds no valid entries
        # — skipping it skips the host fetch AND the merge, and is exact
        # because a fully-masked attend() yields merge's identity element.
        hi_pos = jnp.max(pos)
        state = fori_double_buffered(
            0, n_host_chunks, fetch,
            lambda c, buf, st: merge(st, attend(*buf)),
            zero_state((b, cfg.num_heads, 1, cfg.head_dim)),
            live=lambda c: (c * cs) <= hi_pos,
        )
        o = finalize(state)[:, :, 0]  # [b, h, d]
    else:
        o = finalize(attend(ck, cv, kpos))[:, :, 0]
    o = o.reshape(b, 1, cfg.q_dim).astype(x.dtype)
    out = o @ p["wo"]
    # NOTE: host residency of the updated cache comes from serve_step's
    # re-offload put through the placement policy — nothing explicit here.
    new_cache = {"k": ck, "v": cv, "kpos": kpos}
    return out, new_cache


def _paged_write_ids(table: jnp.ndarray, pos: jnp.ndarray, page_size: int,
                     n_phys: int):
    """(physical page, in-page offset) for writing at ``pos`` through the
    page table.  Negative (unmapped) entries are redirected out of bounds
    so a ``mode="drop"`` scatter skips them — live positions are always
    mapped (the manager allocates a slot's full reserve at admit)."""
    max_pages = table.shape[1]
    j = jnp.minimum(pos // page_size, max_pages - 1)
    pid = jnp.take_along_axis(table, j.reshape(table.shape[0], -1), axis=1)
    pid = pid.reshape(pos.shape)
    pid = jnp.where(pid < 0, n_phys, pid)  # never wrap: OOB -> dropped
    return pid, pos % page_size


def _paged_gather(ck, cv, kpos, table, j):
    """Fetch logical page ``j`` of every slot: ([b, ps, hkv, dh]) k/v, the
    page's filled positions, and the page-mapped mask (``-1`` table entries
    clamp to page 0 for the gather and are masked out here)."""
    pid = table[:, j]
    safe = jnp.clip(pid, 0, None)
    kc = jnp.take(ck, safe, axis=0)
    vc = jnp.take(cv, safe, axis=0)
    kp = jnp.take(kpos, safe, axis=0)
    okp = jnp.broadcast_to((pid >= 0)[:, None], kp.shape)
    return kc, vc, kp, okp


def _decode_attention_paged(cfg: ModelConfig, par: Optional[ParallelContext],
                            p: Params, x: jnp.ndarray, cache: Params, pos,
                            table: jnp.ndarray, *, n_host_chunks: int = 0):
    """Paged twin of ``_decode_attention``: K/V are gathered through the
    per-slot page table instead of sliced from a dense ``[b, S]`` row.

    x [b, 1, d]; pos scalar or [b]; table [b, max_pages] int32 (physical
    page of each logical page; -1 = unmapped -> masked; FREE rows point at
    the trash page).  With ``n_host_chunks > 0`` the pool is host-resident
    and pages stream device-ward one logical page at a time through
    ``fori_double_buffered`` — the same scan-carry Fig. 6 pipeline as the
    dense host-chunked path, so program size is flat in BOTH ``n_pages``
    and ``max_pages``; with 0 the whole mapped range is gathered at once
    (on-device fast path, bit-comparable to dense attention).
    Returns (attn_out [b, 1, qd], new pool leaves)."""
    b = x.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    q, k, v = L.qkv_proj(cfg, p, x)  # [b, 1, h, dh]
    q = L.apply_rope(q, pos[:, None], cfg.rope_theta)
    k = L.apply_rope(k, pos[:, None], cfg.rope_theta)
    n_phys, ps = cache["pkpos"].shape
    max_pages = table.shape[1]
    pid_w, off = _paged_write_ids(table, pos, ps, n_phys)  # [b], [b]
    ck = cache["pk"].at[pid_w, off].set(k[:, 0].astype(cache["pk"].dtype), mode="drop")
    cv = cache["pv"].at[pid_w, off].set(v[:, 0].astype(cache["pv"].dtype), mode="drop")
    kpos = cache["pkpos"].at[pid_w, off].set(pos, mode="drop")
    if par is not None and par.mesh is not None:
        pspec = paged_pool_spec(par, ps, ck.shape[2])
        ck = par.constrain(ck, *pspec)
        cv = par.constrain(cv, *pspec)
        kpos = par.constrain(kpos, None, pspec[1])

    g = cfg.num_heads // cfg.num_kv_heads
    qf = q[:, 0].astype(jnp.float32)  # [b, hq, dh]
    scale = cfg.head_dim ** -0.5

    def attend(kc, vc, kp, okp):
        """Partial state of q against a gathered page run; ``okp`` masks
        entries whose logical page is unmapped in this slot's table."""
        ke = jnp.repeat(kc.astype(jnp.float32), g, axis=2) if g > 1 else kc.astype(jnp.float32)
        ve = jnp.repeat(vc.astype(jnp.float32), g, axis=2) if g > 1 else vc.astype(jnp.float32)
        s = jnp.einsum("bhd,bshd->bhs", qf, ke) * scale
        ok = okp & (kp >= 0) & (kp <= pos[:, None])
        s = jnp.where(ok[:, None, :], s, NEG_INF)
        m = jnp.max(s, axis=-1)
        pr = jnp.where(s <= NEG_INF / 2, 0.0, jnp.exp(s - m[..., None]))
        l = pr.sum(-1)
        acc = jnp.einsum("bhs,bshd->bhd", pr, ve)
        return SoftmaxState(acc[:, :, None, :], m[:, :, None], l[:, :, None])

    if n_host_chunks:
        # two-tier pool: cold pages live host-side; stream one logical page
        # per iteration, fetch j+1 issued before page j's merge (Fig. 6)
        slab_spec = None
        if par is not None and par.mesh is not None:
            all_axes = tuple(par.mesh.axis_names)
            if ps % par.mesh.size == 0:  # host custom-calls need FULL sharding
                slab_spec = (None, all_axes, None, None)

        def fetch(j):
            kc, vc, kp, okp = _paged_gather(ck, cv, kpos, table, j)
            if par is not None:
                kc = par.to_device(kc, *(slab_spec or ()))
                vc = par.to_device(vc, *(slab_spec or ()))
            return kc, vc, kp, okp

        hi_pos = jnp.max(pos)
        state = fori_double_buffered(
            0, max_pages, fetch,
            lambda j, buf, st: merge(st, attend(*buf)),
            zero_state((b, cfg.num_heads, 1, cfg.head_dim)),
            live=lambda j: (j * ps) <= hi_pos,
        )
        o = finalize(state)[:, :, 0]  # [b, h, d]
    else:
        safe = jnp.clip(table, 0, None)  # [b, max_pages]
        kall = jnp.take(ck, safe, axis=0).reshape(b, max_pages * ps, *ck.shape[2:])
        vall = jnp.take(cv, safe, axis=0).reshape(b, max_pages * ps, *cv.shape[2:])
        kpall = jnp.take(kpos, safe, axis=0).reshape(b, max_pages * ps)
        okall = jnp.repeat(table >= 0, ps, axis=1)
        o = finalize(attend(kall, vall, kpall, okall))[:, :, 0]
    o = o.reshape(b, 1, cfg.q_dim).astype(x.dtype)
    out = o @ p["wo"]
    return out, {"pk": ck, "pv": cv, "pkpos": kpos}


def _decode_block(cfg, par, kind, p, h, cache, pos, n_host_chunks=0, table=None):
    if kind in ("attn", "local_attn"):
        window = cfg.window if kind == "local_attn" else 0
        hn = L.apply_norm(cfg, p["norm1"], h)
        if table is not None and "pk" in cache:  # paged pool (attn only)
            o, cache = _decode_attention_paged(cfg, par, p["attn"], hn, cache,
                                               pos, table,
                                               n_host_chunks=n_host_chunks)
        else:
            o, cache = _decode_attention(cfg, par, p["attn"], hn, cache, pos,
                                         window=window,
                                         n_host_chunks=0 if kind == "local_attn" else n_host_chunks)
        h = h + o
        hn2 = L.apply_norm(cfg, p["norm2"], h)
        if cfg.num_experts:
            from repro.models import moe as MOE

            y, _ = MOE.moe_ffn(cfg, p["moe"], hn2)
        else:
            y = L.mlp_block(cfg, p["mlp"], hn2)
        return h + y, cache
    if kind == "ssm":
        hn = L.apply_norm(cfg, p["norm"], h)
        y, st = M.mamba_decode_step(cfg, p["mixer"], hn, cache)
        return h + y, st
    if kind == "rglru":
        hn = L.apply_norm(cfg, p["norm1"], h)
        y, st = R.rglru_decode_step(cfg, p["mixer"], hn, cache)
        h = h + y
        hn2 = L.apply_norm(cfg, p["norm2"], h)
        return h + L.mlp_block(cfg, p["mlp"], hn2), st
    raise ValueError(kind)


def decode_step(cfg: ModelConfig, par: Optional[ParallelContext], params: Params,
                cache: Params, inp: Dict[str, jnp.ndarray], pos,
                n_host_chunks: int = 0, table: Optional[jnp.ndarray] = None):
    """One decode step: advance every sequence in the batch by one token.

    Contract:
      inp    — {"tokens": [b, 1] int32} or {"frame_embeds": [b, 1, d]}.
      pos    — scalar or int32 [b]: the position each sequence's incoming
               token occupies.  The token is written into its cache slot
               (``kpos[slot] = pos``) and attends to entries with
               ``0 <= kpos <= pos``, so batch rows may sit at different
               depths.
      cache  — pytree from ``init_cache``/``prefill_step``; the returned
               cache is the same pytree with exactly the ``pos`` slots of
               every layer updated (shape- and dtype-stable, so it can ride
               a ``lax.scan`` carry — see ``runtime/decode_loop.py``).
      n_host_chunks — stream each attention layer's KV in this many chunks
               through ``fori_double_buffered`` (0 = on-device attention).
      table  — optional [b, max_pages] int32 page table: attention blocks
               read/write the slot-shared paged pool through it
               (``init_paged_cache`` layout; see ``runtime/paged.py``).

    Returns (logits [b, vocab] fp32, new cache)."""
    if cfg.frontend == "audio_frames":
        h = inp["frame_embeds"]
        # sinusoidal positional embedding at the (traced) decode position(s)
        b, d = h.shape[0], cfg.d_model
        posb = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
        dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
        ang = posb.astype(jnp.float32)[:, None] / jnp.power(10000.0, dim / d)
        pe = jnp.zeros((b, d), jnp.float32).at[:, 0::2].set(jnp.sin(ang)).at[:, 1::2].set(jnp.cos(ang))
        h = h + pe.astype(h.dtype)[:, None]
    else:
        h = params["embed"][inp["tokens"]].astype(jnp.dtype(cfg.param_dtype))
    pat, n_cycles, tail = layout_of(cfg)

    def cycle_body(h, scans):
        cyc_p, cyc_cache = scans
        new_caches = {}
        for i, kind in enumerate(pat):
            h, nc = _decode_block(cfg, par, kind, cyc_p[f"pos{i}"], h,
                                  cyc_cache[f"pos{i}"], pos, n_host_chunks,
                                  table)
            new_caches[f"pos{i}"] = nc
        return h, new_caches

    h, new_cycle_caches = jax.lax.scan(
        cycle_body, h, (params["cycles"], {k: cache[k] for k in cache if k != "tail"})
    )
    new_cache = dict(new_cycle_caches)
    if tail:
        new_tail = []
        for i, kind in enumerate(tail):
            h, nc = _decode_block(cfg, par, kind, params["tail"][i], h,
                                  cache["tail"][i], pos, n_host_chunks, table)
            new_tail.append(nc)
        new_cache["tail"] = new_tail
    h = L.apply_norm(cfg, params["final_norm"], h)
    logits = (h[:, 0] @ head_matrix(cfg, params)).astype(jnp.float32)
    return logits, new_cache


# ---------------------------------------------------------------------------
# fused mixed step: one prefill chunk OR one decode token per batch row
# ---------------------------------------------------------------------------


def _chunk_attention(cfg: ModelConfig, par: Optional[ParallelContext], p: Params,
                     x: jnp.ndarray, cache: Params, qpos: jnp.ndarray,
                     live: jnp.ndarray, *, window: int = 0,
                     n_host_chunks: int = 0):
    """Chunk-window attention against the cache at a traced offset.

    x [b, cp, d]; qpos [b, cp] the position of each window token; live [b]
    how many leading window tokens are real (0 = row is a complete no-op).
    Attention = online-softmax merge of (a) the PRE-write cache, masked on
    ``kpos`` (optionally host-streamed), and (b) the window's own keys
    under an intra-window causal mask — then the ``live`` keys are written
    into the cache (``mode="drop"`` scatter: dead positions never land, so
    a row with live=0 leaves its cache untouched).  ``live = 1`` is
    exactly one decode step; ``live = cp`` is one dense prefill chunk.
    Returns (attn out [b, cp, qd], new cache)."""
    b, cp, _ = x.shape
    q, k, v = L.qkv_proj(cfg, p, x)  # [b, cp, h, dh]
    q = L.apply_rope(q, qpos, cfg.rope_theta)
    k = L.apply_rope(k, qpos, cfg.rope_theta)
    S = cache["k"].shape[1]
    if window:
        window = min(window, S)  # ring capacity bounds the visible window
    g = cfg.num_heads // cfg.num_kv_heads
    qt = q.astype(jnp.float32).transpose(0, 2, 1, 3)  # [b, hq, cp, dh]
    scale = cfg.head_dim ** -0.5
    key_live = jnp.arange(cp)[None, :] < live[:, None]  # [b, cp]

    def expand(t):
        t = t.astype(jnp.float32)
        return jnp.repeat(t, g, axis=2) if g > 1 else t

    def attend(kc, vc, kp):
        """Partial state [b, h, cp, dh] of the window queries vs a KV slab."""
        ke, ve = expand(kc), expand(vc)
        s_ = jnp.einsum("bhqd,bshd->bhqs", qt, ke) * scale
        ok = (kp[:, None, :] >= 0) & (kp[:, None, :] <= qpos[:, :, None])
        if window:
            ok = ok & (kp[:, None, :] > (qpos[:, :, None] - window))
        s_ = jnp.where(ok[:, None], s_, NEG_INF)
        m = jnp.max(s_, axis=-1)
        pr = jnp.where(s_ <= NEG_INF / 2, 0.0, jnp.exp(s_ - m[..., None]))
        l = pr.sum(-1)
        acc = jnp.einsum("bhqs,bshd->bhqd", pr, ve)
        return SoftmaxState(acc, m, l)

    def attend_intra():
        """The window attending to its own (live, causal) keys — these are
        not in the cache yet, which is what makes the pre-write cache pass
        exact: no entry is double-counted, and ring-buffer eviction cannot
        clobber history the earlier window tokens still need."""
        ke, ve = expand(k), expand(v)
        s_ = jnp.einsum("bhqd,bkhd->bhqk", qt, ke) * scale
        ok = key_live[:, None, :] & (qpos[:, None, :] <= qpos[:, :, None])
        if window:
            ok = ok & (qpos[:, None, :] > (qpos[:, :, None] - window))
        s_ = jnp.where(ok[:, None], s_, NEG_INF)
        m = jnp.max(s_, axis=-1)
        pr = jnp.where(s_ <= NEG_INF / 2, 0.0, jnp.exp(s_ - m[..., None]))
        l = pr.sum(-1)
        acc = jnp.einsum("bhqk,bkhd->bhqd", pr, ve)
        return SoftmaxState(acc, m, l)

    if n_host_chunks and S % n_host_chunks == 0 and not window:
        # FPDT-for-inference, mixed-step flavor: stream the pre-write cache
        # slab by slab (chunk body traced once — program size flat in the
        # slab count), merge with the intra-window part at the end.
        cs = S // n_host_chunks
        slab_spec = None
        if par is not None and par.mesh is not None:
            all_axes = tuple(par.mesh.axis_names)
            if cs % par.mesh.size == 0:
                slab_spec = (None, all_axes, None, None)

        def fetch(c):
            kc = jax.lax.dynamic_slice_in_dim(cache["k"], c * cs, cs, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(cache["v"], c * cs, cs, axis=1)
            kp = jax.lax.dynamic_slice_in_dim(cache["kpos"], c * cs, cs, axis=1)
            if par is not None:
                kc = par.to_device(kc, *(slab_spec or ()))
                vc = par.to_device(vc, *(slab_spec or ()))
            return kc, vc, kp

        # full-attn slots fill [0, pos] in order, so a slab starting past
        # every row's highest live position holds no valid entries
        hi_pos = jnp.max(jnp.where(key_live, qpos, -1))
        hist = fori_double_buffered(
            0, n_host_chunks, fetch,
            lambda c, buf, st: merge(st, attend(*buf)),
            zero_state((b, cfg.num_heads, cp, cfg.head_dim)),
            live=lambda c: (c * cs) <= hi_pos,
        )
    else:
        hist = attend(cache["k"], cache["v"], cache["kpos"])

    o = finalize(merge(hist, attend_intra()))  # [b, h, cp, dh]
    o = o.transpose(0, 2, 1, 3).reshape(b, cp, cfg.q_dim).astype(x.dtype)
    out = o @ p["wo"]

    # write the live window into the cache (after attention).  Ring buffers
    # additionally drop all but the last S (ring capacity) live tokens — the
    # only survivors of intra-window eviction, and mutually collision-free.
    wmask = key_live
    if window:
        wmask = wmask & (jnp.arange(cp)[None, :] >= (live[:, None] - S))
        slot = qpos % S
    else:
        slot = qpos
    slot = jnp.where(wmask, slot, S)  # dead/evicted -> out of bounds, dropped
    bi = jnp.arange(b)[:, None]
    ck = cache["k"].at[bi, slot].set(k.astype(cache["k"].dtype), mode="drop")
    cv = cache["v"].at[bi, slot].set(v.astype(cache["v"].dtype), mode="drop")
    kpos = cache["kpos"].at[bi, slot].set(qpos, mode="drop")
    if par is not None and par.mesh is not None:
        dspec = dense_kv_spec(par, ck.shape)
        ck = par.constrain(ck, *dspec)
        cv = par.constrain(cv, *dspec)
    return out, {"k": ck, "v": cv, "kpos": kpos}


def _chunk_attention_paged(cfg: ModelConfig, par: Optional[ParallelContext],
                           p: Params, x: jnp.ndarray, cache: Params,
                           qpos: jnp.ndarray, live: jnp.ndarray,
                           table: jnp.ndarray, *, n_host_chunks: int = 0):
    """Paged twin of ``_chunk_attention``: the history pass gathers the
    PRE-write pool through the page table (page by page, host-streamed,
    when ``n_host_chunks > 0``; one gather otherwise), the intra-window
    pass is identical to dense, and the live window tokens scatter back
    through the table (dead positions -> out-of-bounds, dropped).  Shared
    (radix) pages are only ever read — the page manager guarantees every
    written page is exclusively owned (COW).  Returns
    (attn out [b, cp, qd], new pool leaves)."""
    b, cp, _ = x.shape
    q, k, v = L.qkv_proj(cfg, p, x)  # [b, cp, h, dh]
    q = L.apply_rope(q, qpos, cfg.rope_theta)
    k = L.apply_rope(k, qpos, cfg.rope_theta)
    n_phys, ps = cache["pkpos"].shape
    max_pages = table.shape[1]
    g = cfg.num_heads // cfg.num_kv_heads
    qt = q.astype(jnp.float32).transpose(0, 2, 1, 3)  # [b, hq, cp, dh]
    scale = cfg.head_dim ** -0.5
    key_live = jnp.arange(cp)[None, :] < live[:, None]  # [b, cp]

    def expand(t):
        t = t.astype(jnp.float32)
        return jnp.repeat(t, g, axis=2) if g > 1 else t

    def attend(kc, vc, kp, okp):
        """Window queries vs a gathered page run; ``okp`` masks entries of
        unmapped logical pages."""
        ke, ve = expand(kc), expand(vc)
        s_ = jnp.einsum("bhqd,bshd->bhqs", qt, ke) * scale
        ok = okp[:, None, :] & (kp[:, None, :] >= 0) & (kp[:, None, :] <= qpos[:, :, None])
        s_ = jnp.where(ok[:, None], s_, NEG_INF)
        m = jnp.max(s_, axis=-1)
        pr = jnp.where(s_ <= NEG_INF / 2, 0.0, jnp.exp(s_ - m[..., None]))
        l = pr.sum(-1)
        acc = jnp.einsum("bhqs,bshd->bhqd", pr, ve)
        return SoftmaxState(acc, m, l)

    def attend_intra():
        """The window vs its own (live, causal) keys — not yet in the pool,
        so the pre-write history pass double-counts nothing."""
        ke, ve = expand(k), expand(v)
        s_ = jnp.einsum("bhqd,bkhd->bhqk", qt, ke) * scale
        ok = key_live[:, None, :] & (qpos[:, None, :] <= qpos[:, :, None])
        s_ = jnp.where(ok[:, None], s_, NEG_INF)
        m = jnp.max(s_, axis=-1)
        pr = jnp.where(s_ <= NEG_INF / 2, 0.0, jnp.exp(s_ - m[..., None]))
        l = pr.sum(-1)
        acc = jnp.einsum("bhqk,bkhd->bhqd", pr, ve)
        return SoftmaxState(acc, m, l)

    if n_host_chunks:
        slab_spec = None
        if par is not None and par.mesh is not None:
            all_axes = tuple(par.mesh.axis_names)
            if ps % par.mesh.size == 0:  # host custom-calls need FULL sharding
                slab_spec = (None, all_axes, None, None)

        def fetch(j):
            kc, vc, kp, okp = _paged_gather(cache["pk"], cache["pv"],
                                            cache["pkpos"], table, j)
            if par is not None:
                kc = par.to_device(kc, *(slab_spec or ()))
                vc = par.to_device(vc, *(slab_spec or ()))
            return kc, vc, kp, okp

        hi_pos = jnp.max(jnp.where(key_live, qpos, -1))
        hist = fori_double_buffered(
            0, max_pages, fetch,
            lambda j, buf, st: merge(st, attend(*buf)),
            zero_state((b, cfg.num_heads, cp, cfg.head_dim)),
            live=lambda j: (j * ps) <= hi_pos,
        )
    else:
        safe = jnp.clip(table, 0, None)
        kall = jnp.take(cache["pk"], safe, axis=0).reshape(
            b, max_pages * ps, *cache["pk"].shape[2:])
        vall = jnp.take(cache["pv"], safe, axis=0).reshape(
            b, max_pages * ps, *cache["pv"].shape[2:])
        kpall = jnp.take(cache["pkpos"], safe, axis=0).reshape(b, max_pages * ps)
        okall = jnp.repeat(table >= 0, ps, axis=1)
        hist = attend(kall, vall, kpall, okall)

    o = finalize(merge(hist, attend_intra()))  # [b, h, cp, dh]
    o = o.transpose(0, 2, 1, 3).reshape(b, cp, cfg.q_dim).astype(x.dtype)
    out = o @ p["wo"]

    # write the live window through the table (after attention)
    pid_w, off = _paged_write_ids(table, qpos, ps, n_phys)  # [b, cp] each
    pid_w = jnp.where(key_live, pid_w, n_phys)  # dead -> OOB, dropped
    ck = cache["pk"].at[pid_w, off].set(k.astype(cache["pk"].dtype), mode="drop")
    cv = cache["pv"].at[pid_w, off].set(v.astype(cache["pv"].dtype), mode="drop")
    kpos = cache["pkpos"].at[pid_w, off].set(qpos, mode="drop")
    if par is not None and par.mesh is not None:
        pspec = paged_pool_spec(par, ps, ck.shape[2])
        ck = par.constrain(ck, *pspec)
        cv = par.constrain(cv, *pspec)
        kpos = par.constrain(kpos, None, pspec[1])
    return out, {"pk": ck, "pv": cv, "pkpos": kpos}


def _chunk_block(cfg, par, kind, p, h, cache, qpos, live, n_host_chunks=0,
                 table=None):
    if kind in ("attn", "local_attn"):
        window = cfg.window if kind == "local_attn" else 0
        hn = L.apply_norm(cfg, p["norm1"], h)
        if table is not None and "pk" in cache:  # paged pool (attn only)
            o, cache = _chunk_attention_paged(cfg, par, p["attn"], hn, cache,
                                              qpos, live, table,
                                              n_host_chunks=n_host_chunks)
        else:
            o, cache = _chunk_attention(cfg, par, p["attn"], hn, cache, qpos, live,
                                        window=window,
                                        n_host_chunks=0 if kind == "local_attn" else n_host_chunks)
        h = h + o
        hn2 = L.apply_norm(cfg, p["norm2"], h)
        if cfg.num_experts:
            from repro.models import moe as MOE

            y, _ = MOE.moe_ffn(cfg, p["moe"], hn2)
        else:
            y = L.mlp_block(cfg, p["mlp"], hn2)
        return h + y, cache
    if kind == "ssm":
        hn = L.apply_norm(cfg, p["norm"], h)
        y, st = M.mamba_chunk_step(cfg, p["mixer"], hn, cache, live)
        return h + y, st
    if kind == "rglru":
        hn = L.apply_norm(cfg, p["norm1"], h)
        y, st = R.rglru_chunk_step(cfg, p["mixer"], hn, cache, live)
        h = h + y
        hn2 = L.apply_norm(cfg, p["norm2"], h)
        return h + L.mlp_block(cfg, p["mlp"], hn2), st
    raise ValueError(kind)


def chunk_step(cfg: ModelConfig, par: Optional[ParallelContext], params: Params,
               cache: Params, toks: jnp.ndarray, offset, live,
               n_host_chunks: int = 0, table: Optional[jnp.ndarray] = None):
    """One fused mixed step: every batch row processes a ``cp``-token window.

    Contract:
      toks   — [b, cp] int32 window tokens.  A row consuming a prefill
               chunk passes the chunk (``live`` real tokens, rest padding);
               a row decoding passes its next token broadcast (``live=1``);
               an idle row passes anything (``live=0`` — complete no-op:
               cache, recurrent state and ring buffers are untouched).
      offset — scalar or int32 [b]: the position of each row's first window
               token (a prefilling row's chunk offset; a decoding row's
               ``pos``).
      live   — scalar or int32 [b] in [0, cp]: real tokens per row.
      cache  — pytree from ``init_cache``; updated in place at the live
               positions only (shape/dtype-stable — rides the mixed-step
               ``lax.scan`` carry in ``runtime/decode_loop.py``).
      table  — optional [b, max_pages] int32 page table for the paged pool
               (``init_paged_cache`` layout; see ``runtime/paged.py``).

    Recurrent blocks (ssm / rglru / local_attn ring) are handled by the
    *state-at-length gather*: pad positions are identity transitions and
    the conv carry is gathered at the true length
    (``mamba.mamba_chunk_step`` / ``rglru.rglru_chunk_step``), so
    variable-length chunked prefill is exact for state-integrating layouts
    — the capability that admits them into continuous batching.

    Returns (logits [b, vocab] fp32 at each row's LAST live token, cache).
    """
    if cfg.frontend == "audio_frames":
        raise ValueError("chunk_step feeds token ids; the audio_frames "
                         "frontend consumes frame embeddings")
    b, cp = toks.shape
    offset = jnp.broadcast_to(jnp.asarray(offset, jnp.int32), (b,))
    live = jnp.broadcast_to(jnp.asarray(live, jnp.int32), (b,))
    qpos = offset[:, None] + jnp.arange(cp)[None, :]  # [b, cp]
    h = params["embed"][toks].astype(jnp.dtype(cfg.param_dtype))
    pat, n_cycles, tail = layout_of(cfg)

    def cycle_body(h, scans):
        cyc_p, cyc_cache = scans
        new_caches = {}
        for i, kind in enumerate(pat):
            h, nc = _chunk_block(cfg, par, kind, cyc_p[f"pos{i}"], h,
                                 cyc_cache[f"pos{i}"], qpos, live, n_host_chunks,
                                 table)
            new_caches[f"pos{i}"] = nc
        return h, new_caches

    h, new_cycle_caches = jax.lax.scan(
        cycle_body, h, (params["cycles"], {k: cache[k] for k in cache if k != "tail"})
    )
    new_cache = dict(new_cycle_caches)
    if tail:
        new_tail = []
        for i, kind in enumerate(tail):
            h, nc = _chunk_block(cfg, par, kind, params["tail"][i], h,
                                 cache["tail"][i], qpos, live, n_host_chunks,
                                 table)
            new_tail.append(nc)
        new_cache["tail"] = new_tail
    h = L.apply_norm(cfg, params["final_norm"], h)
    li = jnp.clip(live - 1, 0, cp - 1)
    last = h[jnp.arange(b), li]
    logits = (last @ head_matrix(cfg, params)).astype(jnp.float32)
    return logits, new_cache


# ---------------------------------------------------------------------------
# prefill: forward + cache population
# ---------------------------------------------------------------------------


def prefill_step(cfg: ModelConfig, par: Optional[ParallelContext], params: Params,
                 batch: Dict[str, jnp.ndarray], max_len: int,
                 lengths: Optional[jnp.ndarray] = None):
    """Forward over the prompt batch, returning (logits, filled cache).

    Contract:
      batch   — prompt batch ({"tokens": [b, s]} or frontend equivalents);
                every row runs the full s-length forward.
      max_len — cache capacity (prompt + generation budget); the returned
                cache is ready for ``decode_step`` at ``pos = s`` (or
                ``pos = lengths`` per row).
      lengths — optional int32 [b] of true prompt lengths for
                *position-masked* prefill of RIGHT-padded variable-length
                prompts: cache entries at positions >= ``lengths[i]`` are
                marked invalid (``kpos = -1``) and row i's logits are taken
                at its last real token (position ``lengths[i] - 1``) rather
                than at s-1.  Right padding + causal attention guarantee
                real tokens never attend to pads, so this is exact for
                global-attention blocks.  Recurrent states (ssm/rglru) and
                the local_attn ring buffer integrate pad tokens into their
                carry, so archs containing those block kinds must prefill
                at exact length (raises ValueError).

    Returns (logits [b, vocab] fp32 at each row's last real token, cache).
    """
    from repro.models import transformer as T

    h = T.embed_input(cfg, params, batch)
    h = h.astype(jnp.dtype(cfg.param_dtype))
    b, s, _ = h.shape
    pat, n_cycles, tail = layout_of(cfg)
    if lengths is not None:
        bad = {k for k in (*pat, *tail) if k != "attn"}
        if bad:
            raise ValueError(
                f"position-masked prefill (lengths=...) only supports pure "
                f"global-attention layouts; {cfg.name} contains {sorted(bad)} "
                f"blocks whose state integrates pad tokens — prefill those "
                f"at exact length instead")
        lengths = jnp.asarray(lengths, jnp.int32)
    if par is not None and par.mesh is not None:
        h = par.seq_sharded(h)

    def prefill_block(kind, p, h):
        if kind in ("attn", "local_attn"):
            window = cfg.window if kind == "local_attn" else 0
            hn = L.apply_norm(cfg, p["norm1"], h)
            from repro.core import fpdt

            o = fpdt.fpdt_attention(cfg, par, p["attn"], hn,
                                    kind=attn_kind(cfg, par), window=window)
            h = h + o @ p["attn"]["wo"]
            # cache: recompute roped k/v (cheap vs attention)
            _, k, v = L.qkv_proj(cfg, p["attn"], hn)
            k = L.apply_rope(k, jnp.arange(s), cfg.rope_theta)
            W = min(cfg.window, max_len) if kind == "local_attn" else max_len
            ck = _attn_cache(cfg, b, W, h.dtype)
            take = min(W, s)
            pvec = jnp.arange(s - take, s)
            # ring slots MUST follow the decode invariant slot = pos % W —
            # writing the tail at slots 0..take-1 is only equivalent when
            # (s - take) % W == 0, and otherwise decode evicts the wrong
            # entry (a position still inside the window)
            slots = pvec % W if kind == "local_attn" else pvec
            kp = jnp.broadcast_to(pvec[None], (b, take))
            if lengths is not None:  # mask pad-token slots as never-filled
                kp = jnp.where(kp < lengths[:, None], kp, -1)
            cache = {
                "k": ck["k"].at[:, slots].set(k[:, s - take:].astype(ck["k"].dtype)),
                "v": ck["v"].at[:, slots].set(v[:, s - take:].astype(ck["v"].dtype)),
                "kpos": ck["kpos"].at[:, slots].set(kp),
            }
            hn2 = L.apply_norm(cfg, p["norm2"], h)
            if cfg.num_experts:
                from repro.models import moe as MOE

                y, _ = MOE.moe_ffn_chunked(cfg, p["moe"], hn2, cfg.mlp_chunks)
            else:
                y = L.mlp_chunked(cfg, p["mlp"], hn2, cfg.mlp_chunks)
            return h + y, cache
        if kind == "ssm":
            hn = L.apply_norm(cfg, p["norm"], h)
            y, st = M.mamba_mixer(cfg, p["mixer"], hn, None, None)
            return h + y, st
        if kind == "rglru":
            hn = L.apply_norm(cfg, p["norm1"], h)
            y, st = R.rglru_mixer(cfg, p["mixer"], hn, None, None, scan_impl="xla")
            h = h + y
            hn2 = L.apply_norm(cfg, p["norm2"], h)
            return h + L.mlp_chunked(cfg, p["mlp"], hn2, cfg.mlp_chunks), st
        raise ValueError(kind)

    def cycle_body(h, cyc_p):
        caches = {}
        for i, kind in enumerate(pat):
            h, c = prefill_block(kind, cyc_p[f"pos{i}"], h)
            caches[f"pos{i}"] = c
        if par is not None and par.mesh is not None:
            h = par.seq_sharded(h)
        return h, caches

    h, cycle_caches = jax.lax.scan(cycle_body, h, params["cycles"])
    cache = dict(cycle_caches)
    if tail:
        tcaches = []
        for i, kind in enumerate(tail):
            h, c = prefill_block(kind, params["tail"][i], h)
            tcaches.append(c)
        cache["tail"] = tcaches
    h = L.apply_norm(cfg, params["final_norm"], h)
    last = h[:, -1] if lengths is None else h[jnp.arange(b), lengths - 1]
    logits = (last @ head_matrix(cfg, params)).astype(jnp.float32)
    return logits, cache
