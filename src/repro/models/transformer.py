"""Model assembly: scan-over-layers decoder covering all assigned families.

Layer stacking: layers are grouped into repeating *cycles* of the arch's
block pattern (dense/MoE/audio/vlm: 1-layer cycle; recurrentgemma:
(rglru, rglru, local_attn)); cycle parameters are stacked and the stack is
driven by one rematerialized ``lax.scan`` — the compiled HLO contains a
single cycle body regardless of depth (compile-time and HLO size stay flat
at 512 devices).  Remainder layers (38 % 3 == 2) run unrolled after the scan.
Inside each attention block the FPDT chunk pipeline is scan-compiled the
same way (core/fpdt.py), so HLO size is flat in the chunk count u as well;
``scan_layers=False`` (roofline probes) unrolls both.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import ad_checkpoint

from repro.configs import ModelConfig
from repro.core import fpdt
from repro.core.chunked_loss import IGNORE, auto_chunks, softmax_xent_chunked
from repro.core.parallel import ParallelContext, make_shard_fn
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import moe as MOE
from repro.models import rglru as R
from repro.runtime import placement

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_block(cfg: ModelConfig, kind: str, key, dtype) -> Params:
    ks = jax.random.split(key, 4)
    if kind in ("attn", "local_attn"):
        p = {
            "norm1": L.init_norm(cfg, dtype),
            "attn": L.init_attn(cfg, ks[0], dtype),
            "norm2": L.init_norm(cfg, dtype),
        }
        if cfg.num_experts:
            p["moe"] = MOE.init_moe(cfg, ks[1], dtype)
        else:
            p["mlp"] = L.init_mlp(cfg, ks[1], dtype)
        return p
    if kind == "ssm":
        return {"norm": L.init_norm(cfg, dtype), "mixer": M.init_mamba(cfg, ks[0], dtype)}
    if kind == "rglru":
        return {
            "norm1": L.init_norm(cfg, dtype),
            "mixer": R.init_rglru(cfg, ks[0], dtype),
            "norm2": L.init_norm(cfg, dtype),
            "mlp": L.init_mlp(cfg, ks[1], dtype),
        }
    raise ValueError(kind)


def pattern_of(cfg: ModelConfig) -> Tuple[str, ...]:
    if cfg.block_pattern:
        return cfg.block_pattern
    return ("ssm",) if cfg.family == "ssm" else ("attn",)


def layout_of(cfg: ModelConfig):
    """(pattern, n_cycles, tail_kinds)."""
    pat = pattern_of(cfg)
    n_cycles = cfg.num_layers // len(pat)
    tail = tuple(pat[: cfg.num_layers % len(pat)])
    return pat, n_cycles, tail


def init_params(cfg: ModelConfig, key) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    pat, n_cycles, tail = layout_of(cfg)
    keys = jax.random.split(key, 4)
    params: Params = {}
    if cfg.frontend != "audio_frames":
        params["embed"] = (
            0.02 * jax.random.normal(keys[0], (cfg.padded_vocab, cfg.d_model))
        ).astype(dtype)
    # stacked cycle params
    cyc_keys = jax.random.split(keys[1], n_cycles)

    def one_cycle(k):
        kk = jax.random.split(k, len(pat))
        return {f"pos{i}": _init_block(cfg, kind, kk[i], dtype) for i, kind in enumerate(pat)}

    params["cycles"] = jax.vmap(one_cycle)(cyc_keys)
    if tail:
        tk = jax.random.split(keys[2], len(tail))
        params["tail"] = [_init_block(cfg, kind, tk[i], dtype) for i, kind in enumerate(tail)]
    params["final_norm"] = L.init_norm(cfg, dtype)
    if not cfg.tie_embeddings:
        params["head"] = L._dense_init(keys[3], (cfg.d_model, cfg.padded_vocab), dtype)
    return params


def head_matrix(cfg: ModelConfig, params: Params) -> jnp.ndarray:
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["head"]


def attn_kind(cfg: ModelConfig, par: Optional[ParallelContext]) -> str:
    if par is None or par.mesh is None:
        return "local"
    if cfg.attn_impl in ("ulysses", "cp"):
        return cfg.attn_impl
    return "ulysses" if cfg.num_heads % par.sp == 0 else "cp"


# ---------------------------------------------------------------------------
# training / prefill forward
# ---------------------------------------------------------------------------


def block_apply(cfg: ModelConfig, par: Optional[ParallelContext], kind: str,
                p: Params, h: jnp.ndarray, pos_offset: int = 0):
    """One block; returns (h, aux_loss)."""
    shard = make_shard_fn(par)
    if kind in ("attn", "local_attn"):
        window = cfg.window if kind == "local_attn" else 0
        hn = L.apply_norm(cfg, p["norm1"], h)
        # Roofline probes unroll the layer stack so HLO costs scale with the
        # true layer count; the scan-compiled FPDT chunk loops hide per-pair
        # costs the same way, so probe mode unrolls the chunk pipeline too
        # (identical numerics — differentially tested in test_fpdt_scan.py).
        acfg = cfg if cfg.scan_layers else dataclasses.replace(cfg, fpdt_unroll=True)
        o = fpdt.fpdt_attention(acfg, par, p["attn"], hn,
                                kind=attn_kind(cfg, par), window=window,
                                pos_offset=pos_offset)
        h = h + o @ p["attn"]["wo"]
        hn2 = L.apply_norm(cfg, p["norm2"], h)
        if cfg.num_experts:
            y, aux = MOE.moe_ffn_chunked(cfg, p["moe"], hn2, cfg.mlp_chunks, shard)
        else:
            y, aux = L.mlp_chunked(cfg, p["mlp"], hn2, cfg.mlp_chunks), jnp.float32(0)
        return h + y, aux
    if kind == "ssm":
        hn = L.apply_norm(cfg, p["norm"], h)
        y, _ = M.mamba_mixer(cfg, p["mixer"], hn, None, shard,
                             n_shards=par.sp if par is not None and par.mesh is not None else 1)
        return h + y, jnp.float32(0)
    if kind == "rglru":
        hn = L.apply_norm(cfg, p["norm1"], h)
        y, _ = R.rglru_mixer(cfg, p["mixer"], hn, None, shard,
                             scan_impl="pallas" if par is None else "xla",
                             n_shards=par.sp if par is not None and par.mesh is not None else 1)
        h = h + y
        hn2 = L.apply_norm(cfg, p["norm2"], h)
        return h + L.mlp_chunked(cfg, p["mlp"], hn2, cfg.mlp_chunks), jnp.float32(0)
    raise ValueError(kind)


def _remat_policy(cfg: ModelConfig, par: Optional[ParallelContext] = None):
    if cfg.remat == "none":
        return None
    if cfg.remat == "offload":
        # memory kinds come from the placement layer; on backends with no
        # host pool this degrades to full remat (nothing saveable)
        pol = par.pol if par is not None else placement.default_policy()
        return pol.remat_policy(offload_names=["block_in"])
    return jax.checkpoint_policies.nothing_saveable


def hidden_forward(cfg: ModelConfig, par: Optional[ParallelContext],
                   params: Params, h: jnp.ndarray):
    """Run the full layer stack. h: [b, S, d]. Returns (h, aux)."""
    pat, n_cycles, tail = layout_of(cfg)
    if par is not None and par.mesh is not None:
        h = par.seq_sharded(h)

    def cycle_body(carry, cyc_p):
        x, aux = carry
        if cfg.remat != "none":
            x = ad_checkpoint.checkpoint_name(x, "block_in")
        for i, kind in enumerate(pat):
            x, a = block_apply(cfg, par, kind, cyc_p[f"pos{i}"], x)
            aux = aux + a
        if par is not None and par.mesh is not None:
            x = par.seq_sharded(x)
        return (x, aux), None

    body = cycle_body
    if cfg.remat != "none":
        body = jax.checkpoint(cycle_body, policy=_remat_policy(cfg, par),
                              prevent_cse=False)
    if cfg.scan_layers:
        (h, aux), _ = jax.lax.scan(body, (h, jnp.float32(0)), params["cycles"])
    else:  # unrolled (roofline probes: HLO costs scale with true layer count)
        carry = (h, jnp.float32(0))
        for ci in range(n_cycles):
            cyc = jax.tree.map(lambda x: x[ci], params["cycles"])
            carry, _ = body(carry, cyc)
        h, aux = carry
    for i, kind in enumerate(tail):
        h, a = block_apply(cfg, par, kind, params["tail"][i], h)
        aux = aux + a
    return h, aux


def embed_input(cfg: ModelConfig, params: Params, batch: Dict[str, jnp.ndarray]):
    """Assemble the input hidden sequence (modality frontends are stubs)."""
    if cfg.frontend == "audio_frames":
        h = batch["frame_embeds"]  # [b, s, d] precomputed EnCodec frame embeds
        s = h.shape[1]
        h = h + L.sinusoidal_pos_emb(s, cfg.d_model).astype(h.dtype)[None]
        return h
    tok_emb = params["embed"][batch["tokens"]]
    if cfg.frontend == "vision_patches":
        return jnp.concatenate([batch["patch_embeds"].astype(tok_emb.dtype), tok_emb], axis=1)
    return tok_emb


def loss_fn(cfg: ModelConfig, par: Optional[ParallelContext],
            params: Params, batch: Dict[str, jnp.ndarray]):
    """Mean next-token xent (labels pre-shifted; IGNORE masked). Returns
    (loss, metrics)."""
    h = embed_input(cfg, params, batch)
    h = h.astype(jnp.dtype(cfg.param_dtype))
    h, aux = hidden_forward(cfg, par, params, h)
    h = L.apply_norm(cfg, params["final_norm"], h)
    labels = batch["labels"]
    if cfg.frontend == "vision_patches":  # no loss on patch positions
        pad = jnp.full(batch["patch_embeds"].shape[:2], IGNORE, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    n_chunks = cfg.loss_chunks or auto_chunks(
        cfg, h.shape[1], sp=par.sp if par is not None else 1)
    loss_sum, count = softmax_xent_chunked(h, head_matrix(cfg, params), labels, n_chunks, par=par)
    loss = loss_sum / jnp.maximum(count, 1.0)
    total = loss + 0.01 * aux
    return total, {"loss": loss, "aux": aux, "tokens": count}
