"""Mixture-of-Experts FFN with GShard-style grouped capacity dispatch.

Expert-parallel: the expert dimension is sharded over the ``model`` mesh axis
(EP); token dispatch/combine einsums induce the EP all-to-all under GSPMD.
Capacity-based dispatch keeps compiled FLOPs at ~active-expert cost
(6·N_active·D), which the roofline analysis depends on.
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models.layers import _dense_init

Params = Dict[str, Any]

GROUP_TOKENS = 512  # tokens per dispatch group


def init_moe(cfg: ModelConfig, key, dtype) -> Params:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    p = {
        "router": _dense_init(ks[0], (d, e), jnp.float32),
        "wu": _dense_init(ks[1], (e, d, ff), dtype, fan_in=d),
        "wd": _dense_init(ks[2], (e, ff, d), dtype, fan_in=ff),
    }
    if cfg.mlp_act == "swiglu":
        p["wg"] = _dense_init(ks[3], (e, d, ff), dtype, fan_in=d)
    return p


def capacity(tokens_per_group: int, cfg: ModelConfig) -> int:
    c = math.ceil(
        tokens_per_group * cfg.experts_per_token / cfg.num_experts * cfg.moe_capacity_factor
    )
    return max(4, min(c, tokens_per_group))


def moe_ffn(cfg: ModelConfig, p: Params, x: jnp.ndarray, shard=None):
    """x: [b, s, d] -> (y [b, s, d], aux_loss scalar)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    tg = min(GROUP_TOKENS, b * s)
    assert (b * s) % tg == 0, (b, s, tg)
    g = (b * s) // tg
    cap = capacity(tg, cfg)

    xt = x.reshape(g, tg, d)
    logits = (xt.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # [g, tg, e]
    gates = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(gates, k)  # [g, tg, k]
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)  # renormalize over chosen

    # load-balancing aux loss (Switch): e * sum(frac_tokens * frac_router)
    me = jnp.mean(jax.nn.one_hot(topi[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    ce = jnp.mean(gates, axis=(0, 1))
    aux = e * jnp.sum(me * ce)

    # position of each (token, choice) within its expert queue
    onehot = jax.nn.one_hot(topi, e, dtype=jnp.int32)  # [g, tg, k, e]
    flat = onehot.reshape(g, tg * k, e)
    pos = jnp.cumsum(flat, axis=1) - 1  # [g, tg*k, e]
    pos = (pos * flat).sum(-1).reshape(g, tg, k)  # queue position per choice
    keep = pos < cap

    # dispatch/combine tensors [g, tg, e, cap]
    disp = (
        jax.nn.one_hot(topi, e, dtype=x.dtype)[..., None]
        * jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1, dtype=x.dtype)[..., :cap][..., None, :]
    ).sum(2)  # sum over k choices -> [g, tg, e, cap]
    comb = (
        (topv.astype(x.dtype) * keep.astype(x.dtype))[..., None, None]
        * jax.nn.one_hot(topi, e, dtype=x.dtype)[..., None]
        * jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1, dtype=x.dtype)[..., :cap][..., None, :]
    ).sum(2)

    ein = xt  # [g, tg, d]
    expert_in = jnp.einsum("gtec,gtd->egcd", disp, ein)  # [e, g, cap, d]
    if shard is not None:
        expert_in = shard(expert_in, "expert")
    if cfg.mlp_act == "swiglu":
        h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", expert_in, p["wg"])) * jnp.einsum(
            "egcd,edf->egcf", expert_in, p["wu"]
        )
    else:
        h = jax.nn.gelu(jnp.einsum("egcd,edf->egcf", expert_in, p["wu"]))
    expert_out = jnp.einsum("egcf,efd->egcd", h, p["wd"])
    if shard is not None:
        expert_out = shard(expert_out, "expert")
    y = jnp.einsum("gtec,egcd->gtd", comb, expert_out)
    return y.reshape(b, s, d), aux


def moe_ffn_chunked(cfg: ModelConfig, p: Params, x: jnp.ndarray, n_chunks: int, shard=None):
    """Sequence-chunked MoE (paper §5.4 applied to the MoE FFN)."""
    if n_chunks <= 1 or x.shape[1] % n_chunks != 0:
        return moe_ffn(cfg, p, x, shard)
    b, s, d = x.shape
    xs = x.reshape(b, n_chunks, s // n_chunks, d).transpose(1, 0, 2, 3)

    @jax.checkpoint
    def step(_, xc):
        y, aux = moe_ffn(cfg, p, xc, shard)
        return None, (y, aux)

    _, (ys, auxs) = jax.lax.scan(step, None, xs)
    return ys.transpose(1, 0, 2, 3).reshape(b, s, d), jnp.mean(auxs)
