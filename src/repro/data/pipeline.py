"""Deterministic, checkpointable, sharded data pipeline.

Sources:
  * SyntheticLM  — Zipf-ish token stream with document structure, generated
    per (seed, step, shard) so any host can materialize exactly its shard of
    any step without coordination (what a 1000-node fleet needs: no data
    server, O(1) resume).
  * MmapTokens   — memory-mapped flat token file, strided by (step, shard).

The iterator state is a single integer ``step`` — checkpoint/restore and
elastic re-sharding (different dp size on restore) are trivial by design:
batch(step) is a pure function of (seed, step, global layout).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs import ModelConfig, ShapeConfig


@dataclasses.dataclass
class DataConfig:
    seed: int = 1234
    vocab_size: int = 32000
    mmap_path: Optional[str] = None
    zipf_a: float = 1.2


class TokenSource:
    """batch(step) -> {"tokens": [B, S+1] int32} pure in (seed, step)."""

    def __init__(self, dc: DataConfig, global_batch: int, seq_len: int):
        self.dc = dc
        self.B = global_batch
        self.S = seq_len
        self._mm = None
        if dc.mmap_path:
            self._mm = np.memmap(dc.mmap_path, dtype=np.int32, mode="r")

    def batch(self, step: int) -> np.ndarray:
        if self._mm is not None:
            n = self.B * (self.S + 1)
            start = (step * n) % max(1, len(self._mm) - n)
            return np.asarray(self._mm[start : start + n]).reshape(self.B, self.S + 1)
        rng = np.random.default_rng(np.random.SeedSequence([self.dc.seed, step]))
        toks = rng.zipf(self.dc.zipf_a, size=(self.B, self.S + 1)).astype(np.int64)
        toks = (toks - 1) % (self.dc.vocab_size - 2) + 2  # reserve 0=BOS, 1=EOS
        # document structure: independent geometric doc lengths -> BOS markers
        doc_starts = rng.random((self.B, self.S + 1)) < (1.0 / 512)
        doc_starts[:, 0] = True
        toks[doc_starts] = 0
        return toks.astype(np.int32)


def make_batch_fn(cfg: ModelConfig, shape: ShapeConfig, dc: Optional[DataConfig] = None):
    """Returns batch(step) -> dict of numpy arrays matching input_specs."""
    dc = dc or DataConfig(vocab_size=cfg.vocab_size)
    dc.vocab_size = cfg.vocab_size
    B, S = shape.global_batch, shape.seq_len
    rng_stub = np.random.default_rng(dc.seed)

    if cfg.frontend == "audio_frames":
        def batch(step: int) -> Dict[str, np.ndarray]:
            src = TokenSource(dc, B, S)
            toks = src.batch(step)
            rng = np.random.default_rng(np.random.SeedSequence([dc.seed, step, 7]))
            # STUB frontend: EnCodec frame embeddings stand-in
            fe = rng.standard_normal((B, S, cfg.d_model), dtype=np.float32) * 0.02
            return {"frame_embeds": fe, "labels": toks[:, 1 : S + 1]}
        return batch

    if cfg.frontend == "vision_patches":
        St = S - cfg.num_patches
        def batch(step: int) -> Dict[str, np.ndarray]:
            src = TokenSource(dc, B, St)
            toks = src.batch(step)
            rng = np.random.default_rng(np.random.SeedSequence([dc.seed, step, 7]))
            pe = rng.standard_normal((B, cfg.num_patches, cfg.d_model), dtype=np.float32) * 0.02
            return {
                "patch_embeds": pe,
                "tokens": toks[:, :St],
                "labels": toks[:, 1 : St + 1],
            }
        return batch

    def batch(step: int) -> Dict[str, np.ndarray]:
        src = TokenSource(dc, B, S)
        toks = src.batch(step)
        return {"tokens": toks[:, :S], "labels": toks[:, 1 : S + 1]}

    return batch


class CheckpointableIterator:
    """Step-indexed iterator; ``state`` is just the step counter."""

    def __init__(self, batch_fn, start_step: int = 0):
        self.batch_fn = batch_fn
        self.step = start_step

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        b = self.batch_fn(self.step)
        self.step += 1
        return b

    def state(self) -> int:
        return self.step

    def restore(self, state: int) -> None:
        self.step = int(state)
