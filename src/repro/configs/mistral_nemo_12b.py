"""mistral-nemo-12b [dense] — hf:mistralai/Mistral-Nemo-Base-2407, 128k ctx.

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.
head_dim=128 (the real arch decouples head_dim from d_model/heads).
"""
from repro.configs import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b",
        family="dense",
        num_layers=40,
        d_model=5120,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=131072,
        mlp_act="swiglu",
        norm="rmsnorm",
        rope_theta=1000000.0,
        attn_impl="ulysses",
    )
