"""Config system: model configs, input-shape configs, and the registry.

Every assigned architecture has one module in this package exporting
``config() -> ModelConfig``.  ``get_config(name)`` resolves by registry id
(e.g. ``llama3.2-1b``), ``reduced(cfg)`` derives a CPU-smoke-testable config
of the same family.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field, replace
from typing import Tuple

# --------------------------------------------------------------------------
# Model config
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25

    # SSM (mamba1)
    ssm_state: int = 0
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 = auto ceil(d_model/16)

    # hybrid (recurrentgemma): cycle of block kinds; window for local attn
    block_pattern: Tuple[str, ...] = ()
    window: int = 0

    # modality frontends (STUBS: input_specs() provides embeddings)
    frontend: str = "none"  # none | audio_frames | vision_patches
    num_patches: int = 0

    # numerics / structure
    mlp_act: str = "swiglu"  # swiglu | gelu
    qkv_bias: bool = False
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    param_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"

    # --- the paper's technique + parallelism knobs -----------------------
    attn_impl: str = "auto"  # auto | ulysses | cp | none
    fpdt_chunks: int = 1  # u; 1 = un-chunked (plain Ulysses/CP baseline)
    fpdt_offload: bool = False  # offload idle KV chunks to pinned_host
    # True: legacy Python-unrolled chunk loops (O(u^2) HLO; kept for
    # differential testing against the scan-compiled pipeline)
    fpdt_unroll: bool = False
    mlp_chunks: int = 1  # paper: 2x attention chunks
    loss_chunks: int = 0  # 0 = auto: ceil(vocab/d_model) * 2 (paper 5.4)
    remat: str = "full"  # none | full | offload (AC. / OC. in Table 3)
    scan_layers: bool = True  # False: unroll cycles (roofline probes)
    # block-sparse attention (paper §5.6 / Table 4): fraction of off-diagonal
    # chunk pairs skipped (0.0 = full attention); diagonal always kept
    attn_sparsity: float = 0.0
    # flash-attention kernel tiling
    block_q: int = 512
    block_k: int = 512

    # ----------------------------------------------------------------- api
    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def dt_rank_actual(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)

    @property
    def padded_vocab(self) -> int:
        """Embedding/head table rows padded to 128 (Megatron-style) so the
        vocab dim shards over the mesh axes; labels/ids never touch padding."""
        return -(-self.vocab_size // 128) * 128

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def block_kind(self, layer: int) -> str:
        """Mixer kind of layer ``layer``: attn | ssm | rglru | local_attn."""
        if self.family == "ssm":
            return "ssm"
        if self.block_pattern:
            return self.block_pattern[layer % len(self.block_pattern)]
        return "attn"

    def layer_kinds(self) -> Tuple[str, ...]:
        return tuple(self.block_kind(i) for i in range(self.num_layers))

    def num_params(self) -> int:
        """Total parameter count (embedding included once if tied)."""
        return _count_params(self)

    def num_active_params(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        return _count_params(self, active_only=True)


def _count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    d, dff = cfg.d_model, cfg.d_ff
    n_mlp_mats = 3 if cfg.mlp_act == "swiglu" else 2
    total = 0
    for kind in cfg.layer_kinds():
        if kind in ("attn", "local_attn"):
            total += d * cfg.q_dim + 2 * d * cfg.kv_dim + cfg.q_dim * d
            if cfg.qkv_bias:
                total += cfg.q_dim + 2 * cfg.kv_dim
        elif kind == "ssm":
            di, ds, dtr = cfg.d_inner, cfg.ssm_state, cfg.dt_rank_actual
            total += d * 2 * di  # in_proj
            total += di * cfg.d_conv + di  # depthwise conv + bias
            total += di * (dtr + 2 * ds)  # x_proj
            total += dtr * di + di  # dt_proj
            total += di * ds + di  # A_log, D
            total += di * d  # out_proj
        elif kind == "rglru":
            di = cfg.d_inner if cfg.expand else d
            total += 2 * d * di  # x and gate branches
            total += di * cfg.d_conv + di  # temporal conv
            total += 2 * di  # RG-LRU a-param + input gate proj (diag)
            total += 2 * di * di // 1  # recurrent/input gate dense (lru)
            total += di * d  # out proj
        # MLP / MoE
        if kind == "ssm":
            continue  # mamba block has no separate MLP
        if cfg.num_experts:
            e = cfg.experts_per_token if active_only else cfg.num_experts
            total += e * n_mlp_mats * d * dff
            total += d * cfg.num_experts  # router
        else:
            total += n_mlp_mats * d * dff
        # norms
        total += 2 * d
    total += cfg.vocab_size * d  # embedding
    if not cfg.tie_embeddings:
        total += cfg.vocab_size * d  # lm head
    total += d  # final norm
    return total


# --------------------------------------------------------------------------
# Input-shape configs (assigned shape set, applies to every arch)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# Pure full-attention archs skip long_500k (sub-quadratic required).
LONG_CTX_ARCHS = ("falcon-mamba-7b", "recurrentgemma-9b")
# Beyond-spec EXTRA cell: FPDT host-offloaded KV decode on a dense arch.
EXTRA_LONG_CTX_ARCHS = ("llama3.2-1b",)


def shape_applicable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in LONG_CTX_ARCHS or arch in EXTRA_LONG_CTX_ARCHS
    return True


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

ASSIGNED_ARCHS = (
    "falcon-mamba-7b",
    "granite-moe-1b-a400m",
    "llama4-maverick-400b-a17b",
    "musicgen-medium",
    "llama3.2-1b",
    "yi-34b",
    "qwen1.5-4b",
    "mistral-nemo-12b",
    "recurrentgemma-9b",
    "internvl2-2b",
)

PAPER_ARCHS = (
    "gpt-2.7b",
    "gpt-6.7b",
    "gpt-13b",
    "gpt-30b",
    "llama-8b",
    "llama-70b",
)

_MODULE_FOR = {
    "falcon-mamba-7b": "falcon_mamba_7b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "musicgen-medium": "musicgen_medium",
    "llama3.2-1b": "llama3p2_1b",
    "yi-34b": "yi_34b",
    "qwen1.5-4b": "qwen1p5_4b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "internvl2-2b": "internvl2_2b",
    "gpt-2.7b": "gpt_paper",
    "gpt-6.7b": "gpt_paper",
    "gpt-13b": "gpt_paper",
    "gpt-30b": "gpt_paper",
    "llama-8b": "llama_paper",
    "llama-70b": "llama_paper",
}


def list_configs():
    return sorted(_MODULE_FOR)


def get_config(name: str, **overrides) -> ModelConfig:
    if name not in _MODULE_FOR:
        raise KeyError(f"unknown arch {name!r}; known: {list_configs()}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR[name]}")
    cfg = mod.config(name) if "paper" in _MODULE_FOR[name] else mod.config()
    if overrides:
        cfg = replace(cfg, **overrides)
    return cfg


def reduced(cfg: ModelConfig) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    kwargs = dict(
        name=cfg.name + "-reduced",
        num_layers=min(cfg.num_layers, 3 if not cfg.block_pattern else len(cfg.block_pattern)),
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4,
        head_dim=16,
        d_ff=128 if not cfg.num_experts else 32,
        vocab_size=256,
        num_patches=min(cfg.num_patches, 4),
        block_q=16,
        block_k=16,
    )
    if cfg.num_experts:
        kwargs["num_experts"] = min(cfg.num_experts, 4)
        kwargs["experts_per_token"] = min(cfg.experts_per_token, 2)
    if cfg.family == "ssm" or "ssm" in cfg.block_pattern or "rglru" in cfg.block_pattern:
        kwargs["expand"] = 2
        kwargs["ssm_state"] = min(cfg.ssm_state or 4, 4)
        kwargs["dt_rank"] = 4
    if cfg.window:
        kwargs["window"] = 8
    return replace(cfg, **kwargs)
