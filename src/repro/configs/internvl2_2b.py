"""internvl2-2b [vlm] — InternViT + InternLM2 backbone (arXiv:2404.16821).

LM backbone: 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.
Vision frontend is a STUB: input_specs() provides precomputed patch
embeddings (b, num_patches, d_model) prepended to the token sequence.
"""
from repro.configs import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b",
        family="vlm",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=92553,
        mlp_act="swiglu",
        norm="rmsnorm",
        rope_theta=1000000.0,
        frontend="vision_patches",
        num_patches=256,
        attn_impl="ulysses",
    )
