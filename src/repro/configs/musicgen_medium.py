"""musicgen-medium [audio] — decoder-only over EnCodec tokens (arXiv:2306.05284).

48L d_model=1536 24H (MHA kv=24) d_ff=6144 vocab=2048.
Modality frontend is a STUB: input_specs() provides precomputed frame
embeddings (b, s, d_model); the transformer backbone is what we build.
24 heads % 16 != 0 -> all-gather context parallelism (FPDT-CP).
"""
from repro.configs import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium",
        family="audio",
        num_layers=48,
        d_model=1536,
        num_heads=24,
        num_kv_heads=24,
        head_dim=64,
        d_ff=6144,
        vocab_size=2048,
        mlp_act="gelu",
        norm="layernorm",
        frontend="audio_frames",
        attn_impl="cp",
    )
