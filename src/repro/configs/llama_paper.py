"""Llama configs used by the FPDT paper (8B / 70B)."""
from repro.configs import ModelConfig

_DIMS = {
    "llama-8b": dict(num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, d_ff=14336, vocab_size=128256),
    "llama-70b": dict(num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8, d_ff=28672, vocab_size=128256),
}


def config(name: str = "llama-8b") -> ModelConfig:
    dims = _DIMS[name]
    return ModelConfig(
        name=name,
        family="dense",
        head_dim=dims["d_model"] // dims["num_heads"],
        mlp_act="swiglu",
        norm="rmsnorm",
        rope_theta=500000.0,
        attn_impl="auto",
        **dims,
    )
