"""qwen1.5-4b [dense] — QKV bias (hf:Qwen/Qwen1.5 family).

40L d_model=2560 20H (MHA kv=20) d_ff=6912 vocab=151936, QKV bias.
20 heads % 16 != 0 -> all-gather context parallelism (FPDT-CP).
"""
from repro.configs import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-4b",
        family="dense",
        num_layers=40,
        d_model=2560,
        num_heads=20,
        num_kv_heads=20,
        head_dim=128,
        d_ff=6912,
        vocab_size=151936,
        mlp_act="swiglu",
        norm="rmsnorm",
        qkv_bias=True,
        attn_impl="cp",
    )
