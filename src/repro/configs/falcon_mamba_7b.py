"""falcon-mamba-7b [ssm] — mamba1 arch, attention-free (arXiv:2410.05355).

64L d_model=4096 d_ff=0 vocab=65024, ssm_state=16, d_inner=2*d_model.
No attention heads: the `model` mesh axis shards SSM channels via the
sequence<->channel all-to-all (Ulysses-for-SSMs, DESIGN.md §3); FPDT maps to
the chunked sequential scan with carried SSM state.
"""
from repro.configs import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b",
        family="ssm",
        num_layers=64,
        d_model=4096,
        num_heads=0,
        num_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab_size=65024,
        ssm_state=16,
        d_conv=4,
        expand=2,
        mlp_act="swiglu",
        norm="rmsnorm",
        tie_embeddings=False,
        attn_impl="none",
    )
