"""llama4-maverick-400b-a17b [moe] — MoE, early fusion (hf:meta-llama/Llama-4).

48L d_model=5120 40H (GQA kv=8) d_ff=8192/expert vocab=202048, MoE 128e top-1.
40 heads % 16 != 0 -> all-gather context parallelism (FPDT-CP).
Optimizer state kept in bf16 so per-chip state fits v5e HBM at 512 chips
(see DESIGN.md §4 — the assigned 48Lx128e config totals ~780B params).
"""
from repro.configs import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b",
        family="moe",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=202048,
        num_experts=128,
        experts_per_token=1,
        mlp_act="swiglu",
        norm="rmsnorm",
        rope_theta=500000.0,
        attn_impl="cp",
        opt_state_dtype="bfloat16",
    )
