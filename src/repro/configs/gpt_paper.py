"""GPT configs used by the FPDT paper (2.7B / 6.7B / 13B / 30B).

Standard GPT-3-family dims; used by the paper-table benchmarks
(Table 1, Fig. 11, Fig. 12, Table 3, Table 4).
"""
from repro.configs import ModelConfig

_DIMS = {
    "gpt-2.7b": dict(num_layers=32, d_model=2560, num_heads=32),
    "gpt-6.7b": dict(num_layers=32, d_model=4096, num_heads=32),
    "gpt-13b": dict(num_layers=40, d_model=5120, num_heads=40),
    "gpt-30b": dict(num_layers=48, d_model=7168, num_heads=56),
}


def config(name: str = "gpt-2.7b") -> ModelConfig:
    dims = _DIMS[name]
    d = dims["d_model"]
    return ModelConfig(
        name=name,
        family="dense",
        num_kv_heads=dims["num_heads"],
        head_dim=d // dims["num_heads"],
        d_ff=4 * d,
        vocab_size=50304,
        mlp_act="gelu",
        norm="layernorm",
        attn_impl="auto",
        **dims,
    )
