"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1:2 (arXiv:2402.19427).

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000, window=2048.
Block pattern cycles (rglru, rglru, local_attn).
"""
from repro.configs import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        num_layers=38,
        d_model=4096,
        num_heads=16,
        num_kv_heads=1,
        head_dim=256,
        d_ff=12288,
        vocab_size=256000,
        expand=1,  # lru_width == d_model in RG-9B
        block_pattern=("rglru", "rglru", "local_attn"),
        window=2048,
        mlp_act="gelu",
        norm="rmsnorm",
        attn_impl="ulysses",
    )
