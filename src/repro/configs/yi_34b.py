"""yi-34b [dense] — llama-arch GQA (arXiv:2403.04652).

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
56 heads % 16 != 0 -> all-gather context parallelism (FPDT-CP).
"""
from repro.configs import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-34b",
        family="dense",
        num_layers=60,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        head_dim=128,
        d_ff=20480,
        vocab_size=64000,
        mlp_act="swiglu",
        norm="rmsnorm",
        rope_theta=5000000.0,
        attn_impl="cp",
    )
