"""Sharded, async, elastic checkpointing.

Layout on disk (per step):
    <dir>/step_<N>.tmp/           written first
        MANIFEST.json             step, leaf index, shard counts, mesh info
        <leaf_id>.shard<k>.npy    axis-0 slices of each leaf
    <dir>/step_<N>/               atomic rename on completion (commit point)

Design points for 1000+ nodes:
  * per-leaf axis-0 shard files emulate per-host shard writes: restore
    reassembles from the index, so a checkpoint written on one mesh restores
    onto ANY mesh/device count (elastic re-scaling) — resharding happens at
    device_put with the new sharding.
  * async: `save(...)` snapshots to host memory (device_get) then writes in
    a background thread, overlapping the next training steps; `wait()`
    joins before the next save or on exit.
  * atomicity: readers only ever see fully-written checkpoints (tmp+rename);
    partial writes from preempted hosts are invisible.
  * SIGTERM-driven final save is wired in runtime/train_loop.py.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import ml_dtypes

from repro.runtime import placement
import numpy as np


def _np_dtype(name: str):
    try:
        return np.dtype(name)
    except TypeError:
        return np.dtype(getattr(ml_dtypes, name))


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path
        )
        out.append((key, leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str, shards_per_leaf: int = 4, keep: int = 3):
        self.dir = directory
        self.shards = shards_per_leaf
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ----------------------------------------------------------- save
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None,
             blocking: bool = False) -> None:
        self.wait()
        # snapshot to host (device_get) on the caller thread
        leaves = [(k, np.asarray(jax.device_get(v))) for k, v in _flatten_with_paths(tree)]

        def _write():
            tmp = os.path.join(self.dir, f"step_{step}.tmp")
            final = os.path.join(self.dir, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            index = {}
            for key, arr in leaves:
                leaf_id = key.replace("/", "__")
                n = min(self.shards, max(1, arr.shape[0] if arr.ndim else 1))
                bounds = np.linspace(0, arr.shape[0] if arr.ndim else 1, n + 1, dtype=int)
                files = []
                for s in range(n):
                    fn = f"{leaf_id}.shard{s}.npy"
                    part = arr[bounds[s]:bounds[s + 1]] if arr.ndim else arr
                    # raw-byte payload: robust for extension dtypes (bf16)
                    raw = np.frombuffer(np.ascontiguousarray(part).tobytes(), np.uint8)
                    np.save(os.path.join(tmp, fn), raw)
                    files.append(fn)
                index[key] = {
                    "files": files, "shape": list(arr.shape), "dtype": str(arr.dtype),
                }
            manifest = {"step": step, "index": index, "extra": extra or {}}
            with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # commit
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # ----------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, d, "MANIFEST.json")):
                    out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target: Any, shardings: Any = None) -> Any:
        """Restore into the structure of ``target``; optional new shardings
        (elastic: target mesh may differ from the save-time mesh)."""
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "MANIFEST.json")) as f:
            manifest = json.load(f)
        index = manifest["index"]

        keys = [k for k, _ in _flatten_with_paths(target)]
        shard_leaves = (
            [s for _, s in _flatten_with_paths(shardings)] if shardings is not None
            else [None] * len(keys)
        )
        leaves = []
        for key, shd in zip(keys, shard_leaves):
            meta = index[key]
            raw = np.concatenate([np.load(os.path.join(path, fn)) for fn in meta["files"]])
            arr = np.frombuffer(raw.tobytes(), _np_dtype(meta["dtype"])).reshape(meta["shape"])
            leaves.append(placement.default_policy().put(arr, shd))
        _, tdef = jax.tree_util.tree_flatten(target)
        return jax.tree_util.tree_unflatten(tdef, leaves), manifest["extra"]
