"""Continuous-batching serving example: a mixed-length prompt workload run
through the fused mixed-step scheduler (`runtime/decode_loop.ServeEngine`)
— more prompts than slots, variable prompt lengths (including prompts
LONGER than the bucket: they just take more prefill chunks), staggered
finishes (random stop token), chunked prefill streaming into freed slots
*while the other slots keep decoding*, FPDT-style host-streamed KV.

  PYTHONPATH=src python examples/serve_batched.py --slots 4 --requests 10 \
      [--prefill-chunk 16] [--blocking]

``--paged`` runs the same workload through the slot-shared paged KV pool
(`runtime/paged.py`); add ``--shared-prefix N`` for the shared-system-
prompt variant — every request starts with the same N tokens, so the
radix tree maps the prefix pages copy-free and only the distinct
suffixes are prefilled (prefix-hit and page-occupancy stats printed):

  PYTHONPATH=src python examples/serve_batched.py --paged \
      --shared-prefix 128 --page-size 16 --slots 4 --requests 10

``--mesh AxB --replicas N`` shards N paged engines over disjoint (A data,
B model) device slices behind the session-affine router: each "tenant"
(one distinct system prompt per replica) keeps hitting the same replica's
radix tree, so the prefix-hit rate survives routing — rerun with
``--router rr`` to watch round-robin shred it:

  PYTHONPATH=src python examples/serve_batched.py --paged --mesh 1x2 \
      --replicas 2 --shared-prefix 64 --requests 8 [--router rr]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

if "--mesh" in sys.argv:  # force fake CPU devices BEFORE jax import
    _i = sys.argv.index("--mesh")
    _d, _m = (int(x) for x in sys.argv[_i + 1].split("x"))
    _r = (int(sys.argv[sys.argv.index("--replicas") + 1])
          if "--replicas" in sys.argv else 2)
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={_d * _m * _r}")

import dataclasses

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core.parallel import ParallelContext
from repro.models import transformer as T
from repro.runtime import decode_loop as DL


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--slots", type=int, default=4, help="concurrent cache rows")
    ap.add_argument("--requests", type=int, default=10, help="queued prompts")
    ap.add_argument("--bucket", type=int, default=48,
                    help="capacity floor for prompt length (longer prompts "
                         "are still legal — they take more chunks)")
    ap.add_argument("--min-prompt", type=int, default=8)
    ap.add_argument("--max-prompt", type=int, default=72,
                    help="longest workload prompt (> bucket exercises "
                         "multi-chunk refill)")
    ap.add_argument("--gen", type=int, default=16, help="max new tokens per request")
    ap.add_argument("--segment", type=int, default=8, help="mixed steps per dispatch")
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="prompt tokens consumed per mixed step by a "
                         "refilling slot (0 = auto)")
    ap.add_argument("--host-kv-chunks", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--blocking", action="store_true",
                    help="run the stop-the-world refill baseline engine")
    ap.add_argument("--paged", action="store_true",
                    help="slot-shared paged KV pool with radix prefix reuse")
    ap.add_argument("--page-size", type=int, default=16,
                    help="with --paged: tokens per pool page")
    ap.add_argument("--n-pages", type=int, default=0,
                    help="with --paged: pool pages (0 = dense-equivalent)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="shared system prompt length prepended to every "
                         "request (with --paged: radix prefix hits)")
    ap.add_argument("--mesh", default="",
                    help="shard engines over an AxB (data x model) mesh "
                         "and route a multi-tenant workload (see header)")
    ap.add_argument("--replicas", type=int, default=2,
                    help="with --mesh: engine replicas behind the router")
    ap.add_argument("--router", default="affine", choices=["affine", "rr"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = dataclasses.replace(reduced(get_config(args.arch)), remat="none")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(args.seed)
    if args.mesh:
        return run_mesh(args, cfg, params, rng)

    # the workload: variable-length prompts, several per slot (the blocking
    # baseline cannot take prompts longer than its bucket), optionally all
    # opening with the same system prompt
    shared = rng.integers(0, cfg.vocab_size, size=args.shared_prefix).tolist()
    hi = args.bucket if args.blocking else args.max_prompt
    lens = rng.integers(args.min_prompt, hi + 1, size=args.requests)
    prompts = [shared + rng.integers(0, cfg.vocab_size, size=n).tolist()
               for n in lens]
    # a "stop token" some sequences will happen to emit -> staggered finishes
    stop = int(rng.integers(0, cfg.vocab_size))

    par = ParallelContext(mesh=None) if args.host_kv_chunks else None
    kw = dict(slots=args.slots, bucket=args.bucket + args.shared_prefix,
              max_new_tokens=args.gen, segment=args.segment,
              n_host_chunks=args.host_kv_chunks,
              sampling=DL.SamplingConfig(temperature=args.temperature),
              stop_tokens=(stop,), par=par)
    if args.paged:
        from repro.runtime.paged import PagedServeEngine

        engine = PagedServeEngine(cfg, params, prefill_chunk=args.prefill_chunk,
                                  page_size=args.page_size,
                                  n_pages=args.n_pages, **kw)
        mode = f"paged pool (page_size={engine.page_size}, n_pages={engine.n_pages})"
    elif args.blocking:
        engine = DL.BlockingServeEngine(cfg, params, **kw)
        mode = "blocking baseline"
    else:
        engine = DL.ServeEngine(cfg, params, prefill_chunk=args.prefill_chunk,
                                **kw)
        mode = "fused scheduler"

    t0 = time.perf_counter()
    outs = engine.generate(prompts, key=jax.random.PRNGKey(args.seed))
    dt = time.perf_counter() - t0
    total = sum(len(o) for o in outs)
    print(f"[{mode}] {args.requests} requests (prompt {lens.min()}-{lens.max()}"
          f"{f' +{args.shared_prefix} shared' if args.shared_prefix else ''} "
          f"tokens) over {args.slots} slots, host-KV chunks={args.host_kv_chunks}: "
          f"{total} tokens in {dt*1e3:.0f} ms ({total/dt:.1f} tok/s incl. compile)")
    steps = engine.last_stats["steps"][1:]
    refill = [s["ms"] for s in steps if s["prefilling"]]
    steady = [s["ms"] for s in steps if not s["prefilling"]]
    if refill and steady:
        print(f"  dispatches: {len(steps) + 1} "
              f"({len(refill)} overlapped a refill); steady p50 "
              f"{np.percentile(steady, 50):.2f} ms vs refill-active p95 "
              f"{np.percentile(refill, 95):.2f} ms")
    if args.paged:
        st = engine.last_stats
        hit = st["prefix_hit_tokens"] / max(st["prompt_tokens"], 1)
        print(f"  prefix reuse: {st['prefix_hit_tokens']}/{st['prompt_tokens']} "
              f"prompt tokens served from shared pages ({hit:.0%} hit rate), "
              f"{st['prefilled_tokens']} prefilled, {st['cow_copies']} COW "
              f"copies, {st['deferrals']} deferrals")
        print(f"  page occupancy: peak {st['pages_peak']}/{engine.n_pages} "
              f"pages (page_size={engine.page_size}); {st['radix_pages']} "
              f"pages retained in the radix tree for future requests")
    for i, (n, o) in enumerate(zip(lens, outs)):
        fin = "stop" if o and o[-1] == stop else "budget"
        print(f"  req{i}: prompt={n + args.shared_prefix:<3d} "
              f"generated={len(o):<3d} [{fin}] {o[:8]}...")


def run_mesh(args, cfg, params, rng):
    """Multi-tenant workload over sharded replicas behind the router.

    One distinct system prompt per tenant (= per replica); the aggregate
    radix hit rate is the demo: affine keeps each tenant on one replica
    (hits on every repeat request), round-robin spreads a tenant's
    requests across replicas whose trees never saw its prefix."""
    from repro.launch.mesh import serve_mesh
    from repro.launch.router import ReplicaRouter
    from repro.runtime.paged import PagedServeEngine

    data, model = (int(x) for x in args.mesh.split("x"))
    per = data * model
    devs = jax.devices()
    npfx = max(args.shared_prefix, 2 * args.page_size)
    tenants = [rng.integers(0, cfg.vocab_size, size=npfx).tolist()
               for _ in range(max(2, args.replicas))]
    prompts, sessions = [], []
    for i in range(args.requests):
        t = i % len(tenants)
        n = int(rng.integers(args.min_prompt, args.bucket + 1))
        prompts.append(tenants[t] + rng.integers(0, cfg.vocab_size, size=n).tolist())
        sessions.append(f"tenant-{t}")
    # shuffled arrival order: round-robin cannot accidentally align with
    # the tenant cycle, so only real affinity preserves locality
    order = rng.permutation(len(prompts))
    prompts = [prompts[i] for i in order]
    sessions = [sessions[i] for i in order]

    class Replica:
        def __init__(self, r):
            self.par = serve_mesh(data, model,
                                  devices=devs[r * per:(r + 1) * per])
            with self.par.mesh:
                self.engine = PagedServeEngine(
                    cfg, params, par=self.par, slots=args.slots,
                    bucket=args.bucket + npfx, max_new_tokens=args.gen,
                    segment=args.segment, prefill_chunk=args.prefill_chunk,
                    page_size=args.page_size, n_pages=args.n_pages)

        def generate(self, ps):
            with self.par.mesh:
                return self.engine.generate(ps)

        @property
        def last_stats(self):
            return self.engine.last_stats

    router = ReplicaRouter([Replica(r) for r in range(args.replicas)],
                           policy=args.router)
    t0 = time.perf_counter()
    outs = router.generate(prompts, sessions)
    dt = time.perf_counter() - t0
    per_rep = router.last_stats["per_replica"]
    total = sum(len(o) for o in outs)
    print(f"[{args.replicas} x ({data}x{model}) mesh replicas, "
          f"router={args.router}] {len(tenants)} tenants, "
          f"{len(prompts)} shuffled requests: {total} tokens, "
          f"{dt*1e3:.0f} ms (incl. compile)")
    for rs in per_rep:
        pt = rs.get("prompt_tokens", 0)
        hit = rs.get("prefix_hit_tokens", 0)
        print(f"  replica {rs['replica']}: {rs['requests']} reqs, "
              f"{hit}/{pt} prompt tokens prefix-hit ({hit / pt:.0%})"
              if pt else f"  replica {rs['replica']}: idle")
    agg_pt = sum(rs.get("prompt_tokens", 0) for rs in per_rep)
    agg_hit = sum(rs.get("prefix_hit_tokens", 0) for rs in per_rep)
    print(f"  aggregate prefix-hit: {agg_hit}/{agg_pt} "
          f"({agg_hit / max(agg_pt, 1):.0%}) — each tenant's repeats only "
          f"hit a radix tree that already served it; rerun with "
          f"--router {'rr' if args.router == 'affine' else 'affine'} "
          f"to compare")


if __name__ == "__main__":
    main()
