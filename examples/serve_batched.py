"""Continuous-batching serving example: a mixed-length prompt workload run
through the fused mixed-step scheduler (`runtime/decode_loop.ServeEngine`)
— more prompts than slots, variable prompt lengths (including prompts
LONGER than the bucket: they just take more prefill chunks), staggered
finishes (random stop token), chunked prefill streaming into freed slots
*while the other slots keep decoding*, FPDT-style host-streamed KV.

  PYTHONPATH=src python examples/serve_batched.py --slots 4 --requests 10 \
      [--prefill-chunk 16] [--blocking]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core.parallel import ParallelContext
from repro.models import transformer as T
from repro.runtime import decode_loop as DL


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--slots", type=int, default=4, help="concurrent cache rows")
    ap.add_argument("--requests", type=int, default=10, help="queued prompts")
    ap.add_argument("--bucket", type=int, default=48,
                    help="capacity floor for prompt length (longer prompts "
                         "are still legal — they take more chunks)")
    ap.add_argument("--min-prompt", type=int, default=8)
    ap.add_argument("--max-prompt", type=int, default=72,
                    help="longest workload prompt (> bucket exercises "
                         "multi-chunk refill)")
    ap.add_argument("--gen", type=int, default=16, help="max new tokens per request")
    ap.add_argument("--segment", type=int, default=8, help="mixed steps per dispatch")
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="prompt tokens consumed per mixed step by a "
                         "refilling slot (0 = auto)")
    ap.add_argument("--host-kv-chunks", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--blocking", action="store_true",
                    help="run the stop-the-world refill baseline engine")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = dataclasses.replace(reduced(get_config(args.arch)), remat="none")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(args.seed)

    # the workload: variable-length prompts, several per slot; the blocking
    # baseline cannot take prompts longer than its bucket
    hi = args.bucket if args.blocking else args.max_prompt
    lens = rng.integers(args.min_prompt, hi + 1, size=args.requests)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).tolist() for n in lens]
    # a "stop token" some sequences will happen to emit -> staggered finishes
    stop = int(rng.integers(0, cfg.vocab_size))

    par = ParallelContext(mesh=None) if args.host_kv_chunks else None
    if args.blocking:
        engine = DL.BlockingServeEngine(
            cfg, params, slots=args.slots, bucket=args.bucket,
            max_new_tokens=args.gen, segment=args.segment,
            n_host_chunks=args.host_kv_chunks,
            sampling=DL.SamplingConfig(temperature=args.temperature),
            stop_tokens=(stop,), par=par)
    else:
        engine = DL.ServeEngine(
            cfg, params, slots=args.slots, bucket=args.bucket,
            max_new_tokens=args.gen, segment=args.segment,
            prefill_chunk=args.prefill_chunk,
            n_host_chunks=args.host_kv_chunks,
            sampling=DL.SamplingConfig(temperature=args.temperature),
            stop_tokens=(stop,), par=par)

    t0 = time.perf_counter()
    outs = engine.generate(prompts, key=jax.random.PRNGKey(args.seed))
    dt = time.perf_counter() - t0
    total = sum(len(o) for o in outs)
    mode = "blocking baseline" if args.blocking else "fused scheduler"
    print(f"[{mode}] {args.requests} requests (prompt {lens.min()}-{lens.max()} "
          f"tokens) over {args.slots} slots, host-KV chunks={args.host_kv_chunks}: "
          f"{total} tokens in {dt*1e3:.0f} ms ({total/dt:.1f} tok/s incl. compile)")
    steps = engine.last_stats["steps"][1:]
    refill = [s["ms"] for s in steps if s["prefilling"]]
    steady = [s["ms"] for s in steps if not s["prefilling"]]
    if refill and steady:
        print(f"  dispatches: {len(steps) + 1} "
              f"({len(refill)} overlapped a refill); steady p50 "
              f"{np.percentile(steady, 50):.2f} ms vs refill-active p95 "
              f"{np.percentile(refill, 95):.2f} ms")
    for i, (n, o) in enumerate(zip(lens, outs)):
        fin = "stop" if o and o[-1] == stop else "budget"
        print(f"  req{i}: prompt={n:<3d} generated={len(o):<3d} [{fin}] {o[:8]}...")


if __name__ == "__main__":
    main()
