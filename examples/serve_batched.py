"""Batched serving example: prefill a batch of prompts, then greedy-decode
with a jitted incremental step — including FPDT-style host-streamed KV.

  PYTHONPATH=src python examples/serve_batched.py --batch 4 --gen 16
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.core.parallel import ParallelContext
from repro.models import serve as SV
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--host-kv-chunks", type=int, default=4)
    args = ap.parse_args()

    cfg = dataclasses.replace(reduced(get_config(args.arch)), remat="none")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    max_len = args.prompt_len + args.gen
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size)

    t0 = time.perf_counter()
    logits, cache = SV.prefill_step(cfg, None, params, {"tokens": prompts}, max_len=max_len)
    jax.block_until_ready(logits)
    print(f"prefill: {args.batch} x {args.prompt_len} tokens in "
          f"{(time.perf_counter()-t0)*1e3:.0f} ms")

    par = ParallelContext(mesh=None)
    decode = jax.jit(lambda c, t, p: SV.decode_step(
        cfg, par, params, c, {"tokens": t}, p, n_host_chunks=args.host_kv_chunks))
    tok = jnp.argmax(logits[:, :cfg.vocab_size], -1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        logits, cache = decode(cache, out[-1], jnp.int32(args.prompt_len + i))
        out.append(jnp.argmax(logits[:, :cfg.vocab_size], -1)[:, None].astype(jnp.int32))
    jax.block_until_ready(out[-1])
    dt = time.perf_counter() - t0
    print(f"decode (host-streamed KV, {args.host_kv_chunks} chunks): "
          f"{args.gen-1} steps in {dt*1e3:.0f} ms ({dt/(args.gen-1)*1e3:.1f} ms/step)")
    seqs = jnp.concatenate(out, axis=1)
    for i in range(args.batch):
        print(f"  seq{i}: {seqs[i, :10].tolist()}...")


if __name__ == "__main__":
    main()
