"""Continuous-batching serving example: a mixed-length prompt workload run
through the scan-compiled decode engine (`runtime/decode_loop.ServeEngine`)
— more prompts than slots, variable prompt lengths (position-masked
prefill), staggered finishes (random stop token), slot reuse on completion,
FPDT-style host-streamed KV.

  PYTHONPATH=src python examples/serve_batched.py --slots 4 --requests 10
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core.parallel import ParallelContext
from repro.models import transformer as T
from repro.runtime import decode_loop as DL


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--slots", type=int, default=4, help="concurrent cache rows")
    ap.add_argument("--requests", type=int, default=10, help="queued prompts")
    ap.add_argument("--bucket", type=int, default=48, help="prompt-length bucket")
    ap.add_argument("--min-prompt", type=int, default=8)
    ap.add_argument("--gen", type=int, default=16, help="max new tokens per request")
    ap.add_argument("--segment", type=int, default=8, help="decode steps per scan segment")
    ap.add_argument("--host-kv-chunks", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = dataclasses.replace(reduced(get_config(args.arch)), remat="none")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(args.seed)

    # the workload: variable-length prompts, several per slot
    lens = rng.integers(args.min_prompt, args.bucket + 1, size=args.requests)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).tolist() for n in lens]
    # a "stop token" some sequences will happen to emit -> staggered finishes
    stop = int(rng.integers(0, cfg.vocab_size))

    par = ParallelContext(mesh=None) if args.host_kv_chunks else None
    engine = DL.ServeEngine(
        cfg, params, slots=args.slots, bucket=args.bucket,
        max_new_tokens=args.gen, segment=args.segment,
        n_host_chunks=args.host_kv_chunks,
        sampling=DL.SamplingConfig(temperature=args.temperature),
        stop_tokens=(stop,), par=par)

    t0 = time.perf_counter()
    outs = engine.generate(prompts, key=jax.random.PRNGKey(args.seed))
    dt = time.perf_counter() - t0
    total = sum(len(o) for o in outs)
    print(f"{args.requests} requests (prompt {lens.min()}-{lens.max()} tokens) "
          f"over {args.slots} slots, host-KV chunks={args.host_kv_chunks}: "
          f"{total} tokens in {dt*1e3:.0f} ms ({total/dt:.1f} tok/s incl. compile)")
    for i, (n, o) in enumerate(zip(lens, outs)):
        fin = "stop" if o and o[-1] == stop else "budget"
        print(f"  req{i}: prompt={n:<3d} generated={len(o):<3d} [{fin}] {o[:8]}...")


if __name__ == "__main__":
    main()
