"""Fault-tolerance demo: preemption + elastic resume + straggler detection.

1. Train with periodic async checkpoints, then simulate a preemption
   (SIGTERM) — a final blocking checkpoint is written.
2. Resume from the newest manifest and finish on the SAME loss trajectory.
3. Feed the heartbeat monitor an injected straggler and show the re-mesh
   alert a 1000-node launcher would act on.

  PYTHONPATH=src python examples/fault_tolerance_demo.py
"""
import os
import signal
import sys
import threading
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs import ShapeConfig, get_config, reduced
from repro.data.pipeline import CheckpointableIterator, make_batch_fn
from repro.models import transformer as T
from repro.optim import adamw
from repro.runtime.train_loop import (HeartbeatMonitor, StragglerAlert,
                                      TrainConfig, TrainLoop, make_train_step)


def main():
    ckpt_dir = "/tmp/ft_demo_ckpt"
    os.system(f"rm -rf {ckpt_dir}")
    cfg = dataclasses.replace(reduced(get_config("llama3.2-1b")), num_layers=2)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    oc = adamw.OptConfig(lr=1e-3, warmup_steps=2, total_steps=30)
    opt = adamw.init(oc, params)
    tc = TrainConfig(steps=30, ckpt_every=5, log_every=5)
    step_fn = jax.jit(make_train_step(cfg, None, oc, tc))
    bf = make_batch_fn(cfg, ShapeConfig("ft", 64, 2, "train"))
    put = lambda b: {k: jnp.asarray(v) for k, v in b.items()}
    mgr = CheckpointManager(ckpt_dir)

    # --- phase 1: train, then preempt mid-run
    loop = TrainLoop(cfg, None, oc, tc, step_fn, CheckpointableIterator(bf), mgr)
    killer = threading.Timer(6.0, lambda: os.kill(os.getpid(), signal.SIGTERM))
    killer.start()
    print("== phase 1: training until preemption (SIGTERM in ~6s) ==")
    p1, o1, reached = loop.run(params, opt, put_batch=put)
    killer.cancel()
    print(f"preempted at step {reached}; checkpoints on disk: {mgr.all_steps()}")
    assert mgr.latest_step() == reached  # final blocking save happened

    # --- phase 2: elastic resume from the newest manifest
    print("\n== phase 2: resume ==")
    restored, extra = mgr.restore(mgr.latest_step(), {"params": params, "opt": opt})
    loop2 = TrainLoop(cfg, None, oc, tc, step_fn, CheckpointableIterator(bf), mgr)
    p2, o2, end = loop2.run(restored["params"], restored["opt"],
                            start_step=extra["data_step"], put_batch=put)
    print(f"resumed from {extra['data_step']} and finished at step {end}")
    assert end == tc.steps

    # --- phase 3: straggler detection
    print("\n== phase 3: straggler detection ==")
    mon = HeartbeatMonitor(zscore=4.0, patience=2)
    try:
        for i in range(40):
            mon.record(0.10 + 0.001 * (i % 3))
        mon.record(2.5)  # injected slow host
        mon.record(2.5)
    except StragglerAlert as e:
        print(f"StragglerAlert raised -> launcher re-meshes: {e}")
    else:
        raise RuntimeError("straggler not detected")
    print("\nALL FT PHASES PASSED")


if __name__ == "__main__":
    main()
