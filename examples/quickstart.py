"""Quickstart: train a ~100M-parameter llama-style model end to end.

  PYTHONPATH=src python examples/quickstart.py                 # ~100 steps
  PYTHONPATH=src python examples/quickstart.py --steps 300 --batch 8 --seq 256

Uses the full production stack: config system, synthetic checkpointable data
pipeline, AdamW with cosine schedule, remat, chunked loss, async checkpoints.
On this 1-core CPU container the default (~100M params, batch 2, seq 128)
takes a few seconds per step; on real hardware scale the flags up.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs import ModelConfig, ShapeConfig
from repro.data.pipeline import CheckpointableIterator, make_batch_fn
from repro.models import transformer as T
from repro.optim import adamw
from repro.runtime.train_loop import TrainConfig, TrainLoop, make_train_step


def quickstart_config() -> ModelConfig:
    """~100M params (d=640, 10 layers, tied 32k vocab)."""
    return ModelConfig(
        name="quickstart-100m", family="dense", num_layers=10, d_model=640,
        num_heads=10, num_kv_heads=5, head_dim=64, d_ff=2560, vocab_size=32768,
        mlp_act="swiglu", norm="rmsnorm", tie_embeddings=True, remat="full",
        block_q=128, block_k=128,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/quickstart_ckpt")
    args = ap.parse_args()

    cfg = quickstart_config()
    print(f"model: {cfg.num_params()/1e6:.0f}M params")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    oc = adamw.OptConfig(lr=args.lr, warmup_steps=max(10, args.steps // 10),
                         total_steps=args.steps)
    opt = adamw.init(oc, params)
    tc = TrainConfig(steps=args.steps, ckpt_every=max(50, args.steps // 2),
                     log_every=10)
    step_fn = jax.jit(make_train_step(cfg, None, oc, tc), donate_argnums=(0, 1))
    data = CheckpointableIterator(
        make_batch_fn(cfg, ShapeConfig("quickstart", args.seq, args.batch, "train")))
    mgr = CheckpointManager(args.ckpt_dir)
    loop = TrainLoop(cfg, None, oc, tc, step_fn, data, mgr)
    loop.run(params, opt, put_batch=lambda b: {k: jnp.asarray(v) for k, v in b.items()})

    losses = [h["loss"] for h in loop.history]
    n = max(1, len(losses) // 10)
    first, last = sum(losses[:n]) / n, sum(losses[-n:]) / n
    print(f"\nloss: {first:.3f} -> {last:.3f} "
          f"({'OK: decreasing' if last < first else 'WARN: not decreasing'})")
    print(f"checkpoints: {mgr.all_steps()} in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
