"""Paper Fig. 14: FPDT is a pure systems optimization — training curves with
and without chunking+offload coincide.

Trains a tiny GPT three ways on identical data (baseline / FPDT-chunked /
FPDT-chunked+offload) and prints the loss curves + max divergence.

  PYTHONPATH=src python examples/convergence_fpdt.py --steps 40
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced, ShapeConfig
from repro.data.pipeline import make_batch_fn
from repro.models import transformer as T
from repro.optim import adamw


def run(cfg, steps, batch_fn):
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    oc = adamw.OptConfig(lr=1e-3, warmup_steps=5, total_steps=steps)
    opt = adamw.init(oc, params)

    @jax.jit
    def step(p, o, b):
        (l, m), g = jax.value_and_grad(lambda p: T.loss_fn(cfg, None, p, b),
                                       has_aux=True)(p)
        p, o, _ = adamw.apply(oc, p, g, o)
        return p, o, l

    losses = []
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in batch_fn(i).items()}
        params, opt, l = step(params, opt, b)
        losses.append(float(l))
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    args = ap.parse_args()
    base = dataclasses.replace(reduced(get_config("gpt-2.7b")), num_layers=2,
                               block_q=16, block_k=16)
    batch_fn = make_batch_fn(base, ShapeConfig("conv", 64, 4, "train"))
    curves = {}
    for name, u, off in (("baseline", 1, False), ("fpdt-u4", 4, False),
                         ("fpdt-u4-offload", 4, True)):
        cfg = dataclasses.replace(base, fpdt_chunks=u, fpdt_offload=off)
        curves[name] = run(cfg, args.steps, batch_fn)
        print(f"{name:18s} " + " ".join(f"{l:.3f}" for l in curves[name][:: max(1, args.steps // 8)]))
    ref = np.asarray(curves["baseline"])
    for name, c in curves.items():
        dev = np.max(np.abs(np.asarray(c) - ref))
        print(f"max |loss - baseline| for {name}: {dev:.5f}")
        assert dev < 5e-3, name
    print("\ncurves coincide -> FPDT does not change optimization (Fig 14).")


if __name__ == "__main__":
    main()
