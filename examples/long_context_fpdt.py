"""FPDT long-context demo: sequence-chunked + host-offloaded attention on a
(2 data x 4 model) mesh of 8 CPU devices.

Trains the same batch with (a) plain Ulysses (u=1) and (b) FPDT u=4 with KV
offload, verifying the losses/gradients agree (FPDT is exact — paper Fig 14)
and reporting per-variant compiled temp memory.

  PYTHONPATH=src python examples/long_context_fpdt.py [--seq 4096]
"""
import argparse
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core.parallel import ParallelContext
from repro.launch.mesh import make_compat_mesh
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--seq", type=int, default=2048)
    ap.add_argument("--chunks", type=int, default=4)
    args = ap.parse_args()

    mesh = make_compat_mesh((2, 4), ("data", "model"))
    par = ParallelContext(mesh=mesh, dp_axes=("data",), attn_impl="pallas")
    base = dataclasses.replace(reduced(get_config("llama3.2-1b")),
                               num_layers=4, block_q=256, block_k=256)
    key = jax.random.PRNGKey(0)
    params = T.init_params(base, key)
    batch = {
        "tokens": jax.random.randint(key, (2, args.seq), 0, base.vocab_size),
        "labels": jax.random.randint(key, (2, args.seq), 0, base.vocab_size),
    }

    results = {}
    for name, u, off in (("ulysses-baseline", 1, False),
                         (f"fpdt-u{args.chunks}-offload", args.chunks, True)):
        cfg = dataclasses.replace(base, fpdt_chunks=u, fpdt_offload=off,
                                  mlp_chunks=2 * u if u > 1 else 1)

        def step(p, b):
            (l, m), g = jax.value_and_grad(
                lambda p: T.loss_fn(cfg, par, p, b), has_aux=True)(p)
            gn = sum(jnp.sum(jnp.abs(x.astype(jnp.float32))) for x in jax.tree.leaves(g))
            return l, gn

        with mesh:
            jf = jax.jit(step)
            comp = jf.lower(params, batch).compile()
            loss, gnorm = jf(params, batch)
        ma = comp.memory_analysis()
        results[name] = (float(loss), float(gnorm), ma.temp_size_in_bytes / 2**20)
        print(f"{name:24s} loss={float(loss):.5f} |grad|={float(gnorm):.2f} "
              f"temp={ma.temp_size_in_bytes/2**20:.0f} MiB")

    (l0, g0, _), (l1, g1, _) = results.values()
    np.testing.assert_allclose(l0, l1, rtol=1e-4)
    np.testing.assert_allclose(g0, g1, rtol=1e-3)
    print("\nFPDT == baseline (loss and grad norm) — pure systems optimization, "
          "as the paper's Fig 14 claims.")


if __name__ == "__main__":
    main()
