"""FPDT chunk-pipeline correctness: u>1 (+offload) == u=1 baseline, for
outputs AND gradients — the paper's central exactness claim (it is a pure
systems optimization, Fig. 14)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import fpdt
from repro.core.parallel import ParallelContext
from repro.models import layers as L


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(reduced(get_config("llama3.2-1b")), param_dtype="float32")
    key = jax.random.PRNGKey(1)
    p = L.init_attn(cfg, key, jnp.float32)
    b, S = 2, 64
    x = jax.random.normal(jax.random.fold_in(key, 1), (b, S, cfg.d_model), jnp.float32)
    do = jax.random.normal(jax.random.fold_in(key, 2), (b, S, cfg.q_dim), jnp.float32)
    return cfg, p, x, do


def _run(cfg, p, x, do, u, offload, impl="pallas", window=0):
    c = dataclasses.replace(cfg, fpdt_chunks=u, fpdt_offload=offload, block_q=16, block_k=16)
    par = ParallelContext(mesh=None, attn_impl=impl)

    def f(x, p):
        o = fpdt.fpdt_attention(c, par, p, x, kind="local", window=window)
        return (o * do).sum(), o

    (val, o), grads = jax.value_and_grad(f, argnums=(0, 1), has_aux=True)(x, p)
    return o, grads


@pytest.mark.parametrize("u,offload,impl", [
    (2, False, "pallas"), (4, False, "pallas"), (4, True, "pallas"),
    (4, True, "xla_flash"), (8, True, "pallas"),
])
def test_fpdt_equals_baseline(setup, u, offload, impl):
    cfg, p, x, do = setup
    o1, g1 = _run(cfg, p, x, do, 1, False)
    o, g = _run(cfg, p, x, do, u, offload, impl)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o1), rtol=2e-4, atol=2e-4)
    for a, b_ in zip(jax.tree.leaves(g), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("window", [8, 24])
def test_fpdt_windowed(setup, window):
    cfg, p, x, do = setup
    o1, g1 = _run(cfg, p, x, do, 1, False, window=window)
    o, g = _run(cfg, p, x, do, 4, True, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o1), rtol=2e-4, atol=2e-4)
    for a, b_ in zip(jax.tree.leaves(g), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=5e-4, atol=5e-4)


def test_qkv_bias_grads(setup):
    cfg, _, x, do = setup
    cfg = dataclasses.replace(cfg, qkv_bias=True)
    p = L.init_attn(cfg, jax.random.PRNGKey(3), jnp.float32)
    p = {k: (v + 0.01 if k.startswith("b") else v) for k, v in p.items()}
    o1, g1 = _run(cfg, p, x, do, 1, False)
    o, g = _run(cfg, p, x, do, 4, True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o1), rtol=2e-4, atol=2e-4)
    assert {"bq", "bk", "bv"} <= set(g[1].keys())
    assert float(jnp.abs(g[1]["bq"]).sum()) > 0  # bias grads flow
    for a, b_ in zip(jax.tree.leaves(g), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=5e-4, atol=5e-4)
