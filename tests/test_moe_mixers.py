"""MoE routing invariants + mamba/rglru mixers vs naive recurrences."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import mamba as M
from repro.models import moe as MOE
from repro.models import rglru as R


@pytest.fixture(scope="module")
def moe_cfg():
    return dataclasses.replace(
        reduced(get_config("granite-moe-1b-a400m")), param_dtype="float32",
        moe_capacity_factor=100.0,
    )


def test_moe_matches_dense_weighted_sum(moe_cfg, rng):
    """Dropless dispatch == explicit per-token weighted expert sum."""
    cfg = moe_cfg
    p = MOE.init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 8, cfg.d_model)), jnp.float32)
    y, aux = MOE.moe_ffn(cfg, p, x)

    xt = x.reshape(-1, cfg.d_model)
    gates = jax.nn.softmax(xt @ p["router"], axis=-1)
    topv, topi = jax.lax.top_k(gates, cfg.experts_per_token)
    topv = topv / topv.sum(-1, keepdims=True)
    want = np.zeros_like(np.asarray(xt))
    for t in range(xt.shape[0]):
        for c in range(cfg.experts_per_token):
            e = int(topi[t, c])
            h = jax.nn.silu(xt[t] @ p["wg"][e]) * (xt[t] @ p["wu"][e])
            want[t] += float(topv[t, c]) * np.asarray(h @ p["wd"][e])
    np.testing.assert_allclose(np.asarray(y.reshape(-1, cfg.d_model)), want,
                               rtol=1e-4, atol=1e-4)
    assert float(aux) > 0


def test_moe_capacity_drops(moe_cfg, rng):
    """With capacity factor ~1, overloaded experts drop tokens (mass<=1)."""
    cfg = dataclasses.replace(moe_cfg, moe_capacity_factor=1.0)
    p = MOE.init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    # adversarial input: all tokens identical -> same expert choice
    x = jnp.ones((1, 16, cfg.d_model), jnp.float32)
    y, _ = MOE.moe_ffn(cfg, p, x)
    assert np.isfinite(np.asarray(y)).all()


def test_moe_chunked_equals_unchunked(moe_cfg, rng):
    cfg = moe_cfg
    p = MOE.init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)), jnp.float32)
    y1, _ = MOE.moe_ffn(cfg, p, x)
    y2, _ = MOE.moe_ffn_chunked(cfg, p, x, 4)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y1), rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------ mamba
def test_mamba_scan_matches_stepwise(rng):
    cfg = dataclasses.replace(reduced(get_config("falcon-mamba-7b")),
                              param_dtype="float32")
    p = M.init_mamba(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jnp.asarray(rng.standard_normal((1, 12, cfg.d_model)) * 0.3, jnp.float32)
    y_seq, st = M.mamba_mixer(cfg, p, x)
    # stepwise decode path must reproduce the sequence output
    state = {
        "conv": jnp.zeros((1, cfg.d_conv - 1, cfg.d_inner), jnp.float32),
        "ssm": jnp.zeros((1, cfg.d_inner, cfg.ssm_state), jnp.float32),
    }
    outs = []
    for t in range(12):
        yt, state = M.mamba_decode_step(cfg, p, x[:, t:t + 1], state)
        outs.append(yt)
    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_seq), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state["ssm"]), np.asarray(st["ssm"]),
                               rtol=2e-4, atol=2e-4)


def test_mamba_block_s_invariance(rng):
    """Chunked scan (FPDT boundary) is exact for any block size."""
    cfg = dataclasses.replace(reduced(get_config("falcon-mamba-7b")), param_dtype="float32")
    p = M.init_mamba(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jnp.asarray(rng.standard_normal((1, 16, cfg.d_model)) * 0.3, jnp.float32)
    xc = jax.nn.silu(jnp.asarray(rng.standard_normal((1, 16, cfg.d_inner)), jnp.float32))
    dt = jax.nn.softplus(jnp.asarray(rng.standard_normal((1, 16, cfg.d_inner)), jnp.float32))
    B = jnp.asarray(rng.standard_normal((1, 16, cfg.ssm_state)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((1, 16, cfg.ssm_state)), jnp.float32)
    outs = [np.asarray(M.selective_scan(xc, dt, p["A_log"], B, C, block_s=bs)[0])
            for bs in (1, 2, 4, 8, 16)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------------ rglru
def test_rglru_matches_stepwise(rng):
    cfg = dataclasses.replace(reduced(get_config("recurrentgemma-9b")), param_dtype="float32")
    p = R.init_rglru(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jnp.asarray(rng.standard_normal((1, 10, cfg.d_model)) * 0.3, jnp.float32)
    y_seq, st = R.rglru_mixer(cfg, p, x, scan_impl="xla")
    state = {
        "conv": jnp.zeros((1, cfg.d_conv - 1, cfg.d_inner), jnp.float32),
        "h": jnp.zeros((1, cfg.d_inner), jnp.float32),
    }
    outs = []
    for t in range(10):
        yt, state = R.rglru_decode_step(cfg, p, x[:, t:t + 1], state)
        outs.append(yt)
    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_seq), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state["h"]), np.asarray(st["h"]), rtol=2e-4, atol=2e-4)


def test_rglru_stability(rng):
    """|a| < 1 by construction: long inputs stay bounded."""
    cfg = dataclasses.replace(reduced(get_config("recurrentgemma-9b")), param_dtype="float32")
    p = R.init_rglru(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jnp.asarray(rng.standard_normal((1, 256, cfg.d_model)), jnp.float32)
    y, _ = R.rglru_mixer(cfg, p, x, scan_impl="xla")
    assert np.isfinite(np.asarray(y)).all()
    assert float(jnp.abs(y).max()) < 1e3
