"""SLO-aware scheduler (`runtime/paged.py::SLOPagedServeEngine`):
preemption by page spill/publish is LOSSLESS (preempted-then-resumed ==
uninterrupted solo, token for token), prefill-budget pauses and the FIFO
baseline preserve outputs, recurrent layouts are refused with a reason,
no request starves under sustained deferral/preemption pressure, and the
compiled-program set stays bounded across preempt/resume cycles."""
import dataclasses
import functools

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.parallel import ParallelContext
from repro.models import transformer as T
from repro.runtime import decode_loop as DL
from repro.runtime import paged as PG


@functools.lru_cache(maxsize=2)
def setup(name):
    cfg = dataclasses.replace(reduced(get_config(name)), param_dtype="float32",
                              remat="none")
    return cfg, T.init_params(cfg, jax.random.PRNGKey(0))


def pool_kw(pool):
    """Engine kwargs per pool placement: on-device vs host-streamed."""
    if pool == "host":
        return dict(n_host_chunks=2, par=ParallelContext(mesh=None))
    return {}


def prompts_for(cfg, seed=0):
    rng = np.random.default_rng(seed)
    V = cfg.vocab_size
    return ([int(t) for t in rng.integers(0, V, 13)],
            [int(t) for t in rng.integers(0, V, 5)])


def solo_ref(cfg, params, prompt, *, gen=8, bucket=16, **kw):
    """Uninterrupted single-request run on a FRESH engine of the same
    class/config — the parity reference for preempted runs."""
    eng = PG.SLOPagedServeEngine(cfg, params, slots=1, bucket=bucket,
                                 max_new_tokens=gen, page_size=4, segment=1,
                                 **kw)
    return eng.generate([prompt])[0]


@pytest.mark.parametrize("pool", ["device", "host"])
def test_preempt_resume_matches_solo(pool):
    """A decoding low-priority request preempted by a high-priority
    arrival (pages published to the radix tree, slot released, later
    re-admitted with its remaining budget) emits exactly the tokens an
    uninterrupted solo run emits — over the on-device AND the
    host-streamed pool."""
    cfg, params = setup("llama3.2-1b")
    long_p, short_p = prompts_for(cfg)
    kw = pool_kw(pool)
    ref_long = solo_ref(cfg, params, long_p, **kw)
    ref_short = solo_ref(cfg, params, short_p, **kw)
    eng = PG.SLOPagedServeEngine(cfg, params, slots=1, bucket=16,
                                 max_new_tokens=8, page_size=4, segment=1,
                                 spill_pages=8, **kw)
    out = eng.generate([
        DL.Request(tokens=tuple(long_p), priority=1, arrival=0),
        DL.Request(tokens=tuple(short_p), priority=0, arrival=6)])
    st = eng.last_stats
    assert st["preemptions"] >= 1, "scenario must actually preempt"
    assert out[0] == ref_long
    assert out[1] == ref_short
    # the preempted request's record names its disruption
    assert st["requests"][0]["preemptions"] >= 1
    assert st["requests"][1]["preemptions"] == 0


def test_preempt_mid_prefill_matches_solo():
    """Preempting a slot that is still PREFILLING publishes the pages of
    the already-computed prefix, so the resume radix-matches them back
    instead of restarting from token 0 — and output parity still holds."""
    cfg, params = setup("llama3.2-1b")
    rng = np.random.default_rng(3)
    long_p = [int(t) for t in rng.integers(0, cfg.vocab_size, 30)]
    _, short_p = prompts_for(cfg)
    ref_long = solo_ref(cfg, params, long_p, bucket=40, prefill_chunk=4)
    ref_short = solo_ref(cfg, params, short_p, bucket=40, prefill_chunk=4)
    eng = PG.SLOPagedServeEngine(cfg, params, slots=1, bucket=40,
                                 max_new_tokens=8, page_size=4, segment=1,
                                 prefill_chunk=4, spill_pages=16)
    out = eng.generate([
        DL.Request(tokens=tuple(long_p), priority=1, arrival=0),
        DL.Request(tokens=tuple(short_p), priority=0, arrival=3)])
    st = eng.last_stats
    assert st["preemptions"] >= 1
    assert st["prefix_hit_tokens"] > 0, \
        "resume must reuse the published partial prefill"
    assert out == [ref_long, ref_short]


def test_prefill_budget_pause_parity():
    """A long prefill that exhausts its chunk budget pauses (table row
    parked on the trash page, mode FREE) while a co-resident decode runs,
    then resumes — outputs identical to unbudgeted solo runs."""
    cfg, params = setup("llama3.2-1b")
    rng = np.random.default_rng(4)
    long_p = [int(t) for t in rng.integers(0, cfg.vocab_size, 25)]
    _, short_p = prompts_for(cfg)
    ref_long = solo_ref(cfg, params, long_p, bucket=32, prefill_chunk=4)
    ref_short = solo_ref(cfg, params, short_p, bucket=32, prefill_chunk=4)
    eng = PG.SLOPagedServeEngine(cfg, params, slots=2, bucket=32,
                                 max_new_tokens=8, page_size=4, segment=1,
                                 prefill_chunk=4, prefill_budget=1)
    out = eng.generate([
        DL.Request(tokens=tuple(short_p), priority=0, arrival=0),
        DL.Request(tokens=tuple(long_p), priority=1, arrival=1)])
    assert eng.last_stats["prefill_pauses"] >= 1
    assert out == [ref_short, ref_long]


def test_fifo_and_slo_policies_emit_identical_outputs():
    """Same requests, both policies, fresh engines: scheduling changes
    WHEN tokens appear, never WHICH tokens appear (greedy sampling)."""
    cfg, params = setup("llama3.2-1b")
    long_p, short_p = prompts_for(cfg)
    reqs = [DL.Request(tokens=tuple(long_p), priority=1, arrival=0),
            DL.Request(tokens=tuple(short_p), priority=0, arrival=6),
            DL.Request(tokens=tuple(short_p[::-1]), priority=0, arrival=7)]
    outs, stats = {}, {}
    for policy in ("fifo", "slo"):
        eng = PG.SLOPagedServeEngine(cfg, params, slots=1, bucket=16,
                                     max_new_tokens=8, page_size=4,
                                     segment=1, spill_pages=8, policy=policy)
        outs[policy] = eng.generate(reqs)
        stats[policy] = eng.last_stats
    assert outs["fifo"] == outs["slo"]
    assert stats["fifo"]["preemptions"] == 0
    assert stats["slo"]["preemptions"] >= 1


def test_raw_prompts_still_accepted():
    """Plain token lists coerce to default-QoS Requests — the engine is a
    drop-in PagedServeEngine replacement for existing callers."""
    cfg, params = setup("llama3.2-1b")
    long_p, short_p = prompts_for(cfg)
    eng = PG.SLOPagedServeEngine(cfg, params, slots=2, bucket=16,
                                 max_new_tokens=4, page_size=4, segment=1)
    base = PG.PagedServeEngine(cfg, params, slots=2, bucket=16,
                               max_new_tokens=4, page_size=4, segment=1)
    assert eng.generate([long_p, short_p]) == base.generate([long_p, short_p])


@pytest.mark.parametrize("name", ["falcon-mamba-7b", "recurrentgemma-9b"])
def test_recurrent_layouts_refused(name):
    """ssm/rglru layouts integrate the prefix into per-slot state a mapped
    page cannot restore: the SLO engine must refuse, naming the reason
    (the carried ROADMAP item), not silently corrupt resumed outputs."""
    cfg, params = setup(name)
    with pytest.raises(ValueError, match="recurrent"):
        PG.SLOPagedServeEngine(cfg, params, slots=1, bucket=8,
                               max_new_tokens=2, page_size=4)


def test_radix_disabled_refused():
    cfg, params = setup("llama3.2-1b")
    with pytest.raises(ValueError, match="radix"):
        PG.SLOPagedServeEngine(cfg, params, slots=1, bucket=8,
                               max_new_tokens=2, page_size=4, radix=False)


def test_bad_policy_refused():
    cfg, params = setup("llama3.2-1b")
    with pytest.raises(ValueError, match="policy"):
        PG.SLOPagedServeEngine(cfg, params, slots=1, bucket=8,
                               max_new_tokens=2, page_size=4, policy="lifo")


def test_no_starvation_under_pressure():
    """Sustained high-priority arrivals over a pool too small to hold
    everyone: low-priority requests are deferred and preempted, but every
    admitted request still runs to completion (full budget emitted)."""
    cfg, params = setup("llama3.2-1b")
    rng = np.random.default_rng(5)
    V = cfg.vocab_size
    gen = 6
    reqs = []
    for i in range(2):  # long low-priority background work, arrives first
        p = tuple(int(t) for t in rng.integers(0, V, 14))
        reqs.append(DL.Request(tokens=p, priority=1, arrival=0))
    for i in range(6):  # a drumbeat of short high-priority requests
        p = tuple(int(t) for t in rng.integers(0, V, 4))
        reqs.append(DL.Request(tokens=p, priority=0, arrival=2 + 3 * i))
    # n_pages sized for ~2 resident requests: admissions must defer
    eng = PG.SLOPagedServeEngine(cfg, params, slots=2, bucket=20,
                                 max_new_tokens=gen, page_size=4, segment=1,
                                 n_pages=14, spill_pages=16)
    out = eng.generate(reqs)
    st = eng.last_stats
    assert st["preemptions"] >= 1, "pressure scenario must preempt"
    assert all(len(o) == gen for o in out), \
        f"every request must complete its budget: {[len(o) for o in out]}"
    assert all(r["first_emit"] is not None for r in st["requests"])


def test_failover_rehomes_qos_to_slo_engine():
    """Satellite of the failover PR: router failover delivers Request
    objects (priority, arrival, session, deadline) to a surviving
    SLOPagedServeEngine INTACT — the survivor's scheduler still preempts
    the low-priority request for the high-priority arrival, and every
    output matches an uninterrupted solo run token for token."""
    from repro.launch.faults import Fault, FaultyReplica
    from repro.launch.router import ReplicaRouter

    cfg, params = setup("llama3.2-1b")
    long_p, short_p = prompts_for(cfg)
    ref_long = solo_ref(cfg, params, long_p)
    ref_short = solo_ref(cfg, params, short_p)

    def engine():
        return PG.SLOPagedServeEngine(cfg, params, slots=1, bucket=16,
                                      max_new_tokens=8, page_size=4,
                                      segment=1, spill_pages=8)

    # one session => one home => the whole QoS scenario re-homes together
    reqs = [DL.Request(tokens=tuple(long_p), priority=1, arrival=0,
                       session="tenant-A"),
            DL.Request(tokens=tuple(short_p), priority=0, arrival=6,
                       itl_slo=8.0, session="tenant-A")]
    engines = [engine(), engine()]
    rt = ReplicaRouter(engines, max_retries=0, warn=lambda m: None)
    victim = rt.home_of(reqs[0], "tenant-A")
    rt.replicas[victim] = FaultyReplica(engines[victim],
                                        [Fault("raise", 0)])
    out = rt.generate(reqs)
    fo = rt.last_stats["failover"]
    assert fo["deaths"] == 1
    assert fo["rehomed_requests"] == 2 and fo["rehomed_sessions"] == 1
    survivor = engines[1 - victim]
    assert survivor.last_stats["preemptions"] >= 1, \
        "re-homed QoS must still drive the survivor's scheduler"
    assert out == [ref_long, ref_short]


@pytest.mark.slow
def test_preempt_resume_program_set():
    """The CI bounded-program gate: the full FIFO-vs-SLO bench workload —
    preemptions, pauses, spill promotes and all — compiles NOTHING after
    warm-up, and the set stays {segment, reset, copy, promote} x 1."""
    import os
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks import serve_bench as SB

    r = SB.slo_workload()
    assert r["slo"]["preemptions"] >= 1
    assert r["outputs_match"]
    for policy in ("fifo", "slo"):
        assert r[policy]["programs"] == r[policy]["programs_before"], \
            f"{policy}: measured run compiled new programs"
        assert set(r[policy]["programs"]) == {"segment", "reset", "copy",
                                              "promote"}
        assert all(v == 1 for v in r[policy]["programs"].values())
    assert r["slo"]["goodput"] >= r["fifo"]["goodput"]
