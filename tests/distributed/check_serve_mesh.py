"""Sharded serve-engine parity on a forced multi-device host platform.

Spawned by tests/test_serve_mesh.py (the main pytest process keeps a single
visible device).  Builds the (2 data, 4 model) serve mesh out of 8 fake CPU
devices and asserts that a mesh-sharded ``PagedServeEngine`` — paged pool
kv-heads over ``model`` per ``cache_shardings``, segment jit carrying
``in_shardings``/``out_shardings`` — reproduces the single-device engine:

  * llama hkv=4: heads divide the model axis -> head-sharded pool; direct
    ``chunk_step``/``decode_step`` logits parity at 1e-5 AND engine token
    parity, including a SECOND generate that must land as a radix prefix
    hit on both engines;
  * llama hkv=2: heads do NOT divide sp=4 -> in-page sequence fallback
    (page_size % 4 == 0), token parity;
  * ssm (falcon-mamba): recurrent per-slot state on the mesh, token
    parity (paged pool degrades to per-slot dense state there);
  * dense (non-paged) ServeEngine on the mesh, token parity;
  * llama ps=4 + host streaming: page_size does NOT divide the 8-device
    mesh, so the pool-offload placement falls back to the kv-head dim;
  * llama-spill: a tight pool demotes radix pages to the spill tier and
    re-serves them through the sharded promote scatter, matching the
    single-device oracle across the whole three-workload sequence.

Every engine must still report exactly its bounded program set after a
full workload.  Exits nonzero on any mismatch; prints the marker line on
success.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

from repro.configs import get_config, reduced
from repro.launch.mesh import serve_mesh
from repro.models import serve as SV
from repro.models import transformer as T
from repro.runtime import decode_loop as DL
from repro.runtime.paged import PagedServeEngine


def make_cfg(arch, **over):
    cfg = dataclasses.replace(reduced(get_config(arch)), param_dtype="float32",
                              remat="none")
    return dataclasses.replace(cfg, **over) if over else cfg


def prompts_for(cfg, seed=0):
    """Two shared-prefix + one distinct prompt, all short (CPU GSPMD)."""
    rng = np.random.default_rng(seed)
    shared = rng.integers(2, cfg.vocab_size - 1, 12).tolist()
    a = shared + rng.integers(2, cfg.vocab_size - 1, 4).tolist()
    b = shared + rng.integers(2, cfg.vocab_size - 1, 4).tolist()
    c = rng.integers(2, cfg.vocab_size - 1, 9).tolist()
    return [a, b, c]


def step_parity(cfg, params, par):
    """Direct sharded-vs-oracle logits parity for the paged step programs
    (tighter than token parity: 1e-5 on raw logits)."""
    ps, n_pages, slots = 8, 8, 2
    cache0 = SV.init_paged_cache(cfg, slots, n_pages, ps)
    table = jnp.array([[0, 1, -1], [2, 3, -1]], jnp.int32)
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(2, cfg.vocab_size - 1, (slots, ps)),
                       jnp.int32)
    off = jnp.zeros(slots, jnp.int32)
    live = jnp.full(slots, ps, jnp.int32)

    def run(par_):
        lg, cache = SV.chunk_step(cfg, par_, params, cache0, toks, off, live,
                                  table=table)
        lg2, _ = SV.decode_step(cfg, par_, params, cache,
                                {"tokens": jnp.argmax(lg, -1, keepdims=True)},
                                jnp.full(slots, ps, jnp.int32), table=table)
        return jax.device_get(lg), jax.device_get(lg2)

    lg0, lg20 = run(None)
    with par.mesh:
        lg1, lg21 = run(par)
    np.testing.assert_allclose(lg1, lg0, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(lg21, lg20, rtol=1e-5, atol=1e-5)


def engine_parity(arch, name, *, paged=True, n_host_chunks=0, page_size=8,
                  n_pages=24, spill_pages=0, **over):
    cfg = make_cfg(arch, **over)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    par = serve_mesh(2, 4)
    kw = dict(slots=2, bucket=16, max_new_tokens=4, prefill_chunk=8,
              segment=2, n_host_chunks=n_host_chunks)
    pkw = (dict(kw, page_size=page_size, n_pages=n_pages,
                spill_pages=spill_pages) if paged else kw)
    Eng = PagedServeEngine if paged else DL.ServeEngine
    prompts = prompts_for(cfg)

    e0 = Eng(cfg, params, **pkw)  # single-device oracle
    want = e0.generate(prompts)

    with par.mesh:
        e1 = Eng(cfg, params, par=par, **pkw)
        got = e1.generate(prompts)
        assert got == want, f"{name}: sharded tokens diverge\n{got}\n{want}"
        if paged and e1.radix_enabled:
            got2 = e1.generate(prompts)
            hit = e1.last_stats["prefix_hit_tokens"]
            assert hit > 0, f"{name}: second run should radix-hit"
            want2 = e0.generate(prompts)
            assert got2 == want2, f"{name}: post-radix-hit tokens diverge"
        progs = e1.compiled_programs()
        expect = ({"segment", "reset", "copy", "promote"} if paged
                  else {"segment", "reset"})
        # bounded set: each program compiled AT MOST once (copy/promote
        # stay 0 when no COW / spill re-admit fired, e.g. radix-disabled
        # recurrent layouts)
        assert set(progs) == expect and all(v <= 1 for v in progs.values()) \
            and progs["segment"] == 1 and progs["reset"] == 1, \
            f"{name}: program set grew: {progs}"

    if paged and arch.startswith("llama"):
        with par.mesh:
            step_parity(cfg, params, par)
    print(f"OK {name}")


def spill_parity():
    """Demote -> promote round-trip on the mesh: a tight pool forces LRU
    radix pages into the spill tier, re-serving the original prompts
    promotes them back through the sharded ``promote_page`` scatter, and
    the whole three-workload sequence must match the single-device oracle
    token for token."""
    cfg = make_cfg("llama3.2-1b", num_heads=4, num_kv_heads=4)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    par = serve_mesh(2, 4)
    pkw = dict(slots=2, bucket=16, max_new_tokens=4, prefill_chunk=8,
               segment=2, page_size=8, n_pages=8, spill_pages=16)
    prompts = prompts_for(cfg)
    rng = np.random.default_rng(7)
    evictors = [rng.integers(2, cfg.vocab_size - 1, 16).tolist()
                for _ in range(3)]

    def run(eng):
        return (eng.generate(prompts), eng.generate(evictors),
                eng.generate(prompts))

    want = run(PagedServeEngine(cfg, params, **pkw))
    with par.mesh:
        e1 = PagedServeEngine(cfg, params, par=par, **pkw)
        got = run(e1)
        assert got == want, f"llama-spill: tokens diverge\n{got}\n{want}"
        st = e1.last_stats
        assert st["spill_promotes"] > 0, \
            f"llama-spill: expected promote-from-spill re-admissions: {st}"
        progs = e1.compiled_programs()
        assert progs["promote"] == 1 and all(v <= 1 for v in progs.values()), \
            f"llama-spill: program set grew: {progs}"
    print("OK llama-spill")


if __name__ == "__main__":
    assert jax.device_count() == 8, jax.device_count()
    # hkv=4 divides sp=4 -> pool kv-heads shard over the model axis
    engine_parity("llama3.2-1b", "llama-headshard", num_heads=4,
                  num_kv_heads=4)
    # hkv=2 does NOT divide sp=4 -> in-page sequence fallback (ps=8 % 4 == 0)
    engine_parity("llama3.2-1b", "llama-psfallback", num_heads=4,
                  num_kv_heads=2)
    # recurrent layout on the mesh (radix disabled by design there)
    engine_parity("falcon-mamba-7b", "ssm-paged")
    # dense engine path (no pool) also carries mesh shardings
    engine_parity("llama3.2-1b", "llama-dense", paged=False, num_heads=4,
                  num_kv_heads=4)
    # ps=4 does NOT divide the 8-device mesh while host-streaming: the
    # pool-offload spec must fall back to the kv-head dim (hkv=8 % 8 == 0)
    # instead of silently building a single-device sharding
    engine_parity("llama3.2-1b", "llama-psindiv-stream", num_heads=8,
                  num_kv_heads=8, page_size=4, n_pages=48, n_host_chunks=2)
    # demote/promote round-trip + persistence program bound on the mesh
    spill_parity()
    print("ALL SERVE MESH CHECKS PASSED")
