"""FPDT distribution-kind parity on a forced multi-device host platform.

Spawned by tests/test_fpdt_mesh.py (the main pytest process keeps a single
visible device).  Builds a (2 data, 4 model) mesh out of 8 fake CPU devices
and asserts, for the attention pipeline alone (fpdt_attention), that

  * kind="ulysses" (heads % sp == 0) and
  * kind="cp"      (heads % sp != 0 — chunk-streamed KV all-gather)

match the kind="local" single-device oracle on outputs AND grads (x and
every attention param), at u=1 (plain baseline) and u=4 (scan-compiled
chunk pipeline, offload requested), plus one unrolled u=4 cell so the
scan/unrolled differential also holds under GSPMD.  Exits nonzero on any
mismatch; prints the marker line on success.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

from repro.configs import get_config, reduced
from repro.core import fpdt
from repro.core.parallel import ParallelContext
from repro.launch.mesh import make_compat_mesh
from repro.models import layers as L

TOL = dict(rtol=2e-3, atol=2e-3)


def run(cfg, par, p, x, do, kind):
    def f(x, p):
        o = fpdt.fpdt_attention(cfg, par, p, x, kind=kind)
        return (o * do).sum(), o

    g = jax.value_and_grad(f, argnums=(0, 1), has_aux=True)
    if par is not None and par.mesh is not None:
        with par.mesh:
            (_, o), grads = jax.jit(g)(x, p)
    else:
        (_, o), grads = jax.jit(g)(x, p)
    return jax.device_get(o), jax.device_get(grads)


def check(kind, heads, kv_heads, u, offload, unroll=False):
    base = reduced(get_config("llama3.2-1b"))
    cfg = dataclasses.replace(
        base, param_dtype="float32", num_heads=heads, num_kv_heads=kv_heads,
        block_q=8, block_k=8, fpdt_chunks=u, fpdt_offload=offload,
        fpdt_unroll=unroll)
    key = jax.random.PRNGKey(0)
    p = L.init_attn(cfg, key, jnp.float32)
    b, S = 2, 64
    x = jax.random.normal(jax.random.fold_in(key, 1), (b, S, cfg.d_model), jnp.float32)
    do = jax.random.normal(jax.random.fold_in(key, 2), (b, S, cfg.q_dim), jnp.float32)

    # single-device oracle: un-chunked local attention
    cfg0 = dataclasses.replace(cfg, fpdt_chunks=1, fpdt_offload=False)
    o0, g0 = run(cfg0, ParallelContext(mesh=None, attn_impl="xla_flash"),
                 p, x, do, "local")

    mesh = make_compat_mesh((2, 4), ("data", "model"))
    par = ParallelContext(mesh=mesh, dp_axes=("data",), attn_impl="xla_flash")
    o1, g1 = run(cfg, par, p, x, do, kind)

    np.testing.assert_allclose(np.asarray(o1), np.asarray(o0), **TOL)
    for a, b_ in zip(jax.tree.leaves(g1), jax.tree.leaves(g0)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), **TOL)
    print(f"OK kind={kind} heads={heads}/{kv_heads} u={u} "
          f"offload={offload} unroll={unroll}")


if __name__ == "__main__":
    assert jax.device_count() == 8, jax.device_count()
    # ulysses: 4 q heads over sp=4; GQA kv=2 stays replicated over model
    check("ulysses", heads=4, kv_heads=2, u=1, offload=False)
    check("ulysses", heads=4, kv_heads=2, u=4, offload=True)
    # cp: 6 heads don't divide the model axis -> chunk-streamed KV all-gather
    check("cp", heads=6, kv_heads=6, u=1, offload=False)
    check("cp", heads=6, kv_heads=6, u=4, offload=True)
    # scan/unrolled differential also holds under GSPMD resharding
    check("ulysses", heads=4, kv_heads=2, u=4, offload=True, unroll=True)
    print("ALL FPDT MESH CHECKS PASSED")
