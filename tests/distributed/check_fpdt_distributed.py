"""Distributed FPDT correctness, run on 8 fake CPU devices.

Invoked as a subprocess by tests/test_distributed.py (so the main pytest
process keeps a single visible device).  Verifies, under a (2 data, 4 model)
mesh, that:
  * Ulysses-FPDT (u=1/u=4, offload on/off) matches the single-device oracle
    for the whole model loss AND parameter gradients;
  * CP-FPDT ditto (arch whose heads don't divide the model axis);
  * SSM / hybrid archs match single-device under the mesh.
Exits nonzero on any mismatch.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

from repro.configs import get_config, reduced
from repro.core.parallel import ParallelContext
from repro.launch.mesh import make_compat_mesh
from repro.models import transformer as T


def make_batch(cfg, key, b, s):
    batch = {}
    if cfg.frontend == "audio_frames":
        batch["frame_embeds"] = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)
        batch["labels"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    elif cfg.frontend == "vision_patches":
        st = s - cfg.num_patches
        batch["patch_embeds"] = jax.random.normal(key, (b, cfg.num_patches, cfg.d_model), jnp.float32)
        batch["tokens"] = jax.random.randint(key, (b, st), 0, cfg.vocab_size)
        batch["labels"] = jax.random.randint(key, (b, st), 0, cfg.vocab_size)
    else:
        batch["tokens"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
        batch["labels"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    return batch


def check(name, u, offload, heads=None, kv_heads=None, tol=2e-3):
    cfg = reduced(get_config(name))
    cfg = dataclasses.replace(
        cfg, param_dtype="float32", fpdt_chunks=u, fpdt_offload=offload,
        block_q=8, block_k=8, remat="full",
        **({"num_heads": heads} if heads else {}),
        **({"num_kv_heads": kv_heads} if kv_heads else {}),
    )
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    batch = make_batch(cfg, jax.random.PRNGKey(1), 2, 64)

    # single-device oracle (u=1, no chunking/offload)
    cfg0 = dataclasses.replace(cfg, fpdt_chunks=1, fpdt_offload=False)
    (l0, _), g0 = jax.value_and_grad(lambda p: T.loss_fn(cfg0, None, p, batch), has_aux=True)(params)

    mesh = make_compat_mesh((2, 4), ("data", "model"))
    par = ParallelContext(mesh=mesh, dp_axes=("data",), attn_impl="pallas")
    with mesh:
        jf = jax.jit(jax.value_and_grad(lambda p, b_: T.loss_fn(cfg, par, p, b_), has_aux=True))
        (l1, _), g1 = jf(params, batch)
    np.testing.assert_allclose(float(l0), float(l1), rtol=tol, atol=tol)
    r0, r1 = jax.tree.leaves(g0), jax.tree.leaves(g1)
    for a, b_ in zip(r0, r1):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b_, np.float32), rtol=5e-2, atol=5e-3
        )
    print(f"OK {name} u={u} offload={offload} loss={float(l1):.4f}")


if __name__ == "__main__":
    # ulysses: 8 heads % 4 == 0; GQA kv=2 -> replication x2
    check("llama3.2-1b", u=1, offload=False, heads=8, kv_heads=2)
    check("llama3.2-1b", u=4, offload=True, heads=8, kv_heads=2)
    # cp: 6 heads % 4 != 0
    check("qwen1.5-4b", u=4, offload=True, heads=6, kv_heads=6)
    # moe + ulysses-fpdt
    check("granite-moe-1b-a400m", u=2, offload=True, heads=8, kv_heads=4)
    # ssm (channel-sharded mixer)
    check("falcon-mamba-7b", u=1, offload=False)
    # hybrid rglru + local attn
    check("recurrentgemma-9b", u=2, offload=False, heads=8, kv_heads=1)
    print("ALL DISTRIBUTED CHECKS PASSED")
