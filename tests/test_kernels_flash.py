"""Pallas flash-attention kernels vs the pure-jnp oracle: shape/dtype sweep."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.online_softmax import SoftmaxState, finalize, lse
from repro.kernels.flash_attention import kernel as K
from repro.kernels.flash_attention import ops as O
from repro.kernels.flash_attention import ref as R


def _mk(rng, b, hq, hkv, sq, sk, d, dtype):
    q = jnp.asarray(rng.standard_normal((b, hq, sq, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, hkv, sk, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, hkv, sk, d)), dtype)
    return q, k, v


SWEEP = [
    # b, hq, hkv, sq, sk, d, block
    (1, 1, 1, 16, 16, 8, 8),
    (2, 4, 2, 32, 32, 16, 16),
    (1, 4, 1, 64, 64, 32, 16),   # MQA
    (1, 3, 3, 48, 48, 16, 16),   # odd head count, non-divisible block fit
    (2, 2, 2, 40, 24, 16, 8),    # sq != sk
]


@pytest.mark.parametrize("b,hq,hkv,sq,sk,d,blk", SWEEP)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fwd_matches_ref(rng, b, hq, hkv, sq, sk, d, blk, dtype):
    q, k, v = _mk(rng, b, hq, hkv, sq, sk, d, dtype)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    want = R.mha(*(x.astype(jnp.float32) for x in (q, k, v)), causal=True)
    got = O.flash_attention(q, k, v, impl="pallas", block_q=blk, block_k=blk)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want), rtol=tol, atol=tol)


@pytest.mark.parametrize("b,hq,hkv,sq,sk,d,blk", SWEEP[:3])
def test_bwd_matches_autodiff_ref(rng, b, hq, hkv, sq, sk, d, blk):
    q, k, v = _mk(rng, b, hq, hkv, sq, sk, d, jnp.float32)

    def loss_ref(q, k, v):
        return (R.mha(q, k, v, causal=True) ** 2).sum()

    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for impl in ("pallas", "xla_flash"):
        def loss_k(q, k, v):
            return (O.flash_attention(q, k, v, impl=impl, block_q=blk, block_k=blk) ** 2).sum()

        g = jax.jit(jax.grad(loss_k, argnums=(0, 1, 2)))(q, k, v)
        for a, b_ in zip(g, g_ref):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-4, atol=1e-4)


def test_chunk_carry_continues_softmax(rng):
    b, h, s, d = 1, 2, 64, 16
    q, k, v = _mk(rng, b, h, h, s, s, d, jnp.float32)
    want = R.mha(q, k, v, causal=True)
    cq = s // 4
    outs = []
    for i in range(4):
        qi = q[:, :, i * cq:(i + 1) * cq]
        carry = None
        for j in range(i + 1):
            carry = K.flash_fwd(qi, k[:, :, j * cq:(j + 1) * cq], v[:, :, j * cq:(j + 1) * cq],
                                carry, causal=True, q_offset=i * cq, k_offset=j * cq,
                                block_q=16, block_k=16)
        outs.append(finalize(SoftmaxState(*carry)))
    got = jnp.concatenate(outs, axis=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("window", [4, 16, 33])
def test_window(rng, window):
    b, h, s, d = 1, 2, 48, 16
    q, k, v = _mk(rng, b, h, h, s, s, d, jnp.float32)
    sc = d ** -0.5
    sm = jnp.einsum("bhqd,bhkd->bhqk", q, k) * sc
    qp, kp = jnp.arange(s)[:, None], jnp.arange(s)[None, :]
    mask = (qp >= kp) & (qp - kp < window)
    want = jnp.einsum("bhqk,bhkd->bhqd",
                      jax.nn.softmax(jnp.where(mask, sm, -1e30), axis=-1), v)
    for impl in ("pallas", "xla_flash", "ref"):
        got = O.flash_attention(q, k, v, causal=True, window=window, impl=impl,
                                block_q=16, block_k=16)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_causality_property(rng):
    """Output at position i must not depend on tokens after i."""
    b, h, s, d = 1, 2, 32, 8
    q, k, v = _mk(rng, b, h, h, s, s, d, jnp.float32)
    base = O.flash_attention(q, k, v, impl="pallas", block_q=8, block_k=8)
    k2 = k.at[:, :, 20:].set(99.0)
    v2 = v.at[:, :, 20:].set(-99.0)
    pert = O.flash_attention(q, k2, v2, impl="pallas", block_q=8, block_k=8)
    np.testing.assert_allclose(np.asarray(base[:, :, :20]), np.asarray(pert[:, :, :20]),
                               rtol=1e-6, atol=1e-6)
    assert not np.allclose(np.asarray(base[:, :, 21:]), np.asarray(pert[:, :, 21:]))
