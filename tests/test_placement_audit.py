"""Placement audit: every ``memory_kind=`` decision lives in placement.py.

The placement module's contract (its own docstring) is that all
``jax.device_put`` memory-kind choices route through ``PlacementPolicy`` —
that is what lets the repo degrade gracefully on backends without a
distinct pinned-host pool and keeps the offload story auditable.  A raw
``memory_kind=`` anywhere else (serve engine, paged pool, launch scripts)
would silently bypass the capability probe and crash on CPU/older TPUs.
This test turns the contract's ``grep`` into tier-1.
"""
import os

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_memory_kind_only_in_placement():
    offenders = []
    for root, _dirs, files in os.walk(SRC):
        for fn in files:
            if not fn.endswith(".py"):
                continue
            path = os.path.join(root, fn)
            if os.path.basename(path) == "placement.py":
                continue
            with open(path, encoding="utf-8") as f:
                for i, line in enumerate(f, 1):
                    if "memory_kind=" in line:
                        offenders.append(f"{os.path.relpath(path, SRC)}:{i}")
    assert not offenders, (
        "memory_kind= outside runtime/placement.py — route these through "
        f"PlacementPolicy instead: {offenders}")
