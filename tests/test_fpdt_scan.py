"""Differential test: the scan-compiled FPDT pipeline must reproduce the
Python-unrolled oracle exactly — outputs and every grad — for u in {2, 4, 8},
offload on/off, both kernel impls.

The two paths trace to different programs (one loop body vs u**2 unrolled
pair calls), so XLA may fuse/reassociate differently; tolerances are set an
order of magnitude tighter than the fp32 pipeline's baseline tolerance
(5e-4) to catch any *algorithmic* divergence while allowing fusion-level
last-ulp noise.  Also covers the sparse schedule: grads of chunk pairs
skipped by pair_live must match a dense-mask reference (zero off-schedule
dk/dv contributions, finite dq everywhere).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import fpdt
from repro.core.parallel import ParallelContext
from repro.models import layers as L

TIGHT = dict(rtol=1e-5, atol=1e-5)


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(reduced(get_config("llama3.2-1b")), param_dtype="float32")
    key = jax.random.PRNGKey(7)
    p = L.init_attn(cfg, key, jnp.float32)
    b, S = 2, 32
    x = jax.random.normal(jax.random.fold_in(key, 1), (b, S, cfg.d_model), jnp.float32)
    do = jax.random.normal(jax.random.fold_in(key, 2), (b, S, cfg.q_dim), jnp.float32)
    return cfg, p, x, do


def _run(cfg, p, x, do, u, offload, impl, *, unroll, window=0, sparsity=0.0):
    c = dataclasses.replace(cfg, fpdt_chunks=u, fpdt_offload=offload, block_q=8,
                            block_k=8, fpdt_unroll=unroll, attn_sparsity=sparsity)
    par = ParallelContext(mesh=None, attn_impl=impl)

    def f(x, p):
        o = fpdt.fpdt_attention(c, par, p, x, kind="local", window=window)
        return (o * do).sum(), o

    (_, o), grads = jax.jit(
        jax.value_and_grad(f, argnums=(0, 1), has_aux=True))(x, p)
    return o, grads


def _assert_trees_match(g, gu, **tol):
    la, lb = jax.tree.leaves(g), jax.tree.leaves(gu)
    assert len(la) == len(lb)
    for a, b_ in zip(la, lb):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), **tol)


@pytest.mark.parametrize("u,offload,impl", [
    (2, False, "pallas"), (2, True, "xla_flash"),
    (4, True, "pallas"), (4, False, "xla_flash"),
    (8, True, "xla_flash"), (8, False, "xla_flash"),
])
def test_scan_equals_unrolled(setup, u, offload, impl):
    cfg, p, x, do = setup
    o_s, g_s = _run(cfg, p, x, do, u, offload, impl, unroll=False)
    o_u, g_u = _run(cfg, p, x, do, u, offload, impl, unroll=True)
    np.testing.assert_allclose(np.asarray(o_s), np.asarray(o_u), **TIGHT)
    _assert_trees_match(g_s, g_u, **TIGHT)


def test_scan_equals_unrolled_windowed(setup):
    cfg, p, x, do = setup
    o_s, g_s = _run(cfg, p, x, do, 4, True, "xla_flash", unroll=False, window=12)
    o_u, g_u = _run(cfg, p, x, do, 4, True, "xla_flash", unroll=True, window=12)
    np.testing.assert_allclose(np.asarray(o_s), np.asarray(o_u), **TIGHT)
    _assert_trees_match(g_s, g_u, **TIGHT)


def test_scan_equals_baseline(setup):
    """Transitivity anchor: scan path vs the u=1 un-chunked baseline."""
    cfg, p, x, do = setup
    o1, g1 = _run(cfg, p, x, do, 1, False, "xla_flash", unroll=False)
    o, g = _run(cfg, p, x, do, 4, True, "xla_flash", unroll=False)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o1), rtol=2e-4, atol=2e-4)
    _assert_trees_match(g, g1, rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# sparse schedules: skipped chunk pairs, zero-grad correctness
# ---------------------------------------------------------------------------


def _dense_sparse_reference(cfg, p, x, do, u, window, sparsity):
    """Oracle: materialized attention under the exact token mask the FPDT
    sparse schedule implements (causal & window & pair_live block mask)."""
    b, S, _ = x.shape
    cq = S // u
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    qpos = np.arange(S)[:, None]
    kpos = np.arange(S)[None, :]
    ok = qpos >= kpos
    if window:
        ok = ok & (qpos - kpos < window)
    blk = np.zeros((S, S), bool)
    for i in range(u):
        for j in range(u):
            if fpdt.pair_live(i, j, cq=cq, window=window, sparsity=sparsity):
                blk[i * cq:(i + 1) * cq, j * cq:(j + 1) * cq] = True
    mask = jnp.asarray(ok & blk)

    def f(x, p):
        q, k, v = L.qkv_proj(cfg, p, x)
        pos = jnp.arange(S)
        q = L.apply_rope(q, pos, cfg.rope_theta)
        k = L.apply_rope(k, pos, cfg.rope_theta)
        q = q.transpose(0, 2, 1, 3).astype(jnp.float32)
        k = k.transpose(0, 2, 1, 3).astype(jnp.float32)
        v = v.transpose(0, 2, 1, 3).astype(jnp.float32)
        if hkv != hq:
            k = jnp.repeat(k, hq // hkv, axis=1)
            v = jnp.repeat(v, hq // hkv, axis=1)
        s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * dh ** -0.5
        s = jnp.where(mask, s, -1e30)
        pr = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", pr, v)
        o = o.transpose(0, 2, 1, 3).reshape(b, S, hq * dh)
        return (o * do).sum(), o

    (_, o), grads = jax.jit(
        jax.value_and_grad(f, argnums=(0, 1), has_aux=True))(x, p)
    return o, grads


@pytest.mark.parametrize("unroll", [False, True])
def test_sparse_skipped_chunks_grads(setup, unroll):
    """attn_sparsity=0.5, u=8: pair_live skips diagonal-adjacent-but-one
    chunks (j = i-2, i-4, ...).  Outputs AND grads must match the dense
    masked-attention oracle — in particular dk/dv receive exactly zero from
    skipped pairs and dq stays finite on every chunk."""
    cfg, p, x, do = setup
    u, sparsity = 8, 0.5
    cq = x.shape[1] // u
    # the schedule really skips pairs (otherwise this test is vacuous)
    assert not fpdt.pair_live(4, 2, cq=cq, window=0, sparsity=sparsity)
    assert fpdt.pair_live(4, 3, cq=cq, window=0, sparsity=sparsity)
    o, g = _run(cfg, p, x, do, u, True, "xla_flash", unroll=unroll,
                sparsity=sparsity)
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()
    o_ref, g_ref = _dense_sparse_reference(cfg, p, x, do, u, 0, sparsity)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), rtol=2e-4, atol=2e-4)
    _assert_trees_match(g, g_ref, rtol=5e-4, atol=5e-4)
