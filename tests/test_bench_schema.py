"""Every committed ``BENCH_*.json`` conforms to the ``benchmarks/run.py
--json`` schema: ``{suite: [{name, value, derived}, ...]}``.

The BENCH files are the repo's measured claims (program-size flatness,
tok/s, prefix-hit rates) and downstream tooling parses them; a hand-edited
or truncated file should fail tier-1, not silently skew a comparison.
"""
import glob
import json
import os

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")
BENCH_FILES = sorted(glob.glob(os.path.join(ROOT, "BENCH_*.json")))


def test_bench_files_exist():
    assert BENCH_FILES, "no BENCH_*.json committed at the repo root"


@pytest.mark.parametrize("path", BENCH_FILES,
                         ids=[os.path.basename(p) for p in BENCH_FILES])
def test_bench_schema(path):
    with open(path) as f:
        doc = json.load(f)
    assert isinstance(doc, dict) and doc, f"{path}: top level must be a " \
                                          f"non-empty suite dict"
    for suite, rows in doc.items():
        assert isinstance(suite, str) and suite
        assert isinstance(rows, list) and rows, f"{suite}: empty suite"
        seen = set()
        for row in rows:
            assert isinstance(row, dict), f"{suite}: row is not a dict"
            assert set(row) == {"name", "value", "derived"}, \
                f"{suite}: bad keys {sorted(row)}"
            assert isinstance(row["name"], str) and row["name"]
            assert isinstance(row["value"], (int, float)) \
                and not isinstance(row["value"], bool), \
                f"{suite}/{row['name']}: value must be numeric"
            assert isinstance(row["derived"], str)
            assert row["name"] not in seen, \
                f"{suite}: duplicate row name {row['name']}"
            seen.add(row["name"])
