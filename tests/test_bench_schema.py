"""Every committed ``BENCH_*.json`` conforms to the ``benchmarks/run.py
--json`` schema: ``{suite: [{name, value, derived}, ...]}``.

The BENCH files are the repo's measured claims (program-size flatness,
tok/s, prefix-hit rates) and downstream tooling parses them; a hand-edited
or truncated file should fail tier-1, not silently skew a comparison.
"""
import glob
import json
import os

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")
BENCH_FILES = sorted(glob.glob(os.path.join(ROOT, "BENCH_*.json")))


def test_bench_files_exist():
    assert BENCH_FILES, "no BENCH_*.json committed at the repo root"


@pytest.mark.parametrize("path", BENCH_FILES,
                         ids=[os.path.basename(p) for p in BENCH_FILES])
def test_bench_schema(path):
    with open(path) as f:
        doc = json.load(f)
    assert isinstance(doc, dict) and doc, f"{path}: top level must be a " \
                                          f"non-empty suite dict"
    for suite, rows in doc.items():
        assert isinstance(suite, str) and suite
        assert isinstance(rows, list) and rows, f"{suite}: empty suite"
        seen = set()
        for row in rows:
            assert isinstance(row, dict), f"{suite}: row is not a dict"
            assert set(row) == {"name", "value", "derived"}, \
                f"{suite}: bad keys {sorted(row)}"
            assert isinstance(row["name"], str) and row["name"]
            assert isinstance(row["value"], (int, float)) \
                and not isinstance(row["value"], bool), \
                f"{suite}/{row['name']}: value must be numeric"
            assert isinstance(row["derived"], str)
            assert row["name"] not in seen, \
                f"{suite}: duplicate row name {row['name']}"
            seen.add(row["name"])


def test_bench_kv_store_acceptance():
    """The persisted-prefix-cache claims: a restarted engine restored from
    ``--kv-store`` must serve the shared-system-prompt workload >90%
    prefix-hit with identical outputs, through the bounded program set."""
    path = os.path.join(ROOT, "BENCH_kv_store.json")
    assert os.path.exists(path), "BENCH_kv_store.json not committed"
    with open(path) as f:
        rows = {r["name"]: r["value"] for r in json.load(f)["kv_store"]}
    assert rows["kv_store_saved_pages"] > 0
    # restored <= saved: paths the restarted engine already holds live
    # (its own warmup) win over the file and are skipped
    assert 0 < rows["kv_store_restored_pages"] <= rows["kv_store_saved_pages"]
    assert rows["kv_store_restored_hit_rate"] > 0.9, \
        "restored engine must radix-hit the persisted shared prefix"
    assert rows["kv_store_restored_hit_rate"] > rows["kv_store_cold_hit_rate"]
    assert rows["kv_store_restored_promotes"] > 0  # pages came off the tier
    assert rows["kv_store_outputs_match"] == 1
    assert rows["kv_store_programs_promote"] == 1
    for prog in ("segment", "reset", "copy", "promote"):
        assert rows[f"kv_store_programs_{prog}"] <= 1, prog


def test_bench_slo_acceptance():
    """The SLO-scheduler claims: on the same seeded heavy-tailed trace the
    SLO-aware policy beats FIFO on goodput-under-SLO and interactive TTFT,
    actually preempts (spill-backed), emits identical tokens, and stays in
    the bounded program set."""
    path = os.path.join(ROOT, "BENCH_slo.json")
    assert os.path.exists(path), "BENCH_slo.json not committed"
    with open(path) as f:
        rows = {r["name"]: r["value"] for r in json.load(f)["slo"]}
    assert rows["slo_goodput_slo"] >= rows["slo_goodput_fifo"], \
        "SLO-aware scheduling must not lose goodput to FIFO"
    assert rows["slo_good_requests_slo"] >= rows["slo_good_requests_fifo"]
    assert rows["slo_preemptions_slo"] >= 1, \
        "the workload must exercise spill-backed preemption"
    assert rows["slo_interactive_p95_ttft_slo"] <= \
        rows["slo_interactive_p95_ttft_fifo"], \
        "prioritizing interactive requests must not worsen their TTFT"
    assert rows["slo_outputs_match"] == 1, \
        "scheduling may reorder WHEN tokens appear, never WHICH"
    assert rows["slo_programs_segment"] == 1
    for prog in ("segment", "reset", "copy", "promote"):
        assert rows[f"slo_programs_{prog}"] <= 1, prog


def test_bench_failover_acceptance():
    """The failover claims: under the seeded fault schedule (permanent
    crash of 1 replica mid-workload) the router completes 100% of
    requests token-identically while the legacy abort-everything baseline
    loses the crashed round; re-homed sessions recover their prefixes
    through the shared KV store (not a cold recompute); the rejoined
    replica serves warm; the program set stays bounded."""
    path = os.path.join(ROOT, "BENCH_failover.json")
    assert os.path.exists(path), "BENCH_failover.json not committed"
    with open(path) as f:
        rows = {r["name"]: r["value"] for r in json.load(f)["failover"]}
    assert rows["failover_nofault_completion_rate"] == 1.0
    assert rows["failover_failover_completion_rate"] == 1.0, \
        "failover must complete EVERY request despite the crash"
    assert rows["failover_abort_completion_rate"] < 1.0, \
        "the abort baseline must show the partial loss failover prevents"
    assert rows["failover_outputs_match"] == 1, \
        "failover must be invisible in the outputs (greedy-identical)"
    assert rows["failover_deaths"] == 1
    assert rows["failover_rehomed_requests"] > 0
    assert rows["failover_recovered_prefix_tokens"] > 0, \
        "re-homed requests must recover prefixes, not recompute them"
    assert rows["failover_recovered_pages"] > 0
    assert rows["failover_rejoin_completion_rate"] == 1.0
    assert rows["failover_rejoin_hit_rate"] > 0.9, \
        "a rejoined replica must serve its returning sessions warm"
    for prog in ("segment", "reset", "copy", "promote"):
        assert rows[f"failover_programs_{prog}"] <= 1, prog


def test_bench_obs_acceptance():
    """The telemetry claims: tracing the full request lifecycle costs
    < 5% tok/s, leaves the compiled program set untouched (zero
    bounded-program-set alerts), and the per-request summaries
    reconstructed from trace spans alone agree with the scheduler's own
    accounting (TTFT / token counts / preemptions)."""
    path = os.path.join(ROOT, "BENCH_obs.json")
    assert os.path.exists(path), "BENCH_obs.json not committed"
    with open(path) as f:
        rows = {r["name"]: r["value"] for r in json.load(f)["obs"]}
    assert rows["obs_tok_per_s_traced"] > 0
    assert rows["obs_tok_per_s_untraced"] > 0
    assert rows["obs_overhead_pct"] < 5, \
        "tracing must cost < 5% serving throughput"
    assert rows["obs_trace_events"] > 0
    assert rows["obs_summary_consistent"] == 1, \
        "trace-derived summaries must match the scheduler's accounting"
    assert rows["obs_alerts"] == 0, \
        "tracing must not perturb the compiled program set"
    for prog in ("segment", "reset", "copy", "promote"):
        assert rows[f"obs_programs_{prog}"] <= 1, prog
