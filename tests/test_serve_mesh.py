"""Mesh-sharded serving: subprocess parity driver + router unit tests.

The sharded ``PagedServeEngine`` needs a real multi-device mesh; unit
tests keep one visible device, so the parity cells run in a spawned
subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(tests/distributed/check_serve_mesh.py — same harness pattern as
test_fpdt_mesh.py).  The session-affine router is host-side pure Python
and is tested in-process.
"""
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)
SRC = os.path.join(HERE, "..", "src")
sys.path.insert(0, SRC)

from repro.launch.router import ReplicaFailed, ReplicaRouter


def test_serve_mesh_parity():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    r = subprocess.run(
        [sys.executable,
         os.path.join(HERE, "distributed", "check_serve_mesh.py")],
        capture_output=True, text=True, timeout=1800, env=env)
    if r.returncode != 0:
        raise AssertionError(f"exit {r.returncode}\nSTDOUT:\n{r.stdout[-4000:]}\n"
                             f"STDERR:\n{r.stderr[-4000:]}")
    assert "ALL SERVE MESH CHECKS PASSED" in r.stdout
    for cell in ("llama-headshard", "llama-psfallback", "ssm-paged",
                 "llama-dense", "llama-psindiv-stream", "llama-spill"):
        assert f"OK {cell}" in r.stdout, r.stdout


# ---------------------------------------------------------------------------
# router (host-side, no jax)
# ---------------------------------------------------------------------------


class FakeReplica:
    def __init__(self, fail=False):
        self.fail = fail
        self.calls = []
        self.last_stats = {"prompt_tokens": 0, "prefix_hit_tokens": 0}

    def generate(self, prompts):
        if self.fail:
            raise RuntimeError("segment dispatch blew up")
        self.calls.append(list(prompts))
        self.last_stats["prompt_tokens"] += sum(len(p) for p in prompts)
        return [[p[0], len(p)] for p in prompts]


def test_router_affinity_is_sticky_and_deterministic():
    shared = list(range(100, 120))
    reps = [FakeReplica() for _ in range(4)]
    rt = ReplicaRouter(reps, policy="affine")
    homes = {rt.home_of(shared + [i]) for i in range(8)}
    assert len(homes) == 1  # same 16-token prefix -> same home, always
    rt2 = ReplicaRouter([FakeReplica() for _ in range(4)], policy="affine")
    assert rt2.home_of(shared + [0]) == homes.pop()  # process-independent


def test_router_merges_in_request_order():
    reps = [FakeReplica() for _ in range(3)]
    rt = ReplicaRouter(reps, policy="affine")
    prompts = [[i, i + 1, i + 2] for i in range(9)]
    out = rt.generate(prompts)
    assert out == [[p[0], 3] for p in prompts]
    assert sum(len(r.calls) > 0 for r in reps) >= 2  # actually spread
    assert rt.last_stats["requests"] == 9
    assert rt.depth == [0, 0, 0]  # queues drained


def test_router_session_overrides_prefix():
    rt = ReplicaRouter([FakeReplica() for _ in range(4)], policy="affine")
    p = [1, 2, 3]
    by_sess = {rt.home_of(p, session=f"tenant-{i}") for i in range(16)}
    assert len(by_sess) > 1  # sessions spread even with identical prompts


def test_router_spills_to_least_loaded():
    rt = ReplicaRouter([FakeReplica() for _ in range(2)], policy="affine",
                       spill_margin=2)
    p = [7, 7, 7]
    home = rt.home_of(p)
    assert rt.route(p) == home and rt.route(p) == home
    assert rt.route(p) == 1 - home  # depth gap hit the margin -> spill
    rt0 = ReplicaRouter([FakeReplica() for _ in range(2)], policy="affine")
    assert [rt0.route(p) for _ in range(5)] == [home] * 5  # 0 = never spill


def test_router_round_robin_baseline():
    rt = ReplicaRouter([FakeReplica() for _ in range(3)], policy="rr")
    assert [rt.route([9]) for _ in range(6)] == [0, 1, 2, 0, 1, 2]


def test_router_replica_failure_is_named():
    """failover=False keeps the legacy abort-the-workload contract."""
    reps = [FakeReplica(), FakeReplica(fail=True)]
    rt = ReplicaRouter(reps, policy="rr", failover=False)
    with pytest.raises(ReplicaFailed, match="replica 1"):
        rt.generate([[1], [2]])
    assert rt.depth == [0, 0]  # failure still drains accounting


def test_router_failure_drains_undispatched_tail():
    """Regression: when an EARLY replica fails, requests already assigned
    to replicas after it never reached their own dispatch-side decrement —
    the leaked depth permanently skewed every future spill decision."""
    reps = [FakeReplica(fail=True), FakeReplica(), FakeReplica()]
    rt = ReplicaRouter(reps, policy="rr", failover=False)
    with pytest.raises(ReplicaFailed, match="replica 0"):
        rt.generate([[1], [2], [3], [4], [5], [6]])
    assert rt.depth == [0, 0, 0]  # the undispatched tail drained too
    # a healthy rerun routed through the same accounting still balances
    reps[0].fail = False
    rt.generate([[1], [2], [3]])
    assert rt.depth == [0, 0, 0]


def test_router_failover_default_rehomes_instead_of_raising():
    """The new default: the same failing replica costs nothing but a
    re-home — every request completes on the survivors, the death is
    accounted, and queue depths still balance."""
    reps = [FakeReplica(), FakeReplica(fail=True), FakeReplica()]
    rt = ReplicaRouter(reps, policy="rr", max_retries=0,
                       warn=lambda m: None)
    prompts = [[i, i, i] for i in range(9)]
    out = rt.generate(prompts)
    assert out == [[p[0], 3] for p in prompts]
    fo = rt.last_stats["failover"]
    assert fo["deaths"] == 1 and fo["rehomed_requests"] == 3
    assert rt.health[1] == "dead"
    assert rt.depth == [0, 0, 0]


def test_router_routes_around_dead_replicas():
    """Routing (affine AND rr) only considers live replicas; rejoin()
    brings the dead one back into rotation."""
    rt = ReplicaRouter([FakeReplica() for _ in range(3)], policy="rr",
                       warn=lambda m: None)
    rt.health[1] = rt.DEAD
    assert [rt.route([9]) for _ in range(4)] == [0, 2, 0, 2]
    rt.rejoin(1)
    rt2 = ReplicaRouter([FakeReplica() for _ in range(3)], policy="affine",
                        warn=lambda m: None)
    homes = {rt2.home_of([i, i, i]) for i in range(32)}
    assert homes == {0, 1, 2}  # rendezvous spreads keys over all replicas
    rt2.health[0] = rt2.DEAD
    assert {rt2.home_of([i, i, i]) for i in range(32)} == {1, 2}


def test_router_rejects_bad_config():
    with pytest.raises(ValueError):
        ReplicaRouter([])
    with pytest.raises(ValueError):
        ReplicaRouter([FakeReplica()], policy="random")
