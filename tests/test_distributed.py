"""Multi-device correctness + dry-run smoke, via subprocess (the main pytest
process keeps exactly one visible device)."""
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)
SRC = os.path.join(HERE, "..", "src")


def _run(script_rel, env_extra=None, timeout=3000):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.update(env_extra or {})
    r = subprocess.run([sys.executable, os.path.join(HERE, script_rel)],
                       capture_output=True, text=True, timeout=timeout, env=env)
    if r.returncode != 0:
        raise AssertionError(f"exit {r.returncode}\nSTDOUT:\n{r.stdout[-4000:]}\n"
                             f"STDERR:\n{r.stderr[-4000:]}")
    return r.stdout


@pytest.mark.slow
def test_fpdt_distributed_correctness():
    out = _run("distributed/check_fpdt_distributed.py")
    assert "ALL DISTRIBUTED CHECKS PASSED" in out


@pytest.mark.slow
def test_dryrun_single_cell():
    """A full production-mesh (512-dev) dry-run cell must lower+compile."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "llama3.2-1b",
         "--shape", "train_4k", "--mesh", "multi", "--out", "/tmp/dryrun_test"],
        capture_output=True, text=True, timeout=3000, env=env,
        cwd=os.path.join(HERE, ".."),
    )
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "[OK ]" in r.stdout
