"""Minimal stand-in for ``hypothesis`` when it isn't installed.

The property-test modules import ``given/settings/strategies`` through this
shim; with real hypothesis available they get the real thing, otherwise a
deterministic fallback runs each test over a small fixed grid of example
values (the cartesian product of per-strategy samples, capped).  That keeps
the invariant tests *running* — not skipped — on minimal containers, while
real hypothesis still fuzzes them where it exists.
"""
import functools
import inspect
import itertools

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    _MAX_EXAMPLES = 8

    class _Strategy:
        def __init__(self, samples):
            self.samples = list(samples)

    class _Strategies:
        @staticmethod
        def integers(min_value=0, max_value=100):
            span = max_value - min_value
            picks = {min_value, max_value, min_value + span // 2,
                     min_value + span // 3, min_value + (2 * span) // 3}
            return _Strategy(sorted(picks))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            mid = 0.5 * (min_value + max_value)
            return _Strategy([min_value, mid, max_value])

        @staticmethod
        def sampled_from(elements):
            return _Strategy(list(elements))

        @staticmethod
        def booleans():
            return _Strategy([False, True])

    strategies = _Strategies()

    def settings(max_examples=None, **_kw):
        def deco(fn):
            fn._he_max_examples = max_examples
            return fn

        return deco

    def given(**strats):
        def deco(fn):
            @functools.wraps(fn)
            def runner(*args, **kwargs):
                # settings() is the OUTER decorator in the test modules, so
                # the cap lands on this wrapper — check it first
                cap = (getattr(runner, "_he_max_examples", None)
                       or getattr(fn, "_he_max_examples", None) or _MAX_EXAMPLES)
                names = list(strats)
                grids = [strats[n].samples for n in names]
                for k, combo in enumerate(itertools.product(*grids)):
                    if k >= cap:
                        break
                    fn(*args, **dict(kwargs, **dict(zip(names, combo))))

            # hide the strategy-filled params from pytest's fixture resolver
            sig = inspect.signature(fn)
            runner.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items() if name not in strats
            ])
            return runner

        return deco
