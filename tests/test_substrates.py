"""Data pipeline, optimizer, compression, checkpointing, runtime FT."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import CheckpointableIterator, DataConfig, make_batch_fn
from repro.checkpoint.manager import CheckpointManager
from repro.configs import SHAPES, ShapeConfig, get_config, reduced
from repro.optim import adamw
from repro.optim import compression as comp


# ---------------------------------------------------------------- data
def test_data_determinism_and_resume():
    cfg = reduced(get_config("llama3.2-1b"))
    shape = ShapeConfig("t", 32, 4, "train")
    bf = make_batch_fn(cfg, shape)
    a = bf(7)
    b = bf(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    it = CheckpointableIterator(bf)
    for _ in range(3):
        next(it)
    state = it.state()
    want = next(it)["tokens"]
    it2 = CheckpointableIterator(bf)
    it2.restore(state)
    np.testing.assert_array_equal(next(it2)["tokens"], want)


def test_data_labels_shifted():
    cfg = reduced(get_config("llama3.2-1b"))
    bf = make_batch_fn(cfg, ShapeConfig("t", 16, 2, "train"))
    b = bf(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# ---------------------------------------------------------------- optimizer
def test_adamw_matches_reference():
    oc = adamw.OptConfig(lr=1e-2, warmup_steps=0, total_steps=100,
                         weight_decay=0.0, clip_norm=1e9, min_lr_frac=1.0)
    params = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    grads = {"w": jnp.asarray([0.1, 0.2, -0.3])}
    st = adamw.init(oc, params)
    p1, st1, m = adamw.apply(oc, params, grads, st)
    # closed-form first Adam step: p - lr * sign-ish
    g = np.asarray([0.1, 0.2, -0.3])
    mh = g  # m1/c1 with b1 bias correction
    vh = g * g
    want = np.asarray([1.0, -2.0, 3.0]) - 1e-2 * mh / (np.sqrt(vh) + oc.eps)
    np.testing.assert_allclose(np.asarray(p1["w"]), want, rtol=1e-5)


def test_lr_schedule():
    oc = adamw.OptConfig(lr=1.0, warmup_steps=10, total_steps=110, min_lr_frac=0.1)
    assert float(adamw.lr_at(oc, 5)) == pytest.approx(0.5)
    assert float(adamw.lr_at(oc, 10)) == pytest.approx(1.0)
    assert float(adamw.lr_at(oc, 110)) == pytest.approx(0.1, rel=1e-3)


def test_grad_clipping():
    oc = adamw.OptConfig(clip_norm=1.0, warmup_steps=0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    grads = {"w": jnp.full(4, 100.0)}
    st = adamw.init(oc, params)
    _, _, m = adamw.apply(oc, params, grads, st)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


# ---------------------------------------------------------------- compression
def test_compression_error_feedback():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(5000), jnp.float32)
    res = jnp.zeros(5000, jnp.float32)
    # accumulated (g_hat) over steps tracks accumulated g (error feedback)
    tot_hat = np.zeros(5000)
    for _ in range(20):
        g_hat, res = comp.quantize_with_feedback(g, res)
        tot_hat += np.asarray(g_hat)
    drift = np.abs(tot_hat - 20 * np.asarray(g)).max()
    scale = np.abs(np.asarray(g)).max() / 127
    assert drift <= 2 * scale + 1e-5  # residual bounded -> no accumulation


def test_compression_roundtrip_small_error():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((333,)), jnp.float32)
    c = comp.compress(x)
    y = comp.decompress(c, x.shape, jnp.float32)
    assert float(jnp.max(jnp.abs(x - y))) <= float(jnp.max(jnp.abs(x))) / 127 + 1e-6


# ---------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), shards_per_leaf=3, keep=2)
    tree = {"a": jnp.arange(10, dtype=jnp.float32).reshape(5, 2),
            "b": {"c": jnp.ones((7,), jnp.bfloat16)}, "s": jnp.int32(3)}
    for step in (1, 2, 3):
        mgr.save(step, tree, extra={"data_step": step * 10}, blocking=True)
    assert mgr.all_steps() == [2, 3]  # gc keeps last 2
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    got, extra = mgr.restore(3, like)
    assert extra["data_step"] == 30
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.ones((64, 64))}
    mgr.save(5, tree)  # async
    mgr.wait()
    assert mgr.latest_step() == 5


def test_checkpoint_atomicity(tmp_path):
    """A .tmp dir (simulated crash) must be invisible to restore."""
    mgr = CheckpointManager(str(tmp_path))
    os.makedirs(tmp_path / "step_9.tmp")
    assert mgr.latest_step() is None
    mgr.save(1, {"w": jnp.zeros(3)}, blocking=True)
    assert mgr.latest_step() == 1


# ---------------------------------------------------------------- runtime FT
def test_straggler_monitor():
    from repro.runtime.train_loop import HeartbeatMonitor, StragglerAlert

    mon = HeartbeatMonitor(zscore=3.0, patience=2)
    for _ in range(20):
        mon.record(0.1 + np.random.default_rng(0).uniform(0, 0.001))
    with pytest.raises(StragglerAlert):
        mon.record(5.0)
        mon.record(5.0)


def test_train_loop_end_to_end(tmp_path):
    """Tiny model, few steps; checkpoint + resume continues identically."""
    import dataclasses

    from repro.models import transformer as T
    from repro.runtime.train_loop import TrainConfig, TrainLoop, make_train_step

    cfg = dataclasses.replace(reduced(get_config("llama3.2-1b")), num_layers=2)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    oc = adamw.OptConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    opt = adamw.init(oc, params)
    tc = TrainConfig(steps=6, ckpt_every=3, log_every=100)
    step_fn = jax.jit(make_train_step(cfg, None, oc, tc))
    bf = make_batch_fn(cfg, ShapeConfig("t", 32, 2, "train"))

    def put(b):
        return {k: jnp.asarray(v) for k, v in b.items()}

    mgr = CheckpointManager(str(tmp_path))
    loop = TrainLoop(cfg, None, oc, tc, step_fn, CheckpointableIterator(bf), mgr)
    params_f, opt_f, step = loop.run(params, opt, put_batch=put)
    assert step == 6
    assert mgr.latest_step() == 6
    losses = [h["loss"] for h in loop.history]
    assert losses[-1] < losses[0]  # training moves the loss

    # resume from step 3 and land on the same trajectory
    (restored, extra) = mgr.restore(3, {"params": params, "opt": opt})
    loop2 = TrainLoop(cfg, None, oc, tc, step_fn, CheckpointableIterator(bf), None)
    params_r, opt_r, step_r = loop2.run(restored["params"], restored["opt"],
                                        start_step=3, put_batch=put)
    assert step_r == 6
    for a, b in zip(jax.tree.leaves(params_r), jax.tree.leaves(params_f)):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-2)
