"""Paged KV pool (`runtime/paged.py` + the paged attention twins in
`models/serve.py`): allocator/refcount/COW invariants (property tests),
radix prefix reuse, paged == dense engine parity (logits and harvested
ids) across layouts, prefix-reuse == full-recompute, pool-exhaustion
hardening, and (slow) program-size flatness in ``n_pages``."""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st

from repro.configs import get_config, reduced
from repro.models import serve as SV
from repro.models import transformer as T
from repro.runtime import decode_loop as DL
from repro.runtime import paged as PG


@functools.lru_cache(maxsize=4)
def setup(name):
    cfg = dataclasses.replace(reduced(get_config(name)), param_dtype="float32",
                              remat="none")
    return cfg, T.init_params(cfg, jax.random.PRNGKey(0))


def prompts_for(cfg, lens, seed=0, prefix=()):
    rng = np.random.default_rng(seed)
    return [list(prefix) + rng.integers(0, cfg.vocab_size, size=n).tolist()
            for n in lens]


# ---------------------------------------------------------------------------
# allocator invariants
# ---------------------------------------------------------------------------


@settings(max_examples=40)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n_pages=st.integers(min_value=1, max_value=17))
def test_pool_allocator_invariants(seed, n_pages):
    """Random alloc/share/release traces against a reference model: the
    free list never double-allocates, a page is free iff refcount == 0,
    and exhaustion raises instead of handing out a live page."""
    rng = np.random.default_rng(seed)
    pool = PG.PagePool(n_pages)
    live = {}  # pid -> reference refcount
    for _ in range(60):
        op = rng.integers(0, 3)
        if op == 0:  # alloc
            if len(live) == n_pages:
                with pytest.raises(PG.PoolExhausted):
                    pool.alloc()
            else:
                pid = pool.alloc()
                assert pid not in live, "double allocation"
                live[pid] = 1
        elif op == 1 and live:  # share
            pid = int(rng.choice(list(live)))
            pool.share(pid)
            live[pid] += 1
        elif op == 2 and live:  # release
            pid = int(rng.choice(list(live)))
            pool.release(pid)
            live[pid] -= 1
            if live[pid] == 0:
                del live[pid]
        assert pool.used_count == len(live)
        assert pool.free_count == n_pages - len(live)
        for pid, rc in live.items():
            assert int(pool.refcount[pid]) == rc
    for pid in range(n_pages):  # dead pages really are at refcount 0
        assert (pid in live) == (int(pool.refcount[pid]) > 0)


def test_pool_misuse_raises():
    pool = PG.PagePool(2)
    a = pool.alloc()
    pool.release(a)
    with pytest.raises(ValueError):
        pool.release(a)
    with pytest.raises(ValueError):
        pool.share(a)


# ---------------------------------------------------------------------------
# radix tree
# ---------------------------------------------------------------------------


def test_radix_match_insert_evict():
    pool = PG.PagePool(16)
    tree = PG.RadixTree(4, pool)
    toks = list(range(10))  # 2 full pages + partial
    pids = [pool.alloc(), pool.alloc()]
    assert tree.insert(toks, pids) == 2
    assert tree.pages == 2 and int(pool.refcount[pids[0]]) == 2
    # full match; partial page never matched
    assert tree.match(toks) == pids
    assert tree.match(toks[:7]) == pids[:1]
    assert tree.match([99] + toks[1:]) == []
    # re-insert of the same prefix adds nothing (first prefill wins)
    assert tree.insert(toks, [pool.alloc(), pool.alloc()]) == 0
    # owner releases; tree keeps the pages alive
    for pid in pids:
        pool.release(pid)
    assert int(pool.refcount[pids[0]]) == 1
    # eviction is leaf-first and only touches tree-only pages
    pool.share(pids[1])  # someone still maps the leaf
    assert tree.evict(2) == 0  # leaf pinned -> its prefix chain survives too
    pool.release(pids[1])
    assert tree.evict(2) == 2 and tree.pages == 0
    assert int(pool.refcount[pids[0]]) == 0 and int(pool.refcount[pids[1]]) == 0


def test_radix_lru_eviction_order():
    pool = PG.PagePool(8)
    tree = PG.RadixTree(2, pool)
    old = [pool.alloc()]
    new = [pool.alloc()]
    tree.insert([1, 2], old)
    tree.insert([3, 4], new)
    tree.match([3, 4])  # freshen the second branch
    for p in (*old, *new):
        pool.release(p)
    assert tree.evict(1) == 1
    assert int(pool.refcount[old[0]]) == 0  # LRU went first
    assert int(pool.refcount[new[0]]) == 1


# ---------------------------------------------------------------------------
# page tables + COW
# ---------------------------------------------------------------------------


@settings(max_examples=20)
@given(ps=st.sampled_from([1, 2, 4]),
       plen=st.integers(min_value=1, max_value=24),
       budget=st.integers(min_value=0, max_value=9))
def test_manager_reserve_and_release(ps, plen, budget):
    """Admission maps exactly the worst-case reserve, the rest of the row
    is unmapped, and release returns every page."""
    mgr = PG.PagedCacheManager(64, ps, use_radix=False)
    mgr.begin(2, max_pages=-(-(24 + budget) // ps))
    toks = list(range(plen))
    plan = mgr.admit(0, toks, budget)
    need = max(-(-(plen + budget) // ps), 1)
    assert plan.resume == 0 and plan.cow == [] and len(plan.fresh_pages) == need
    row = mgr.table[0]
    assert (row[:need] >= 0).all() and (row[need:] == -1).all()
    assert len(set(row[:need].tolist())) == need  # all distinct
    assert mgr.pages_in_use == need
    mgr.release(0)
    assert mgr.pages_in_use == 0
    assert (mgr.table[0] == mgr.trash).all()


@settings(max_examples=20)
@given(seed=st.integers(min_value=0, max_value=1000),
       ps=st.sampled_from([2, 4]))
def test_cow_divergence_isolates_tables(seed, ps):
    """After ``ensure_writable`` no page is reachable from two tables:
    the diverged page is exclusively owned, refcounts stay exact, and
    still-shared prefix pages keep their sharers."""
    rng = np.random.default_rng(seed)
    mgr = PG.PagedCacheManager(32, ps, use_radix=True)
    mgr.begin(2, max_pages=8)
    toks = rng.integers(0, 100, size=3 * ps).tolist()  # 3 full pages
    p0 = mgr.admit(0, toks, 0)
    mgr.complete_prefill(0, toks)
    p1 = mgr.admit(1, toks, 0)  # full-cover match -> COW of the last page
    assert p1.hit_pages == 3 and p1.resume == 3 * ps - 1
    assert len(p1.cow) == 1
    src, dst = p1.cow[0]
    assert src == mgr.table[0, 2] and dst == mgr.table[1, 2] and src != dst
    # shared prefix pages appear in both tables; the diverged page in one
    shared = set(mgr.table[0, :2].tolist()) & set(mgr.table[1, :2].tolist())
    assert len(shared) == 2
    assert int(mgr.pool.refcount[dst]) == 1
    # a forced write to a still-shared page also diverges it
    pair = mgr.ensure_writable(1, 0)
    assert pair is not None and mgr.table[1, 0] != mgr.table[0, 0]
    assert mgr.ensure_writable(1, 0) is None  # already exclusive
    both = set(mgr.table[0].tolist()) & set(mgr.table[1].tolist()) - {-1}
    for pid in both:  # anything still common is genuinely shared (rc > 1)
        assert int(mgr.pool.refcount[pid]) > 1
    mgr.release(0)
    mgr.release(1)
    assert mgr.pages_in_use == mgr.radix.pages  # only the tree's refs left


def test_cow_source_survives_admit_eviction():
    """A full-cover admit under pool pressure must not evict the page its
    own COW copy reads from: eviction makes room out of OTHER tree leaves
    and the (src, dst) pair stays a real copy, never src == dst."""
    ps = 4
    mgr = PG.PagedCacheManager(6, ps, use_radix=True)
    mgr.begin(1, max_pages=6)
    a, b = list(range(2 * ps)), list(range(100, 100 + 2 * ps))
    for toks in (a, b):
        mgr.admit(0, toks, 0)
        mgr.complete_prefill(0, toks)
        mgr.release(0)
    assert mgr.pages_in_use == 4  # both prompts live only in the tree
    a_pages = mgr.radix.match(a)
    plan = mgr.admit(0, a, 2 * ps)  # need 4: forces eviction of b's leaf
    assert plan.cow and plan.cow[0][0] == a_pages[1]
    src, dst = plan.cow[0]
    assert src != dst
    assert int(mgr.pool.refcount[src]) >= 1  # still alive (tree's ref)
    assert len(mgr.radix.match(b)) == 1  # b's LEAF page paid for the room


def test_begin_recovers_aborted_workload():
    """An exception mid-generate leaves slots admitted; the next workload's
    begin() releases them instead of wedging the engine for good."""
    mgr = PG.PagedCacheManager(8, 4, use_radix=False)
    mgr.begin(2, max_pages=4)
    mgr.admit(0, [1, 2, 3], 4)
    assert mgr.pages_in_use > 0
    mgr.begin(2, max_pages=4)  # no raise; leaked pages returned
    assert mgr.pages_in_use == 0


def test_manager_exhaustion_and_eviction():
    ps = 4
    mgr = PG.PagedCacheManager(4, ps, use_radix=True)
    mgr.begin(2, max_pages=4)
    toks = list(range(2 * ps))
    mgr.admit(0, toks, 0)
    mgr.complete_prefill(0, toks)
    with pytest.raises(PG.PoolExhausted, match="request 9"):
        mgr.admit(1, list(range(100, 100 + 3 * ps)), 0, label="request 9")
    mgr.release(0)  # tree still holds the 2 full pages
    assert mgr.pages_in_use == 2
    # the next admission evicts the tree's pages to make room
    mgr.admit(1, list(range(100, 100 + 3 * ps)), ps)
    assert mgr.pages_in_use == 4 and mgr.radix.pages == 0


# ---------------------------------------------------------------------------
# paged == dense parity
# ---------------------------------------------------------------------------


def test_chunk_step_paged_logit_parity():
    """Direct step parity: chunked prefill + one decode step through the
    page pool == the same through the dense cache (logits and the decode
    step's sampled-from logits), at page sizes that straddle the chunk."""
    cfg, params = setup("llama3.2-1b")
    rng = np.random.default_rng(3)
    b, cp = 2, 4
    lens = [9, 6]
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(b, 9)), jnp.int32)
    for ps in (2, 8):  # page < chunk and page > chunk
        dense = SV.init_cache(cfg, b, 16)
        mgr = PG.PagedCacheManager(16, ps, use_radix=False)
        mgr.begin(b, max_pages=-(-16 // ps))
        for s, n in enumerate(lens):
            mgr.admit(s, [int(t) for t in toks[s, :n]], 16 - n)
        paged = SV.init_paged_cache(cfg, b, 16, ps)
        table = jnp.asarray(mgr.table)
        pfill = np.zeros(b, np.int32)
        plen = np.asarray(lens, np.int32)
        while (pfill < plen).any():
            live = np.clip(plen - pfill, 0, cp)
            idx = np.clip(pfill[:, None] + np.arange(cp)[None], 0, 8)
            chunk = jnp.asarray(np.asarray(toks)[np.arange(b)[:, None], idx])
            ld, dense = SV.chunk_step(cfg, None, params, dense, chunk,
                                      jnp.asarray(pfill), jnp.asarray(live))
            lp, paged = SV.chunk_step(cfg, None, params, paged, chunk,
                                      jnp.asarray(pfill), jnp.asarray(live),
                                      table=table)
            np.testing.assert_allclose(np.asarray(lp), np.asarray(ld),
                                       rtol=1e-5, atol=1e-5)
            pfill += live
        nxt = jnp.argmax(ld[:, : cfg.vocab_size], -1).astype(jnp.int32)[:, None]
        ld2, _ = SV.decode_step(cfg, None, params, dense, {"tokens": nxt},
                                jnp.asarray(plen))
        lp2, _ = SV.decode_step(cfg, None, params, paged, {"tokens": nxt},
                                jnp.asarray(plen), table=table)
        np.testing.assert_allclose(np.asarray(lp2), np.asarray(ld2),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name", ["llama3.2-1b", "falcon-mamba-7b",
                                  "recurrentgemma-9b"])
def test_paged_engine_matches_dense(name):
    """The staggered mixed-length workload (queue > slots, prompts longer
    than the bucket, stop-token finishes) harvests identical ids from the
    paged and dense engines; the paged pool stays within its page bound."""
    cfg, params = setup(name)
    prompts = prompts_for(cfg, (3, 8, 5, 12, 6), seed=0)
    kw = dict(slots=2, bucket=8, max_new_tokens=5, segment=2, prefill_chunk=4)
    ref = DL.ServeEngine(cfg, params, **kw).generate(prompts)
    stop = ref[0][2]

    def trunc(g):
        return g[: g.index(stop) + 1] if stop in g else g

    ref = [trunc(g) for g in ref]
    eng = PG.PagedServeEngine(cfg, params, page_size=4, stop_tokens=(stop,),
                              **kw)
    ref_eng = DL.ServeEngine(cfg, params, stop_tokens=(stop,), **kw)
    assert eng.generate(prompts) == ref_eng.generate(prompts) == ref
    st = eng.last_stats
    assert st["pages_peak"] <= eng.n_pages
    assert eng.compiled_programs()["segment"] == 1


def test_paged_engine_host_streamed():
    """n_host_chunks > 0: pages stream through fori_double_buffered (the
    two-tier path; placement no-ops on CPU) — same ids as dense."""
    from repro.core.parallel import ParallelContext

    cfg, params = setup("llama3.2-1b")
    prompts = prompts_for(cfg, (5, 9, 3), seed=4)
    kw = dict(slots=2, bucket=8, max_new_tokens=4, segment=2, prefill_chunk=4)
    ref = DL.ServeEngine(cfg, params, **kw).generate(prompts)
    eng = PG.PagedServeEngine(cfg, params, page_size=4, n_host_chunks=2,
                              par=ParallelContext(mesh=None), **kw)
    assert eng.generate(prompts) == ref


# ---------------------------------------------------------------------------
# prefix reuse
# ---------------------------------------------------------------------------


def test_prefix_reuse_matches_full_recompute():
    """Requests sharing a long prefix: radix-on output == radix-off output
    == dense output, prefilled-token count drops by the pages actually
    shared, and peak pool usage undercuts the dense-equivalent cache."""
    cfg, params = setup("llama3.2-1b")
    rng = np.random.default_rng(7)
    shared = rng.integers(0, cfg.vocab_size, size=16).tolist()
    prompts = [shared + rng.integers(0, cfg.vocab_size, size=k).tolist()
               for k in (3, 5, 7, 2, 6, 4)]
    # bucket > longest prompt: the dense cache pays slots x bucket rows
    # regardless; the pool only pays pages actually reserved
    kw = dict(slots=2, bucket=32, max_new_tokens=4, segment=2, prefill_chunk=4)
    ref = DL.ServeEngine(cfg, params, **kw).generate(prompts)
    off = PG.PagedServeEngine(cfg, params, page_size=4, n_pages=32,
                              radix=False, **kw)
    on = PG.PagedServeEngine(cfg, params, page_size=4, n_pages=32, **kw)
    assert off.generate(prompts) == ref
    assert on.generate(prompts) == ref
    st_off, st_on = off.last_stats, on.last_stats
    assert st_off["prefix_hit_tokens"] == 0
    # every request after the first finished prefill maps the 4 shared pages
    assert st_on["prefix_hit_tokens"] >= 16 * (len(prompts) - 2)
    assert (st_on["prefilled_tokens"]
            == st_on["prompt_tokens"] - st_on["prefix_hit_tokens"])
    # dense equivalent: slots x ceil(capacity / ps) pages
    dense_pages = kw["slots"] * -(-st_on["capacity"] // 4)
    assert st_on["pages_peak"] < dense_pages
    # the prefix survives for the NEXT workload too (pool persists)
    on.generate(prompts[:2])
    assert on.last_stats["prefix_hit_tokens"] >= 16


def test_engine_cow_on_identical_prompts():
    """Identical prompts with plen % page_size == 0: the radix match covers
    the whole prompt, so the resumed last-token prefill COWs the final
    page — output still equals the dense engine's."""
    cfg, params = setup("llama3.2-1b")
    prompt = prompts_for(cfg, (16,), seed=8)[0]
    prompts = [prompt, prompt, prompt]
    kw = dict(slots=2, bucket=16, max_new_tokens=4, segment=2, prefill_chunk=4)
    ref = DL.ServeEngine(cfg, params, **kw).generate(prompts)
    eng = PG.PagedServeEngine(cfg, params, page_size=4, n_pages=32, **kw)
    assert eng.generate(prompts) == ref
    st = eng.last_stats
    assert st["cow_copies"] >= 1
    assert st["prefix_hit_tokens"] >= 15  # plen - 1 per full-cover hit


# ---------------------------------------------------------------------------
# hardening
# ---------------------------------------------------------------------------


def test_paged_validation_errors():
    cfg, params = setup("llama3.2-1b")
    kw = dict(slots=2, bucket=8, max_new_tokens=4, segment=2)
    with pytest.raises(ValueError, match="prefill_chunk=6 and page_size=4"):
        PG.PagedServeEngine(cfg, params, prefill_chunk=6, page_size=4, **kw)
    with pytest.raises(ValueError, match="page_size must be >= 1"):
        PG.PagedServeEngine(cfg, params, prefill_chunk=4, page_size=0, **kw)
    # a request that could NEVER fit names itself instead of tracing
    eng = PG.PagedServeEngine(cfg, params, prefill_chunk=4, page_size=4,
                              n_pages=2, **kw)
    with pytest.raises(ValueError, match="request 1"):
        eng.generate([[1, 2, 3], [4] * 32])


def test_pool_pressure_defers_not_fails():
    """A pool sized for one request at a time still drains a multi-request
    queue: admission defers while other slots hold pages, and the output
    equals the roomy engine's."""
    cfg, params = setup("llama3.2-1b")
    prompts = prompts_for(cfg, (7, 6, 8), seed=9)
    kw = dict(slots=2, bucket=8, max_new_tokens=4, segment=2, prefill_chunk=4)
    ref = DL.ServeEngine(cfg, params, **kw).generate(prompts)
    eng = PG.PagedServeEngine(cfg, params, page_size=4, n_pages=3,
                              radix=False, **kw)
    assert eng.generate(prompts) == ref
    assert eng.last_stats["deferrals"] > 0


# ---------------------------------------------------------------------------
# spill tier + persistence
# ---------------------------------------------------------------------------


def _rescan_evict_order(nodes, n_pages):
    """Reference model of the RETIRED O(pages^2) eviction: re-collect every
    evictable leaf per freed page, take the min stamp.  ``nodes`` is a
    plain mirror [{page, last_used, parent_idx, alive}]; returns the page
    ids in eviction order (no spill tier: every victim is dropped)."""
    order = []
    while len(order) < n_pages:
        children = {}
        for i, nd in enumerate(nodes):
            if nd["alive"] and nd["parent"] >= 0:
                children.setdefault(nd["parent"], []).append(i)
        cand = [i for i, nd in enumerate(nodes)
                if nd["alive"] and not any(nodes[c]["alive"]
                                           for c in children.get(i, []))]
        if not cand:
            break
        victim = min(cand, key=lambda i: nodes[i]["last_used"])
        nodes[victim]["alive"] = False
        order.append(nodes[victim]["page"])
    return order


@settings(max_examples=25)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n_evict=st.integers(min_value=1, max_value=12))
def test_evict_single_pass_matches_rescan_order(seed, n_evict):
    """Property: the single-pass heap eviction frees exactly the pages the
    retired rescan-per-page algorithm would, in the same order."""
    rng = np.random.default_rng(seed)
    pool = PG.PagePool(24)
    tree = PG.RadixTree(1, pool)
    # random forest: chains off random prefixes, then randomized LRU stamps
    seqs = [rng.integers(0, 4, size=rng.integers(1, 5)).tolist()
            for _ in range(rng.integers(2, 7))]
    for s in seqs:
        have = len(tree.match(s))
        pids = [pool.alloc() for _ in range(len(s) - have)]
        tree.insert(s, tree.match(s)[:have] + pids)
        for p in pids:
            pool.release(p)
    for s in rng.permutation(len(seqs)):
        tree.match(seqs[s])  # scramble recency
    # mirror the live tree into the plain reference structure
    mirror, idx_of = [], {}
    stack = [(tree.root, -1)]
    while stack:
        nd, pidx = stack.pop()
        if nd is not tree.root:
            idx_of[id(nd)] = len(mirror)
            mirror.append({"page": nd.page, "last_used": nd.last_used,
                           "parent": pidx, "alive": True})
        me = idx_of.get(id(nd), -1)
        stack.extend((c, me) for c in nd.children.values())
    want = _rescan_evict_order(mirror, n_evict)
    got = []
    orig = pool.release
    pool.release = lambda pid: (got.append(pid), orig(pid))[1]
    try:
        freed = tree.evict(n_evict)
    finally:
        pool.release = orig
    assert got == want and freed == len(want)


def test_spill_pool_and_radix_demotion():
    """Radix-level tier mechanics: eviction demotes payloads host-side
    through read_page, spilled nodes match (as -1) without dying, insert
    re-points a spilled twin at a fresh device page, and a full tier
    degrades to dropping leaves — never a node with spilled children."""
    pool = PG.PagePool(8)
    spill = PG.SpillPool(2)
    tree = PG.RadixTree(2, pool, spill=spill)
    reads = []
    tree.read_page = lambda pid: (reads.append(pid),
                                  {"pk": np.full(3, pid, np.float32)})[1]
    chains = {"a": [1, 2, 3, 4], "b": [5, 6], "c": [7, 8]}
    pids = {}
    for k, toks in chains.items():
        ps_ = [pool.alloc() for _ in range(len(toks) // 2)]
        tree.insert(toks, ps_)
        pids[k] = ps_
        for p in ps_:
            pool.release(p)
    tree.match(chains["a"])  # a is freshest; b, c are LRU
    assert tree.evict(2) == 2  # demotes b's and c's leaves
    assert sorted(reads) == sorted([pids["b"][0], pids["c"][0]])
    assert tree.spilled == 2 and tree.pages == 2
    assert tree.match(chains["b"]) == [-1]  # spilled, still matchable
    assert np.all(spill.read(tree.match_nodes(chains["b"])[0].spill)["pk"]
                  == pids["b"][0])
    # tier is full: next eviction DROPS the leaf, keeps spilled-child parents
    assert tree.evict(2) == 2  # a's chain: leaf dropped, then its parent
    assert tree.pages == 0 and tree.match(chains["a"]) == []
    # re-prefill of b: the spilled twin is re-pointed, host copy freed
    fresh = pool.alloc()
    assert tree.insert(chains["b"], [fresh]) == 1
    assert tree.match(chains["b"]) == [fresh] and tree.spilled == 1
    pool.release(fresh)
    # misuse raises
    with pytest.raises(ValueError, match="n_spill"):
        PG.SpillPool(0)
    sid = spill.alloc()
    spill.free(sid)
    with pytest.raises(ValueError, match="unallocated"):
        spill.free(sid)


def test_spill_demote_promote_engine_parity():
    """End-to-end tier round-trip: a tight pool demotes the radix pages of
    workload A while B runs, re-serving A promotes them back — outputs
    stay identical to the dense engine across all three workloads, and the
    compiled set stays {segment, reset, copy, promote}, each <= 1."""
    cfg, params = setup("llama3.2-1b")
    rng = np.random.default_rng(11)
    shared = rng.integers(0, cfg.vocab_size, size=16).tolist()
    wl_a = [shared + rng.integers(0, cfg.vocab_size, size=k).tolist()
            for k in (3, 5)]
    wl_b = [rng.integers(0, cfg.vocab_size, size=20).tolist()
            for _ in range(3)]
    kw = dict(slots=2, bucket=24, max_new_tokens=4, segment=2,
              prefill_chunk=4)
    dense = DL.ServeEngine(cfg, params, **kw)
    ref = [dense.generate(w) for w in (wl_a, wl_b, wl_a)]
    eng = PG.PagedServeEngine(cfg, params, page_size=4, n_pages=16,
                              spill_pages=32, **kw)
    got = [eng.generate(w) for w in (wl_a, wl_b, wl_a)]
    assert got == ref
    st = eng.last_stats
    assert st["spill_promotes"] > 0, st  # pages came back from the tier
    assert st["prefix_hit_tokens"] >= 16, st
    progs = eng.compiled_programs()
    assert set(progs) == {"segment", "reset", "copy", "promote"}
    assert all(v <= 1 for v in progs.values()), progs
    assert progs["promote"] == 1, progs


def test_kv_store_save_restore_roundtrip(tmp_path):
    """Persistence: a fresh engine restored from ``save_kv_store`` serves
    the saved prefixes as radix hits (promoted from the spill tier) with
    outputs identical to the engine that built the cache, and restore
    validates page_size / spill-tier preconditions."""
    cfg, params = setup("llama3.2-1b")
    rng = np.random.default_rng(13)
    shared = rng.integers(0, cfg.vocab_size, size=16).tolist()
    prompts = [shared + rng.integers(0, cfg.vocab_size, size=k).tolist()
               for k in (3, 6)]
    kw = dict(slots=2, bucket=24, max_new_tokens=4, segment=2,
              prefill_chunk=4, page_size=4, n_pages=16)
    eng = PG.PagedServeEngine(cfg, params, spill_pages=8, **kw)
    want = eng.generate(prompts)
    store = str(tmp_path / "kv.npz")
    saved = eng.save_kv_store(store)
    assert saved == eng.kv.radix.pages + eng.kv.spilled_pages > 0
    eng2 = PG.PagedServeEngine(cfg, params, spill_pages=32, **kw)
    assert eng2.restore_kv_store(store) == saved
    assert eng2.kv.spilled_pages == saved  # restored pages start host-side
    got = eng2.generate(prompts)
    assert got == want
    st = eng2.last_stats
    assert st["prefix_hit_tokens"] >= 16, st  # the shared prefix radix-hit
    assert st["spill_promotes"] > 0, st
    assert eng2.compiled_programs()["promote"] == 1
    # validation: a mismatched pool geometry must refuse loudly
    with pytest.raises(ValueError, match="page_size"):
        PG.PagedServeEngine(cfg, params, spill_pages=8,
                            **dict(kw, page_size=8,
                                   prefill_chunk=8)).restore_kv_store(store)
    with pytest.raises(ValueError, match="spill"):
        PG.PagedServeEngine(cfg, params, **kw).restore_kv_store(store)
    # save with live device pages needs the engine's page reader: the raw
    # manager without one refuses rather than writing garbage
    bare = PG.PagedCacheManager(8, 4)
    bare.begin(1, 4)
    bare.admit(0, list(range(8)), 0)
    bare.complete_prefill(0, list(range(8)))
    with pytest.raises(ValueError, match="read_page"):
        bare.save(str(tmp_path / "bare.npz"))
    # extension dtypes (bfloat16 pools) survive the npz round-trip: npz
    # would otherwise store them as opaque void and restore would crash
    import ml_dtypes
    pool = PG.PagePool(4)
    tree = PG.RadixTree(2, pool, spill=PG.SpillPool(4))
    pid = pool.alloc()
    tree.insert([9, 9], [pid])
    pool.release(pid)
    payload = {"pk": np.arange(6, dtype=ml_dtypes.bfloat16).reshape(2, 3)}
    bf_store = str(tmp_path / "bf16.npz")
    tree.save(bf_store, lambda _pid: payload)
    tree2 = PG.RadixTree(2, PG.PagePool(4), spill=PG.SpillPool(4))
    assert tree2.restore(bf_store) == 1
    got = tree2.spill.read(tree2.match_nodes([9, 9])[0].spill)
    assert got["pk"].dtype == payload["pk"].dtype
    assert np.array_equal(got["pk"], payload["pk"])


def test_dispatch_failure_releases_slots_spill_survives(monkeypatch):
    """Satellite: a dispatch exception mid-generate leaves slots admitted;
    the next workload's begin() releases their pages while radix-indexed
    AND spilled pages survive — the engine un-wedges without losing the
    prefix cache."""
    cfg, params = setup("llama3.2-1b")
    rng = np.random.default_rng(17)
    shared = rng.integers(0, cfg.vocab_size, size=16).tolist()
    prompts = [shared + rng.integers(0, cfg.vocab_size, size=k).tolist()
               for k in (3, 5)]
    evictors = [rng.integers(0, cfg.vocab_size, size=20).tolist()
                for _ in range(3)]
    kw = dict(slots=2, bucket=24, max_new_tokens=4, segment=2,
              prefill_chunk=4)
    ref_eng = DL.ServeEngine(cfg, params, **kw)
    ref = [ref_eng.generate(w) for w in (prompts, evictors, prompts)]
    eng = PG.PagedServeEngine(cfg, params, page_size=4, n_pages=16,
                              spill_pages=32, **kw)
    assert eng.generate(prompts) == ref[0]
    assert eng.generate(evictors) == ref[1]  # pressure demotes A's prefix
    spilled = eng.kv.spilled_pages
    radix = eng.kv.radix.pages
    assert spilled > 0
    orig = PG.PagedServeEngine._dispatch

    def boom(self, *a, **k):
        raise RuntimeError("injected dispatch failure")

    monkeypatch.setattr(PG.PagedServeEngine, "_dispatch", boom)
    with pytest.raises(RuntimeError, match="injected"):
        eng.generate(prompts)
    # slots stayed admitted (the failure skipped release)
    assert any(eng.kv._slot_pages), "failure should leave admitted slots"
    monkeypatch.setattr(PG.PagedServeEngine, "_dispatch", orig)
    out = eng.generate(prompts)  # begin() releases the wedged slots
    assert out == ref[2]
    st = eng.last_stats
    assert st["prefix_hit_tokens"] >= 16, st  # prefix cache survived
    assert radix + spilled >= 1  # sanity on the pre-failure snapshot
    # no tier-slot leak: every used spill slot is owned by exactly one
    # live tree node (the failed workload's promotes freed their slots)
    owners = []
    stack = [eng.kv.radix.root]
    while stack:
        nd = stack.pop()
        owners.extend(c.spill for c in nd.children.values() if c.spill >= 0)
        stack.extend(nd.children.values())
    assert sorted(owners) == sorted(set(owners))
    assert len(owners) == eng.kv.spilled_pages


# ---------------------------------------------------------------------------
# program-size / acceptance (slow)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_paged_program_flat_in_n_pages():
    """Acceptance bar: the paged mixed-step program neither grows nor
    multiplies from n_pages 32 -> 512, and the engine's compiled-program
    set does not grow on a re-run."""
    import os
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks import serve_bench as SB

    small, big = (SB.measure_paged(n, 8) for n in (32, 512))
    assert big["jaxpr_eqns"] <= small["jaxpr_eqns"]
    assert big["hlo_ops"] <= 1.01 * small["hlo_ops"]

    r = SB.shared_prefix_workload(prefix_len=1024, requests=8)
    assert r["programs"] == r["programs_before"], r
    assert r["programs"]["segment"] == 1
    # prefilled tokens drop by the shared fraction (every request past the
    # pipelined first wave skips the full prefix pages)
    assert r["hit_rate"] > 0.5, r
    assert r["pages_peak"] < r["dense_equiv_pages"], r
