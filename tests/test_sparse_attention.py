"""Block-sparse FPDT attention (paper §5.6 / Table 4)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import fpdt
from repro.core.parallel import ParallelContext
from repro.models import layers as L


def _setup():
    cfg = dataclasses.replace(reduced(get_config("llama3.2-1b")),
                              param_dtype="float32", block_q=8, block_k=8)
    key = jax.random.PRNGKey(0)
    p = L.init_attn(cfg, key, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, 64, cfg.d_model), jnp.float32)
    return cfg, p, x


def _run(cfg, p, x, u, sparsity):
    c = dataclasses.replace(cfg, fpdt_chunks=u, attn_sparsity=sparsity)
    par = ParallelContext(mesh=None)

    def f(x, p):
        o = fpdt.fpdt_attention(c, par, p, x, kind="local")
        return (o ** 2).sum(), o

    (v, o), g = jax.value_and_grad(f, argnums=(0, 1), has_aux=True)(x, p)
    return o, g


def test_zero_sparsity_is_dense():
    cfg, p, x = _setup()
    o0, g0 = _run(cfg, p, x, 8, 0.0)
    o1, g1 = _run(cfg, p, x, 1, 0.0)
    np.testing.assert_allclose(np.asarray(o0), np.asarray(o1), rtol=2e-4, atol=2e-4)


def test_sparse_runs_and_differs():
    cfg, p, x = _setup()
    o_dense, _ = _run(cfg, p, x, 8, 0.0)
    o_sparse, g = _run(cfg, p, x, 8, 0.5)
    assert np.isfinite(np.asarray(o_sparse)).all()
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()
    # off-diagonal chunks skipped -> later positions see different context
    assert not np.allclose(np.asarray(o_sparse[:, 32:]), np.asarray(o_dense[:, 32:]))
    # first chunk (diagonal only) identical
    np.testing.assert_allclose(np.asarray(o_sparse[:, :8]), np.asarray(o_dense[:, :8]),
                               rtol=2e-4, atol=2e-4)


def test_sparsity_skips_pairs():
    """Live-pair count matches the stride rule."""
    for u, sp in ((8, 0.5), (8, 0.75), (4, 0.5)):
        stride = max(1, round(1.0 / (1.0 - sp)))
        live = sum(1 for i in range(u) for j in range(i + 1)
                   if j == i or (i - j - 1) % stride == 0)
        full = u * (u + 1) // 2
        assert live < full
