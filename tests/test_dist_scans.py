"""Sequence-parallel scan algorithms (§Perf A2/A3) vs their serial oracles.
Property tests run on a fixed-seed grid when hypothesis isn't installed
(see tests/_hypothesis_compat.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st

from repro.kernels.linear_scan import ref as LSR
from repro.models import mamba as M
from repro.models.rglru import dist_linear_scan


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.sampled_from([1, 2, 4, 8]))
def test_dist_linear_scan_matches_serial(seed, n):
    rng = np.random.default_rng(seed)
    b, s, c = 2, 16, 4
    a = jnp.asarray(rng.uniform(-0.95, 0.95, (b, s, c)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((b, s, c)), jnp.float32)
    h0 = jnp.asarray(rng.standard_normal((b, c)), jnp.float32)
    want = LSR.linear_scan_naive(a, x, h0)
    got = dist_linear_scan(a, x, n, h0)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n", [2, 4, 8])
@pytest.mark.parametrize("with_h0", [False, True])
def test_selective_scan_dist_matches_serial(rng, n, with_h0):
    b, s, di, ds = 2, 32, 8, 4
    xc = jnp.asarray(rng.standard_normal((b, s, di)) * 0.3, jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(rng.standard_normal((b, s, di)), jnp.float32))
    A_log = jnp.asarray(np.log(rng.uniform(0.5, 2.0, (di, ds))), jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, s, ds)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, s, ds)), jnp.float32)
    h0 = (jnp.asarray(rng.standard_normal((b, di, ds)) * 0.5, jnp.float32)
          if with_h0 else None)
    y0, hl0 = M.selective_scan(xc, dt, A_log, B, C, h0, block_s=8)
    y1, hl1 = M.selective_scan_dist(xc, dt, A_log, B, C, h0, n_shards=n, block_s=4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hl1), np.asarray(hl0), rtol=2e-4, atol=2e-4)


def test_selective_scan_dist_grads(rng):
    b, s, di, ds = 1, 16, 4, 2
    xc = jnp.asarray(rng.standard_normal((b, s, di)) * 0.3, jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(rng.standard_normal((b, s, di)), jnp.float32))
    A_log = jnp.asarray(np.log(rng.uniform(0.5, 2.0, (di, ds))), jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, s, ds)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, s, ds)), jnp.float32)

    def f_serial(xc, dt):
        return M.selective_scan(xc, dt, A_log, B, C, block_s=4)[0].sum()

    def f_dist(xc, dt):
        return M.selective_scan_dist(xc, dt, A_log, B, C, n_shards=4, block_s=4)[0].sum()

    g0 = jax.grad(f_serial, argnums=(0, 1))(xc, dt)
    g1 = jax.grad(f_dist, argnums=(0, 1))(xc, dt)
    for a, b_ in zip(g0, g1):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-3, atol=1e-3)
