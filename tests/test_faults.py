"""Fault-tolerant serve tier: deterministic fault injection + failover.

Three layers, cheapest first:

* the fault harness itself (`launch/faults.py`) — plans parse, faults
  fire on exactly the scripted dispatch, heal() clears them;
* router failover semantics on fake replicas (`launch/router.py`) —
  health state machine, bounded retry for transients, timeout-as-fault,
  minimal key movement under death/rejoin, no silent data loss;
* the acceptance scenario on REAL paged engines: a seeded mid-workload
  permanent crash of 1 of 2 replicas completes ALL requests with
  outputs identical to the no-fault run (greedy), re-homed requests
  recover their prefixes through the shared KV store
  (`prefix_hit_tokens > 0`, not a cold recompute), and the compiled
  program set stays {segment, reset, copy, promote} <= 1 per replica.
"""
import dataclasses
import functools

import pytest

from repro.launch.faults import (Fault, FaultInjected, FaultyReplica,
                                 parse_fault_plan)
from repro.launch.router import (AllReplicasDead, IncompleteGeneration,
                                 ReplicaRouter)


# ---------------------------------------------------------------------------
# harness (no jax)
# ---------------------------------------------------------------------------


class Echo:
    """Minimal replica: returns [first_token, len] per prompt."""

    def __init__(self):
        self.calls = []
        self.last_stats = {"prompt_tokens": 0, "prefix_hit_tokens": 0}

    def generate(self, prompts):
        self.calls.append(list(prompts))
        self.last_stats = {
            "prompt_tokens": sum(len(p) for p in prompts),
            "prefix_hit_tokens": 0}
        return [[p[0], len(p)] for p in prompts]


def test_fault_plan_parses():
    plan = parse_fault_plan("1:raise@2; 0:transient@1x3 ;2:hang@0~0.25")
    assert plan[1] == [Fault("raise", 2)]
    assert plan[0] == [Fault("transient", 1, count=3)]
    assert plan[2] == [Fault("hang", 0, hang_s=0.25)]
    with pytest.raises(ValueError, match="fault-plan"):
        parse_fault_plan("1-raise-2")
    with pytest.raises(ValueError, match="kind"):
        parse_fault_plan("0:explode@1")


def test_faults_fire_on_scripted_dispatch_only():
    rep = FaultyReplica(Echo(), [Fault("transient", 1, count=2)])
    assert rep.generate([[7]]) == [[7, 1]]          # dispatch 0: fine
    for _ in range(2):                              # dispatches 1, 2: fault
        with pytest.raises(FaultInjected, match="transient"):
            rep.generate([[7]])
    assert rep.generate([[7]]) == [[7, 1]]          # dispatch 3: recovered
    assert (rep.dispatches, rep.injected) == (4, 2)


def test_permanent_raise_until_heal():
    rep = FaultyReplica(Echo(), [Fault("raise", 0)])
    for _ in range(3):
        with pytest.raises(FaultInjected, match="raise"):
            rep.generate([[1]])
    rep.heal()
    assert rep.generate([[1]]) == [[1, 1]]


def test_wrapper_passes_everything_else_through():
    rep = FaultyReplica(Echo())
    rep.generate([[5, 6]])
    assert rep.last_stats["prompt_tokens"] == 2  # inner attr via __getattr__


# ---------------------------------------------------------------------------
# router failover on fakes (no jax)
# ---------------------------------------------------------------------------


def quiet(msg):  # the one-shot degradation warning, silenced for tests
    pass


def faulted_router(fault, n=2, prompts=(), **kw):
    """A router whose fault lands on a replica that actually OWNS work
    (rendezvous homes depend on the keys, so a fixed index would make
    the test a coin flip)."""
    reps = [FaultyReplica(Echo()) for _ in range(n)]
    rt = ReplicaRouter(reps, warn=quiet, **kw)
    victim = rt.home_of(prompts[0]) if prompts else 0
    reps[victim].faults.append(fault)
    return rt, victim


def test_transient_fault_retries_without_rehoming():
    prompts = [[i, i, i] for i in range(6)]
    rt, _ = faulted_router(Fault("transient", 0), prompts=prompts,
                           max_retries=2)
    out = rt.generate(prompts)
    assert out == [[p[0], 3] for p in prompts]
    fo = rt.last_stats["failover"]
    assert fo["deaths"] == 0 and fo["rehomed_requests"] == 0
    assert fo["retries"] >= 1
    assert rt.health == ["healthy", "healthy"]  # suspect cleared on success


def test_retry_budget_exhaustion_is_death():
    prompts = [[i, i, i] for i in range(6)]
    rt, victim = faulted_router(Fault("transient", 0, count=5),
                                prompts=prompts, max_retries=1)
    out = rt.generate(prompts)
    fo = rt.last_stats["failover"]
    assert fo["deaths"] == 1 and rt.health[victim] == "dead"
    assert fo["rehomed_requests"] > 0
    assert all(len(o) == 2 for o in out)  # work still completed elsewhere


def test_hang_past_deadline_counts_as_fault():
    """A stalled dispatch (deterministic sleep) exceeds the timeout; its
    late result is discarded, the retry lands after the hang window."""
    prompts = [[i, i, i] for i in range(6)]
    rt, _ = faulted_router(Fault("hang", 0, hang_s=0.2), prompts=prompts,
                           dispatch_timeout=0.05, max_retries=1)
    out = rt.generate(prompts)
    assert rt.timeouts >= 1
    assert all(len(o) == 2 for o in out)
    assert rt.last_stats["failover"]["deaths"] == 0  # retry succeeded


def test_death_moves_only_the_dead_replicas_keys():
    """Rendezvous hashing: a dead replica's keys re-home; every key whose
    home survives KEEPS it (survivors keep their radix locality), and
    rejoin() moves the dead replica's keys back."""
    rt = ReplicaRouter([Echo() for _ in range(4)], warn=quiet)
    keys = [[i, i + 1, i + 2, i + 3] for i in range(64)]
    before = [rt.home_of(k) for k in keys]
    dead = before[0]  # kill a replica that actually owns keys
    rt.health[dead] = rt.DEAD
    after = [rt.home_of(k) for k in keys]
    moved = sum(1 for b, a in zip(before, after) if b != a)
    owned = sum(1 for b in before if b == dead)
    assert moved == owned > 0  # exactly the dead replica's range moved
    rt.rejoin(dead)
    assert [rt.home_of(k) for k in keys] == before


def test_all_replicas_dead_raises_with_clean_depth():
    reps = [FaultyReplica(Echo(), [Fault("raise", 0)]) for _ in range(2)]
    rt = ReplicaRouter(reps, max_retries=0, warn=quiet)
    with pytest.raises(AllReplicasDead):
        rt.generate([[1, 2], [3, 4], [5, 6]])
    assert rt.depth == [0, 0]  # no phantom queue slots for the next run


def test_short_output_is_not_silent_data_loss():
    """Regression (satellite 1): a replica returning too few outputs used
    to surface as [] for the missing requests — indistinguishable from a
    genuine empty generation.  Now it is a dispatch fault; with nowhere
    to fail over to, it raises instead of dropping data."""

    class Short(Echo):
        def generate(self, prompts):
            super().generate(prompts)
            return [[0]] * (len(prompts) - 1)

    rt = ReplicaRouter([Short()], max_retries=0, warn=quiet)
    with pytest.raises(AllReplicasDead):
        rt.generate([[1], [2]])
    # and with a healthy sibling, the work re-homes instead
    rt2 = ReplicaRouter([Short(), Echo()], max_retries=0, warn=quiet)
    out = rt2.generate([[i, i] for i in range(4)])
    assert all(len(o) == 2 for o in out)


def test_incomplete_generation_names_missing_requests():
    err = IncompleteGeneration([3, 5], total=8)
    assert err.missing == [3, 5]
    assert "2/8" in str(err)


def test_one_shot_degradation_warning():
    warned = []
    reps = [FaultyReplica(Echo()) for _ in range(3)]
    rt = ReplicaRouter(reps, max_retries=0, warn=warned.append)
    prompts = [[i, i + 1, i + 2] for i in range(24)]
    homes = {rt.home_of(p) for p in prompts}
    assert len(homes) >= 2  # need two owners so two deaths can happen
    for victim in sorted(homes)[:2]:
        reps[victim].faults.append(Fault("raise", 0))
    out = rt.generate(prompts)
    assert rt.last_stats["failover"]["deaths"] == 2
    assert all(len(o) == 2 for o in out)
    assert len(warned) == 1  # first death warns, later deaths stats-only


def test_failover_false_keeps_legacy_raise():
    from repro.launch.router import ReplicaFailed

    reps = [Echo(), FaultyReplica(Echo(), [Fault("raise", 0)])]
    rt = ReplicaRouter(reps, policy="rr", failover=False)
    with pytest.raises(ReplicaFailed, match="replica 1"):
        rt.generate([[1], [2]])
    assert rt.depth == [0, 0]


def test_qos_requests_survive_rehoming_intact():
    """Satellite 3: Request objects (sessions, priorities, budgets) pass
    through re-homing UNTOUCHED — the survivor receives the exact same
    objects the dead replica would have."""
    from repro.runtime import decode_loop as DL

    seen = {}

    class Capture(Echo):
        def __init__(self, tag):
            super().__init__()
            self.tag = tag

        def generate(self, prompts):
            seen.setdefault(self.tag, []).extend(prompts)
            return super().generate(
                [list(p.tokens) for p in prompts])

    reqs = [DL.Request(tokens=(i, i + 1, i + 2), priority=i % 2,
                       arrival=i, itl_slo=4.0 + i, prefill_chunks=2,
                       tier="interactive", session=f"tenant-{i % 3}")
            for i in range(9)]
    reps = [FaultyReplica(Capture(0)), FaultyReplica(Capture(1))]
    rt = ReplicaRouter(reps, max_retries=0, warn=quiet)
    victim = rt.home_of(reqs[0], reqs[0].session)
    reps[victim].faults.append(Fault("raise", 0))
    out = rt.generate(reqs)  # sessions read off the requests themselves
    fo = rt.last_stats["failover"]
    assert fo["deaths"] == 1 and fo["rehomed_requests"] > 0
    assert fo["rehomed_sessions"] >= 1
    assert all(len(o) == 2 for o in out)
    # every request object reached the surviving replica by IDENTITY: QoS
    # fields (priority, arrival, itl_slo, prefill_chunks, tier) cannot
    # have been rewritten en route
    assert {id(r) for r in reqs} == {id(p) for p in seen[1 - victim]}


def test_session_affinity_reads_request_objects():
    from repro.runtime import decode_loop as DL

    rt = ReplicaRouter([Echo() for _ in range(4)], warn=quiet)
    same = [DL.Request(tokens=(i,), session="tenant-A") for i in range(8)]
    assert len({rt.route(r) for r in same}) == 1  # one session, one home


# ---------------------------------------------------------------------------
# acceptance: real engines, shared store, token-identical recovery
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=1)
def setup():
    import jax

    from repro.configs import get_config, reduced
    from repro.models import transformer as T

    cfg = dataclasses.replace(reduced(get_config("llama3.2-1b")),
                              param_dtype="float32", remat="none")
    return cfg, T.init_params(cfg, jax.random.PRNGKey(0))


def make_engine(cfg, params):
    from repro.runtime import paged as PG

    return PG.PagedServeEngine(cfg, params, slots=2, bucket=24,
                               max_new_tokens=4, page_size=4, segment=1,
                               spill_pages=32)


def session_workload(cfg, seed=0):
    """Two rounds of the same per-session prompts (each session's round-2
    request shares its round-1 prefix), shared 8-token system prompt."""
    import numpy as np

    rng = np.random.default_rng(seed)
    V = cfg.vocab_size
    shared = [int(t) for t in rng.integers(0, V, 8)]
    prompts, sessions = [], []
    for s in range(4):
        body = [int(t) for t in rng.integers(0, V, 8)]
        prompts.append(shared + body)
        sessions.append(f"tenant-{s}")
    return prompts, sessions


def run_rounds(router, prompts, sessions):
    return [router.generate(prompts, sessions=sessions) for _ in range(2)]


@pytest.mark.slow
def test_seeded_crash_completes_all_token_identical(tmp_path):
    """THE acceptance scenario: warm round, then a permanent crash of 1
    of 2 replicas mid-workload (its 2nd dispatch).  Every request
    completes, outputs == the no-fault run token for token (greedy), the
    re-homed sessions recover their prefixes through the shared store
    (prefix_hit_tokens > 0 on the re-home dispatch), and no engine
    compiled anything beyond {segment, reset, copy, promote}."""
    from repro.launch.kvstore import SharedKVStore

    cfg, params = setup()
    prompts, sessions = session_workload(cfg)

    # no-fault reference: fresh engines, same two rounds
    ref_router = ReplicaRouter([make_engine(cfg, params) for _ in range(2)],
                               warn=quiet)
    ref = run_rounds(ref_router, prompts, sessions)

    # fault run: same construction + a scripted permanent crash
    engines = [make_engine(cfg, params) for _ in range(2)]
    store = SharedKVStore(str(tmp_path / "shared"))
    rt = ReplicaRouter(engines, max_retries=1, kv_store=store, warn=quiet)
    victim = rt.home_of(prompts[0], sessions[0])
    rt.replicas[victim] = FaultyReplica(
        engines[victim], [Fault("raise", 1)], name=f"replica{victim}")
    got = run_rounds(rt, prompts, sessions)

    assert got == ref, "failover must be invisible in the outputs"
    fo = rt.last_stats["failover"]
    assert fo["deaths"] == 1 and rt.health[victim] == "dead"
    assert fo["rehomed_requests"] > 0 and fo["rehomed_sessions"] > 0
    # recovery, not recompute: the re-homed dispatch promoted the dead
    # replica's published pages out of the shared store
    assert fo["recovered_pages"] > 0
    assert fo["recovered_prefix_tokens"] > 0
    # bounded program set on every engine, fault path included
    for eng in engines:
        progs = eng.compiled_programs()
        assert set(progs) <= {"segment", "reset", "copy", "promote"}
        assert all(v <= 1 for v in progs.values()), progs


@pytest.mark.slow
def test_rejoin_restores_home_and_warm_cache(tmp_path):
    """After a crash, rejoin() re-admits the replica: its sessions route
    home again and its own published cache restores into it, so the
    first post-rejoin round is warm (prefix hits on its own engine)."""
    from repro.launch.kvstore import SharedKVStore

    cfg, params = setup()
    prompts, sessions = session_workload(cfg, seed=1)
    engines = [make_engine(cfg, params) for _ in range(2)]
    store = SharedKVStore(str(tmp_path / "shared"))
    rt = ReplicaRouter(engines, max_retries=0, kv_store=store, warn=quiet)
    victim = rt.home_of(prompts[0], sessions[0])
    faulty = FaultyReplica(engines[victim], [Fault("raise", 1)],
                           name=f"replica{victim}")
    rt.replicas[victim] = faulty
    ref_router = ReplicaRouter([make_engine(cfg, params) for _ in range(2)],
                               warn=quiet)
    ref = run_rounds(ref_router, prompts, sessions)
    got = run_rounds(rt, prompts, sessions)
    assert got == ref
    assert rt.health[victim] == "dead"

    # the 'process' comes back as a FRESH engine (a real restart loses
    # device state — only the published store survives) behind the same
    # router seat
    engines[victim] = make_engine(cfg, params)
    faulty.inner = engines[victim]
    faulty.heal()
    restored = rt.rejoin(victim)
    assert rt.health[victim] == "healthy"
    assert restored > 0, "rejoin should reload the replica's own cache"
    assert rt.home_of(prompts[0], sessions[0]) == victim  # keys moved back
    out3 = rt.generate(prompts, sessions=sessions)
    assert out3 == ref[1]  # steady-state round, token-identical
    hit = rt.last_stats["per_replica"][victim].get("prefix_hit_tokens", 0)
    assert hit > 0, "rejoined replica must serve its sessions warm"
