"""Heavy-tailed traffic simulator (`benchmarks/serve_bench.py::
traffic_trace`): seeded determinism (in-process and across OS processes),
arrival-time monotonicity and Zipf prefix-share frequencies as
properties (via the ``tests/_hypothesis_compat`` shim), tier/length/
burst structure sanity."""
import hashlib
import os
import subprocess
import sys

import numpy as np

from tests._hypothesis_compat import given, settings, strategies as st

ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, ROOT)

from benchmarks import serve_bench as SB  # noqa: E402


def trace_digest(trace) -> str:
    """Stable fingerprint of a trace: every field of every request."""
    blob = repr([(r.idx, r.arrival, r.tokens, r.prefix_id, r.tier,
                  r.priority, r.ttft_slo, r.itl_slo, r.prefill_chunks)
                 for r in trace])
    return hashlib.sha256(blob.encode()).hexdigest()


def test_same_seed_same_trace():
    a = SB.traffic_trace(seed=7, n_requests=40)
    b = SB.traffic_trace(seed=7, n_requests=40)
    assert a == b
    assert SB.traffic_trace(seed=8, n_requests=40) != a


def test_cross_process_determinism():
    """The same seed yields a byte-identical trace in a fresh OS process —
    the generator leans only on ``numpy.random.default_rng`` (PCG64), not
    process-salted ``hash`` or global RNG state."""
    here = trace_digest(SB.traffic_trace(seed=11, n_requests=30))
    prog = (
        "import sys; sys.path[:0] = [r'{root}', r'{src}']\n"
        "from benchmarks import serve_bench as SB\n"
        "from tests.test_traffic_sim import trace_digest\n"
        "print(trace_digest(SB.traffic_trace(seed=11, n_requests=30)))\n"
    ).format(root=os.path.abspath(ROOT),
             src=os.path.abspath(os.path.join(ROOT, "src")))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.abspath(ROOT), os.path.abspath(os.path.join(ROOT, "src"))])
    got = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=120)
    assert got.returncode == 0, got.stderr
    assert got.stdout.strip() == here


@settings(max_examples=8)
@given(seed=st.integers(min_value=0, max_value=10_000),
       rate=st.floats(min_value=0.05, max_value=2.0))
def test_arrivals_monotone(seed, rate):
    trace = SB.traffic_trace(seed=seed, n_requests=30, rate=rate)
    arr = [r.arrival for r in trace]
    assert all(a >= 0 and isinstance(a, int) for a in arr)
    assert all(b >= a for a, b in zip(arr, arr[1:])), "arrivals must be " \
        "non-decreasing in request order"
    assert [r.idx for r in trace] == list(range(30))


@settings(max_examples=6)
@given(seed=st.integers(min_value=0, max_value=1_000),
       zipf_a=st.sampled_from([0.8, 1.1, 1.5]))
def test_zipf_prefix_shares(seed, zipf_a):
    """Observed prefix frequencies track the 1/rank^a weights: the top
    prefix's share lands within a generous tolerance of its weight, and
    rank 0 strictly dominates the tail rank."""
    n, n_prefixes = 400, 4
    trace = SB.traffic_trace(seed=seed, n_requests=n,
                             n_prefixes=n_prefixes, zipf_a=zipf_a)
    w = np.array([1.0 / (k + 1) ** zipf_a for k in range(n_prefixes)])
    w /= w.sum()
    counts = np.bincount([r.prefix_id for r in trace], minlength=n_prefixes)
    assert counts.sum() == n
    assert abs(counts[0] / n - w[0]) < 0.12
    assert counts[0] > counts[-1], "Zipf head must dominate the tail"


def test_tiers_and_lengths():
    """Every request inherits its tier's QoS contract, and its unique tail
    length stays inside the tier's [lo, hi] band."""
    trace = SB.traffic_trace(seed=1, n_requests=200, prefix_len=8)
    tiers = {t.name: t for t in SB.DEFAULT_TIERS}
    by_tier = {}
    for r in trace:
        t = tiers[r.tier]
        assert r.priority == t.priority
        assert r.ttft_slo == t.ttft_slo and r.itl_slo == t.itl_slo
        assert r.prefill_chunks == t.prefill_chunks
        tail = len(r.tokens) - 8
        assert t.tail_lo <= tail <= t.tail_hi, (r.tier, tail)
        by_tier[r.tier] = by_tier.get(r.tier, 0) + 1
    # 0.7/0.3 split: interactive dominates over 200 draws
    assert by_tier["interactive"] > by_tier["batch"]


def test_prefix_sharing_is_real():
    """Requests with the same prefix_id open with the same tokens — the
    radix tree's hit substrate — and sharing actually occurs."""
    trace = SB.traffic_trace(seed=2, n_requests=50, prefix_len=8)
    heads = {}
    for r in trace:
        head = r.tokens[:8]
        assert heads.setdefault(r.prefix_id, head) == head
    counts = np.bincount([r.prefix_id for r in trace])
    assert counts.max() >= 2, "Zipf sharing must produce repeated prefixes"


def test_bursts_cluster_arrivals():
    """With burst_p=1 every gap delivers burst_k simultaneous requests:
    arrivals come in equal-valued runs of burst_k."""
    trace = SB.traffic_trace(seed=3, n_requests=12, burst_p=1.0, burst_k=3)
    arr = [r.arrival for r in trace]
    for g in range(0, 12, 3):
        assert len({arr[g], arr[g + 1], arr[g + 2]}) == 1, arr
