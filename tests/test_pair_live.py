"""Property tests for the FPDT chunk-liveness predicate.

``pair_live`` (static, unrolled path) and ``pair_live_traced`` (jnp, scan
path) must agree everywhere, and the window semantics must equal the dense
token-level mask: a chunk pair is live iff at least one (q, k) token pair
inside it survives the causal+window band.  Runs under real hypothesis when
installed, else the deterministic fixed grid (tests/_hypothesis_compat.py).
"""
import itertools

import jax.numpy as jnp
import numpy as np

from _hypothesis_compat import given, settings, strategies as st

from repro.core.fpdt import pair_live, pair_live_traced, sparsity_stride


def _dense_window_live(i, j, cq, window):
    """Oracle: any token pair (q in chunk i, k in chunk j) inside the band."""
    q = np.arange(i * cq, (i + 1) * cq)[:, None]
    k = np.arange(j * cq, (j + 1) * cq)[None, :]
    ok = q >= k
    if window:
        ok = ok & (q - k < window)
    return bool(ok.any())


@settings(max_examples=60)
@given(u=st.integers(min_value=1, max_value=8),
       cq=st.sampled_from([1, 4, 8]),
       window=st.sampled_from([0, 1, 5, 8, 17]),
       sparsity=st.sampled_from([0.0, 0.3, 0.5, 0.75, 0.9]))
def test_traced_matches_static(u, cq, window, sparsity):
    kw = dict(cq=cq, window=window, sparsity=sparsity)
    for i, j in itertools.product(range(u), repeat=2):
        static = pair_live(i, j, **kw)
        traced = bool(pair_live_traced(jnp.int32(i), jnp.int32(j), **kw))
        assert static == traced, (i, j, kw)


@settings(max_examples=40)
@given(u=st.integers(min_value=1, max_value=8),
       cq=st.sampled_from([1, 4, 8]),
       window=st.sampled_from([0, 1, 5, 8, 17]))
def test_window_equals_dense_mask(u, cq, window):
    """With sparsity off, chunk liveness == OR-reduction of the token mask."""
    for i, j in itertools.product(range(u), repeat=2):
        assert pair_live(i, j, cq=cq, window=window, sparsity=0.0) == \
            _dense_window_live(i, j, cq, window), (i, j, cq, window)


@settings(max_examples=40)
@given(u=st.integers(min_value=2, max_value=8),
       cq=st.sampled_from([4, 8]),
       sparsity=st.sampled_from([0.3, 0.5, 0.75, 0.9]))
def test_sparsity_invariants(u, cq, sparsity):
    kw = dict(cq=cq, window=0, sparsity=sparsity)
    stride = sparsity_stride(sparsity)
    for i in range(u):
        # the diagonal is always attended (exactness of the local softmax)
        assert pair_live(i, i, **kw)
        # future chunks never
        for j in range(i + 1, u):
            assert not pair_live(i, j, **kw)
        # off-diagonal keep-set is exactly the distance-stride comb
        for j in range(i):
            assert pair_live(i, j, **kw) == ((i - j - 1) % stride == 0)


def test_dense_schedule_keeps_everything():
    for u, cq in [(1, 8), (4, 4), (8, 2)]:
        for i, j in itertools.product(range(u), repeat=2):
            assert pair_live(i, j, cq=cq, window=0, sparsity=0.0) == (j <= i)
