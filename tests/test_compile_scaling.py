"""Compile-scaling regression: the scan-compiled FPDT pipeline's program
size must stay ~flat in the chunk count u, so nobody silently reintroduces
an unrolled (O(u^2)) chunk schedule on the path to the paper's 2M-token
configs.  Measured: traced jaxpr equations and lowered StableHLO op count
at u=32 vs u=4 (value_and_grad, so the Fig. 7 backward is included).
"""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "benchmarks"))

import compile_scaling as CS


@pytest.mark.slow
def test_scan_path_near_constant_in_u():
    r4 = CS.measure(4, unroll=False)
    r32 = CS.measure(32, unroll=False)
    assert r32["jaxpr_eqns"] <= 2 * r4["jaxpr_eqns"], (r4, r32)
    assert r32["hlo_ops"] <= 2 * r4["hlo_ops"], (r4, r32)


@pytest.mark.slow
def test_unrolled_path_grows_superlinearly():
    """Sanity that the counters actually see program size: the legacy
    unrolled path at 2x the chunks must emit >2x the equations (it is the
    quadratic oracle the scan path is measured against)."""
    r4 = CS.measure(4, unroll=True)
    r8 = CS.measure(8, unroll=True)
    assert r8["jaxpr_eqns"] > 2 * r4["jaxpr_eqns"], (r4, r8)
