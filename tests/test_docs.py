"""Docs integrity: the architecture map and doc cross-links cannot rot.

Checks that (1) every relative markdown link inside ``docs/*.md`` resolves,
(2) every ``docs/...`` path referenced from ROADMAP.md / CHANGES.md exists,
and (3) ``docs/README.md`` (the architecture map) links every doc page."""
import os
import re

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOCS = os.path.join(ROOT, "docs")

# [text](target) / [text](target#anchor) — external schemes skipped below
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+?)(?:#[^)]*)?\)")
DOC_REF_RE = re.compile(r"docs/[A-Za-z0-9_.\-/]*[A-Za-z0-9_\-/]")


def _md_files():
    return sorted(f for f in os.listdir(DOCS) if f.endswith(".md"))


def test_docs_relative_links_resolve():
    missing = []
    for fn in _md_files():
        with open(os.path.join(DOCS, fn)) as fh:
            text = fh.read()
        for target in LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:")) or not target:
                continue
            if not os.path.exists(os.path.normpath(os.path.join(DOCS, target))):
                missing.append(f"docs/{fn} -> {target}")
    assert not missing, f"dangling doc links: {missing}"


def test_root_files_doc_references_resolve():
    missing = []
    for name in ("ROADMAP.md", "CHANGES.md"):
        with open(os.path.join(ROOT, name)) as fh:
            text = fh.read()
        for ref in DOC_REF_RE.findall(text):
            if not os.path.exists(os.path.join(ROOT, ref)):
                missing.append(f"{name} -> {ref}")
    assert not missing, f"dangling docs/ references: {missing}"


def test_architecture_map_links_every_doc_page():
    readme = os.path.join(DOCS, "README.md")
    assert os.path.exists(readme), "docs/README.md (architecture map) missing"
    with open(readme) as fh:
        text = fh.read()
    unlinked = [fn for fn in _md_files()
                if fn != "README.md" and fn not in text]
    assert not unlinked, f"docs/README.md does not link: {unlinked}"
