"""Multi-device test substrate for the FPDT distribution kinds.

The ``ulysses`` and ``cp`` kinds — the core of the paper's design — need a
real multi-device mesh to exercise their collectives; unit tests keep one
visible device, so this driver spawns a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` and a (2 data,
4 model) mesh (see tests/distributed/check_fpdt_mesh.py).  Unlike the
full-model distributed checks (tests/test_distributed.py, marked slow),
this runs attention-only cells and stays in the default tier-1 selection.
"""
import os
import subprocess
import sys

HERE = os.path.dirname(__file__)
SRC = os.path.join(HERE, "..", "src")


def test_fpdt_mesh_kinds():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    r = subprocess.run(
        [sys.executable, os.path.join(HERE, "distributed", "check_fpdt_mesh.py")],
        capture_output=True, text=True, timeout=1800, env=env)
    if r.returncode != 0:
        raise AssertionError(f"exit {r.returncode}\nSTDOUT:\n{r.stdout[-4000:]}\n"
                             f"STDERR:\n{r.stderr[-4000:]}")
    assert "ALL FPDT MESH CHECKS PASSED" in r.stdout
    for kind in ("kind=ulysses", "kind=cp"):
        assert kind in r.stdout, r.stdout
