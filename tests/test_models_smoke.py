"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
asserting output shapes + finiteness (assignment deliverable f)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.models import transformer as T


def make_batch(cfg, key, b, s):
    batch = {}
    if cfg.frontend == "audio_frames":
        batch["frame_embeds"] = jax.random.normal(key, (b, s, cfg.d_model), jnp.bfloat16)
        batch["labels"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    elif cfg.frontend == "vision_patches":
        st = s - cfg.num_patches
        batch["patch_embeds"] = jax.random.normal(key, (b, cfg.num_patches, cfg.d_model), jnp.bfloat16)
        batch["tokens"] = jax.random.randint(key, (b, st), 0, cfg.vocab_size)
        batch["labels"] = jax.random.randint(key, (b, st), 0, cfg.vocab_size)
    else:
        batch["tokens"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
        batch["labels"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_smoke_forward_and_train_step(name):
    cfg = reduced(get_config(name))
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    batch = make_batch(cfg, key, 2, 32)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: T.loss_fn(cfg, None, p, batch), has_aux=True
    )(params)
    assert np.isfinite(float(loss)), name
    assert 1.0 < float(loss) < 20.0, (name, float(loss))  # ~ln(vocab) at init
    gsum = jax.tree.reduce(
        lambda a, g: a + jnp.sum(jnp.abs(g.astype(jnp.float32))), grads, 0.0
    )
    assert np.isfinite(float(gsum)) and float(gsum) > 0, name
    # one SGD step moves the loss
    params2 = jax.tree.map(lambda p, g: p - 0.02 * g.astype(p.dtype), params, grads)
    loss2, _ = T.loss_fn(cfg, None, params2, batch)
    assert float(loss2) < float(loss), (name, float(loss), float(loss2))


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_full_config_matches_assignment(name):
    """The full (non-reduced) configs carry the assigned dims exactly."""
    cfg = get_config(name)
    expected = {
        "falcon-mamba-7b": (64, 4096, 0, 0, 0, 65024),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
        "yi-34b": (60, 7168, 56, 8, 20480, 64000),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "mistral-nemo-12b": (40, 5120, 32, 8, 14336, 131072),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
    }[name]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected, (name, got, expected)
    if name == "granite-moe-1b-a400m":
        assert (cfg.num_experts, cfg.experts_per_token) == (32, 8)
    if name == "llama4-maverick-400b-a17b":
        assert (cfg.num_experts, cfg.experts_per_token) == (128, 1)
    if name == "falcon-mamba-7b":
        assert cfg.ssm_state == 16
    if name == "recurrentgemma-9b":
        assert cfg.window == 2048 and cfg.block_pattern == ("rglru", "rglru", "local_attn")
