"""Linear-scan kernel vs naive recurrence + property tests (fixed-seed grid
when hypothesis isn't installed; see tests/_hypothesis_compat.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st

from repro.kernels.linear_scan import ops as O
from repro.kernels.linear_scan import ref as R


@pytest.mark.parametrize("b,s,c,bs,bc", [(1, 8, 4, 4, 4), (2, 32, 8, 8, 4), (1, 24, 6, 8, 3)])
@pytest.mark.parametrize("impl", ["pallas", "xla"])
def test_matches_naive(rng, b, s, c, bs, bc, impl):
    a = jnp.asarray(rng.uniform(-0.99, 0.99, (b, s, c)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((b, s, c)), jnp.float32)
    h0 = jnp.asarray(rng.standard_normal((b, c)), jnp.float32)
    got = O.linear_scan(a, x, h0, impl=impl, block_s=bs, block_c=bc)
    np.testing.assert_allclose(np.asarray(got), R.linear_scan_naive(a, x, h0),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("impl", ["pallas", "xla"])
def test_grads(rng, impl):
    b, s, c = 1, 16, 4
    a = jnp.asarray(rng.uniform(0.2, 0.95, (b, s, c)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((b, s, c)), jnp.float32)
    h0 = jnp.asarray(rng.standard_normal((b, c)), jnp.float32)

    def loss_ref(a, x, h0):
        return (R.linear_scan(a, x, h0) ** 2).sum()

    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(a, x, h0)

    def loss_k(a, x, h0):
        return (O.linear_scan(a, x, h0, impl=impl, block_s=8, block_c=4) ** 2).sum()

    g = jax.jit(jax.grad(loss_k, argnums=(0, 1, 2)))(a, x, h0)
    for u, w in zip(g, g_ref):
        np.testing.assert_allclose(np.asarray(u), np.asarray(w), rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), s=st.sampled_from([4, 8, 12, 16]))
def test_block_boundary_invariance(seed, s):
    """Result must not depend on the block size (the FPDT chunk boundary)."""
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.uniform(-0.9, 0.9, (1, s, 4)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((1, s, 4)), jnp.float32)
    outs = [np.asarray(O.linear_scan(a, x, impl="pallas", block_s=bs, block_c=4))
            for bs in (1, 2, 4) if s % bs == 0]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=1e-5)
