"""Serving: prefill + incremental decode == full forward recompute, and the
scan-compiled decode engine (`runtime/decode_loop.py`) == the per-token
reference loop."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.parallel import ParallelContext
from repro.models import layers as L
from repro.models import serve as SV
from repro.models import transformer as T
from repro.runtime import decode_loop as DL


def full_logits(cfg, params, batch):
    h = T.embed_input(cfg, params, batch).astype(jnp.dtype(cfg.param_dtype))
    h, _ = T.hidden_forward(cfg, None, params, h)
    h = L.apply_norm(cfg, params["final_norm"], h)
    return (h @ T.head_matrix(cfg, params)).astype(jnp.float32)


ARCHS = ["llama3.2-1b", "qwen1.5-4b", "falcon-mamba-7b", "recurrentgemma-9b",
         "granite-moe-1b-a400m", "musicgen-medium", "internvl2-2b"]


@pytest.mark.parametrize("name", ARCHS)
def test_prefill_decode_match_full(name):
    cfg = dataclasses.replace(reduced(get_config(name)), param_dtype="float32",
                              remat="none", moe_capacity_factor=100.0)
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    b, s = 2, 16
    if cfg.frontend == "audio_frames":
        fe = jax.random.normal(key, (b, s + 1, cfg.d_model), jnp.float32)
        pre_b, dec_i = {"frame_embeds": fe[:, :s]}, {"frame_embeds": fe[:, s:s + 1]}
        full_b, full_b1 = pre_b, {"frame_embeds": fe}
    elif cfg.frontend == "vision_patches":
        st = s - cfg.num_patches
        pe = jax.random.normal(key, (b, cfg.num_patches, cfg.d_model), jnp.float32)
        toks = jax.random.randint(key, (b, st + 1), 0, cfg.vocab_size)
        pre_b = {"patch_embeds": pe, "tokens": toks[:, :st]}
        dec_i = {"tokens": toks[:, st:st + 1]}
        full_b, full_b1 = pre_b, {"patch_embeds": pe, "tokens": toks}
    else:
        toks = jax.random.randint(key, (b, s + 1), 0, cfg.vocab_size)
        pre_b, dec_i = {"tokens": toks[:, :s]}, {"tokens": toks[:, s:s + 1]}
        full_b, full_b1 = pre_b, {"tokens": toks}
    logits_pre, cache = SV.prefill_step(cfg, None, params, pre_b, max_len=32)
    np.testing.assert_allclose(np.asarray(logits_pre),
                               np.asarray(full_logits(cfg, params, full_b)[:, s - 1]),
                               rtol=2e-3, atol=2e-3)
    logits_dec, _ = SV.decode_step(cfg, None, params, cache, dec_i, jnp.int32(s))
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(full_logits(cfg, params, full_b1)[:, s]),
                               rtol=2e-3, atol=2e-3)


def test_host_chunked_decode_matches_plain():
    """FPDT-for-inference: host-streamed KV == on-device KV decode."""
    cfg = dataclasses.replace(reduced(get_config("llama3.2-1b")),
                              param_dtype="float32", remat="none")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0, cfg.vocab_size)
    _, cache = SV.prefill_step(cfg, None, params, {"tokens": toks[:, :16]}, max_len=32)
    l0, _ = SV.decode_step(cfg, None, params, cache, {"tokens": toks[:, 16:17]}, jnp.int32(16))
    par = ParallelContext(mesh=None)
    l8, _ = SV.decode_step(cfg, par, params, cache, {"tokens": toks[:, 16:17]},
                           jnp.int32(16), n_host_chunks=8)
    np.testing.assert_allclose(np.asarray(l8), np.asarray(l0), rtol=1e-4, atol=1e-4)


def _per_token_loop(cfg, par, params, cache, tok0, pos0, steps, n_host_chunks=0):
    """Reference: one decode_step dispatch per token, greedy."""
    outs, logits_all = [tok0], []
    for i in range(steps):
        l, cache = SV.decode_step(cfg, par, params, cache,
                                  {"tokens": outs[-1][:, None]},
                                  jnp.int32(pos0 + i), n_host_chunks=n_host_chunks)
        logits_all.append(l[:, : cfg.vocab_size])
        outs.append(jnp.argmax(l[:, : cfg.vocab_size], -1).astype(jnp.int32))
    return jnp.stack(outs[1:], 1), jnp.stack(logits_all, 0)


@pytest.mark.parametrize("name,chunks", [
    ("llama3.2-1b", 0), ("llama3.2-1b", 4),        # attn, on-device + host-KV
    ("falcon-mamba-7b", 0), ("recurrentgemma-9b", 0),  # ssm / rglru+local_attn
])
def test_scan_decode_matches_per_token_loop(name, chunks):
    """decode_tokens (one lax.scan) == per-token loop: logits AND greedy ids."""
    cfg = dataclasses.replace(reduced(get_config(name)), param_dtype="float32",
                              remat="none")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    b, s, steps = 2, 8, 5
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    logits, cache = SV.prefill_step(cfg, None, params, {"tokens": toks}, max_len=16)
    tok0 = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)
    par = ParallelContext(mesh=None) if chunks else None
    want_ids, want_logits = _per_token_loop(cfg, par, params, cache, tok0, s,
                                            steps, n_host_chunks=chunks)
    got_ids, aux = DL.decode_tokens(cfg, par, params, cache, tok0[:, None],
                                    jnp.full((b,), s, jnp.int32), num_steps=steps,
                                    n_host_chunks=chunks, collect_logits=True)
    assert got_ids.tolist() == want_ids.tolist()
    np.testing.assert_allclose(np.asarray(aux["logits"]), np.asarray(want_logits),
                               rtol=1e-5, atol=1e-5)


def test_position_masked_prefill_matches_exact():
    """Right-padded prefill with lengths == exact-length prefill, per row."""
    cfg = dataclasses.replace(reduced(get_config("llama3.2-1b")),
                              param_dtype="float32", remat="none")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 9), 0, cfg.vocab_size)
    lengths = [5, 9]
    l_pad, cache = SV.prefill_step(cfg, None, params, {"tokens": toks}, max_len=16,
                                   lengths=jnp.asarray(lengths, jnp.int32))
    for i, n in enumerate(lengths):
        l_exact, _ = SV.prefill_step(cfg, None, params,
                                     {"tokens": toks[i:i + 1, :n]}, max_len=16)
        np.testing.assert_allclose(np.asarray(l_pad[i]), np.asarray(l_exact[0]),
                                   rtol=2e-4, atol=2e-4)
    # padded slots must be masked out of the cache
    kpos = cache["pos0"]["kpos"]  # [C, b, s]
    assert (np.asarray(kpos[:, 0, 5:]) == -1).all()
    # recurrent layouts must refuse (their states integrate pad tokens)
    with pytest.raises(ValueError, match="position-masked"):
        SV.prefill_step(dataclasses.replace(reduced(get_config("falcon-mamba-7b")),
                                            param_dtype="float32", remat="none"),
                        None, T.init_params(reduced(get_config("falcon-mamba-7b")),
                                            jax.random.PRNGKey(0)),
                        {"tokens": toks}, max_len=16,
                        lengths=jnp.asarray(lengths, jnp.int32))


def test_continuous_batching_staggered_finishes():
    """ServeEngine (slots < requests, mixed lengths, stop token firing at
    different steps) reproduces per-prompt solo greedy generation."""
    cfg = dataclasses.replace(reduced(get_config("llama3.2-1b")),
                              param_dtype="float32", remat="none")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).tolist()
               for n in (3, 8, 5, 6)]
    max_new = 6

    def solo(prompt):
        t = jnp.asarray([prompt], jnp.int32)
        logits, cache = SV.prefill_step(cfg, None, params, {"tokens": t},
                                        max_len=8 + max_new)
        tok0 = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)
        ids, _ = _per_token_loop(cfg, None, params, cache, tok0, len(prompt),
                                 max_new - 1)
        return [int(tok0[0])] + [int(t) for t in ids[0]]

    solos = [solo(p) for p in prompts]
    stop = solos[0][2]  # fires at step 3 for prompt 0; elsewhere (if at all) later

    def trunc(g):
        return g[: g.index(stop) + 1] if stop in g else g

    eng = DL.ServeEngine(cfg, params, slots=2, bucket=8, max_new_tokens=max_new,
                         segment=2, stop_tokens=(stop,))
    got = eng.generate(prompts)
    want = [trunc(g) for g in solos]
    assert got == want
    assert len({len(g) for g in want}) > 1  # finishes genuinely staggered

    # a stop token sampled directly from prefill logits (before any scan
    # step) must also finish the sequence
    stop0 = solos[2][0]

    def trunc0(g):
        return g[: g.index(stop0) + 1] if stop0 in g else g

    eng0 = DL.ServeEngine(cfg, params, slots=2, bucket=8, max_new_tokens=max_new,
                          segment=2, stop_tokens=(stop0,))
    got0 = eng0.generate(prompts)
    assert got0 == [trunc0(g) for g in solos]
    assert len(got0[2]) == 1  # prompt 2 stopped on its very first token


def test_continuous_batching_recurrent_full_bucket():
    """Recurrent layouts can use the engine when prompts exactly fill the
    bucket (no pads -> unmasked prefill): engine == solo generation."""
    cfg = dataclasses.replace(reduced(get_config("falcon-mamba-7b")),
                              param_dtype="float32", remat="none")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    bucket, max_new = 6, 4
    prompts = [rng.integers(0, cfg.vocab_size, size=bucket).tolist()
               for _ in range(3)]

    def solo(prompt):
        t = jnp.asarray([prompt], jnp.int32)
        logits, cache = SV.prefill_step(cfg, None, params, {"tokens": t},
                                        max_len=bucket + max_new)
        tok0 = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)
        ids, _ = _per_token_loop(cfg, None, params, cache, tok0, bucket,
                                 max_new - 1)
        return [int(tok0[0])] + [int(t) for t in ids[0]]

    eng = DL.ServeEngine(cfg, params, slots=2, bucket=bucket,
                         max_new_tokens=max_new, segment=3)
    assert eng.generate(prompts) == [solo(p) for p in prompts]


def test_greedy_decode_loop():
    """Multi-step greedy decode is self-consistent with a one-shot forward."""
    cfg = dataclasses.replace(reduced(get_config("llama3.2-1b")),
                              param_dtype="float32", remat="none")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab_size)
    logits, cache = SV.prefill_step(cfg, None, params, {"tokens": toks}, max_len=32)
    out = [int(jnp.argmax(logits[:, :cfg.vocab_size], -1)[0])]
    pos = 8
    for _ in range(4):
        logits, cache = SV.decode_step(
            cfg, None, params, cache,
            {"tokens": jnp.asarray([[out[-1]]], jnp.int32)}, jnp.int32(pos))
        out.append(int(jnp.argmax(logits[:, :cfg.vocab_size], -1)[0]))
        pos += 1
    # oracle: rerun full forward over the realized sequence
    seq = jnp.concatenate([toks, jnp.asarray([out[:-1]], jnp.int32)], axis=1)
    fl = full_logits(cfg, params, {"tokens": seq})
    want = [int(jnp.argmax(fl[0, i, :cfg.vocab_size])) for i in range(7, 12)]
    assert out == want
