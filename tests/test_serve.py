"""Serving: prefill + incremental decode == full forward recompute."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.parallel import ParallelContext
from repro.models import layers as L
from repro.models import serve as SV
from repro.models import transformer as T


def full_logits(cfg, params, batch):
    h = T.embed_input(cfg, params, batch).astype(jnp.dtype(cfg.param_dtype))
    h, _ = T.hidden_forward(cfg, None, params, h)
    h = L.apply_norm(cfg, params["final_norm"], h)
    return (h @ T.head_matrix(cfg, params)).astype(jnp.float32)


ARCHS = ["llama3.2-1b", "qwen1.5-4b", "falcon-mamba-7b", "recurrentgemma-9b",
         "granite-moe-1b-a400m", "musicgen-medium", "internvl2-2b"]


@pytest.mark.parametrize("name", ARCHS)
def test_prefill_decode_match_full(name):
    cfg = dataclasses.replace(reduced(get_config(name)), param_dtype="float32",
                              remat="none", moe_capacity_factor=100.0)
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    b, s = 2, 16
    if cfg.frontend == "audio_frames":
        fe = jax.random.normal(key, (b, s + 1, cfg.d_model), jnp.float32)
        pre_b, dec_i = {"frame_embeds": fe[:, :s]}, {"frame_embeds": fe[:, s:s + 1]}
        full_b, full_b1 = pre_b, {"frame_embeds": fe}
    elif cfg.frontend == "vision_patches":
        st = s - cfg.num_patches
        pe = jax.random.normal(key, (b, cfg.num_patches, cfg.d_model), jnp.float32)
        toks = jax.random.randint(key, (b, st + 1), 0, cfg.vocab_size)
        pre_b = {"patch_embeds": pe, "tokens": toks[:, :st]}
        dec_i = {"tokens": toks[:, st:st + 1]}
        full_b, full_b1 = pre_b, {"patch_embeds": pe, "tokens": toks}
    else:
        toks = jax.random.randint(key, (b, s + 1), 0, cfg.vocab_size)
        pre_b, dec_i = {"tokens": toks[:, :s]}, {"tokens": toks[:, s:s + 1]}
        full_b, full_b1 = pre_b, {"tokens": toks}
    logits_pre, cache = SV.prefill_step(cfg, None, params, pre_b, max_len=32)
    np.testing.assert_allclose(np.asarray(logits_pre),
                               np.asarray(full_logits(cfg, params, full_b)[:, s - 1]),
                               rtol=2e-3, atol=2e-3)
    logits_dec, _ = SV.decode_step(cfg, None, params, cache, dec_i, jnp.int32(s))
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(full_logits(cfg, params, full_b1)[:, s]),
                               rtol=2e-3, atol=2e-3)


def test_host_chunked_decode_matches_plain():
    """FPDT-for-inference: host-streamed KV == on-device KV decode."""
    cfg = dataclasses.replace(reduced(get_config("llama3.2-1b")),
                              param_dtype="float32", remat="none")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0, cfg.vocab_size)
    _, cache = SV.prefill_step(cfg, None, params, {"tokens": toks[:, :16]}, max_len=32)
    l0, _ = SV.decode_step(cfg, None, params, cache, {"tokens": toks[:, 16:17]}, jnp.int32(16))
    par = ParallelContext(mesh=None)
    l8, _ = SV.decode_step(cfg, par, params, cache, {"tokens": toks[:, 16:17]},
                           jnp.int32(16), n_host_chunks=8)
    np.testing.assert_allclose(np.asarray(l8), np.asarray(l0), rtol=1e-4, atol=1e-4)


def test_greedy_decode_loop():
    """Multi-step greedy decode is self-consistent with a one-shot forward."""
    cfg = dataclasses.replace(reduced(get_config("llama3.2-1b")),
                              param_dtype="float32", remat="none")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab_size)
    logits, cache = SV.prefill_step(cfg, None, params, {"tokens": toks}, max_len=32)
    out = [int(jnp.argmax(logits[:, :cfg.vocab_size], -1)[0])]
    pos = 8
    for _ in range(4):
        logits, cache = SV.decode_step(
            cfg, None, params, cache,
            {"tokens": jnp.asarray([[out[-1]]], jnp.int32)}, jnp.int32(pos))
        out.append(int(jnp.argmax(logits[:, :cfg.vocab_size], -1)[0]))
        pos += 1
    # oracle: rerun full forward over the realized sequence
    seq = jnp.concatenate([toks, jnp.asarray([out[:-1]], jnp.int32)], axis=1)
    fl = full_logits(cfg, params, {"tokens": seq})
    want = [int(jnp.argmax(fl[0, i, :cfg.vocab_size])) for i in range(7, 12)]
    assert out == want
