# NOTE: no XLA_FLAGS here on purpose — unit tests and benches must see the
# single real CPU device.  Multi-device tests run via subprocess (see
# tests/test_distributed.py); the 512-device dry-run sets its own flags.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def rand(rng, *shape, dtype=np.float32):
    import jax.numpy as jnp

    return jnp.asarray(rng.standard_normal(shape), dtype)
