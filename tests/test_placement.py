"""PlacementPolicy: capability probing, graceful no-op degradation, spec
pass-through with a mesh, and the FPDT offload regression on a host with no
pinned memory (the seed crashed here with ValueError)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import fpdt
from repro.core.parallel import ParallelContext
from repro.models import layers as L
from repro.runtime.placement import (
    PlacementPolicy,
    default_policy,
    double_buffered,
)


# ---------------------------------------------------------------------------
# capability probing
# ---------------------------------------------------------------------------


def test_probe_cpu_backend():
    pol = PlacementPolicy.probe(jax.devices()[0])
    assert pol.backend == jax.devices()[0].platform
    assert pol.device_kind == jax.devices()[0].default_memory().kind
    kinds = {m.kind for m in jax.devices()[0].addressable_memories()}
    # on this CPU-only container there is no pinned_host pool
    if "pinned_host" not in kinds:
        assert not pol.supports_pinned_host
        assert not pol.can_offload


def test_default_policy_probes_once():
    assert default_policy() is default_policy()


def test_host_pool_equal_to_default_is_not_offload():
    # a "host" pool that IS the default memory is not an offload target
    pol = PlacementPolicy(device_kind="pinned_host", host_kind=None)
    assert not pol.can_offload
    pol2 = PlacementPolicy(device_kind="device", host_kind="pinned_host")
    assert pol2.supports_pinned_host and pol2.can_offload
    assert not dataclasses.replace(pol2, offload_enabled=False).can_offload


# ---------------------------------------------------------------------------
# no-op degradation
# ---------------------------------------------------------------------------


def test_noop_degradation_without_host_pool():
    pol = PlacementPolicy(device_kind="unpinned_host", host_kind=None,
                          backend="cpu")
    x = jnp.arange(8.0)
    assert pol.to_host(x) is x
    assert pol.to_device(x) is x


def test_noop_logs_warning_once(caplog):
    pol = PlacementPolicy(device_kind="unpinned_host", host_kind=None,
                          backend="test-warn-backend")
    x = jnp.arange(4.0)
    with caplog.at_level("WARNING", logger="repro.runtime.placement"):
        pol.to_host(x)
        pol.to_host(x)
    hits = [r for r in caplog.records if "test-warn-backend" in r.message]
    assert len(hits) == 1  # warn once, not per chunk


def test_remat_policy_degrades_to_full_remat():
    pol = PlacementPolicy(device_kind="unpinned_host", host_kind=None)
    assert pol.remat_policy() is jax.checkpoint_policies.nothing_saveable


# ---------------------------------------------------------------------------
# spec pass-through with a mesh
# ---------------------------------------------------------------------------


def test_sharding_spec_passthrough_with_mesh():
    from repro.launch.mesh import make_compat_mesh

    mesh = make_compat_mesh((1,), ("data",))
    pol = default_policy()
    s = pol.host_sharding(mesh, "data", None)
    assert s is not None and s.mesh is mesh
    assert s.spec == jax.sharding.PartitionSpec("data", None)
    if not pol.can_offload:  # degraded: plain default-memory sharding
        x = jnp.ones((2, 3))
        y = jax.device_put(x, s)  # must be constructible and usable
        np.testing.assert_allclose(np.asarray(y), np.asarray(x))
    assert pol.ns(None) is None  # mesh-less spec degrades to None


def test_parallel_context_routes_through_policy():
    par = ParallelContext(mesh=None)
    x = jnp.arange(6.0).reshape(2, 3)
    hx = par.to_host(x)
    dx = par.to_device(hx)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(x))
    if not par.pol.can_offload:
        assert not par.offload_active
    # offload disabled at the context level short-circuits entirely
    par_off = ParallelContext(mesh=None, offload_to_host=False)
    assert par_off.to_host(x) is x


# ---------------------------------------------------------------------------
# explicit double buffering
# ---------------------------------------------------------------------------


def test_double_buffered_prefetches_one_ahead():
    events = []

    def fetch(k):
        events.append(("fetch", k))
        return k

    for k in double_buffered(range(4), fetch):
        events.append(("compute", k))
    # fetch of k+1 must be issued before compute of k
    assert events == [
        ("fetch", 0), ("fetch", 1), ("compute", 0), ("fetch", 2),
        ("compute", 1), ("fetch", 3), ("compute", 2), ("compute", 3),
    ]
    assert list(double_buffered([], fetch)) == []


# ---------------------------------------------------------------------------
# regression: FPDT offload on a host without pinned memory == u=1 baseline
# ---------------------------------------------------------------------------


def test_fpdt_offload_matches_baseline_without_pinned_memory():
    cfg = dataclasses.replace(reduced(get_config("llama3.2-1b")),
                              param_dtype="float32", block_q=16, block_k=16)
    key = jax.random.PRNGKey(0)
    p = L.init_attn(cfg, key, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 64, cfg.d_model),
                          jnp.float32)

    def run(u, offload):
        c = dataclasses.replace(cfg, fpdt_chunks=u, fpdt_offload=offload)
        par = ParallelContext(mesh=None, attn_impl="pallas")
        return fpdt.fpdt_attention(c, par, p, x, kind="local")

    o1 = run(1, False)
    o4 = run(4, True)  # seed: ValueError before any math on this backend
    np.testing.assert_allclose(np.asarray(o4), np.asarray(o1),
                               rtol=2e-4, atol=2e-4)
