"""Chunked FFN / chunked vocab loss (paper §5.4) == unchunked."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.chunked_loss import IGNORE, auto_chunks, softmax_xent_chunked
from repro.models import layers as L


@pytest.fixture(scope="module")
def cfg():
    return dataclasses.replace(reduced(get_config("llama3.2-1b")), param_dtype="float32")


def test_chunked_mlp(cfg, rng):
    p = L.init_mlp(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 32, cfg.d_model)), jnp.float32)
    full = L.mlp_block(cfg, p, x)
    for n in (2, 4, 8):
        got = L.mlp_chunked(cfg, p, x, n)
        np.testing.assert_allclose(np.asarray(got), np.asarray(full), rtol=1e-5, atol=1e-5)
    # gradient equality through the rematerialized scan
    g_full = jax.grad(lambda p: (L.mlp_block(cfg, p, x) ** 2).sum())(p)
    g_chunk = jax.grad(lambda p: (L.mlp_chunked(cfg, p, x, 4) ** 2).sum())(p)
    for a, b in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_chunk)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


def test_chunked_loss_equals_full(cfg, rng):
    b, s, d, v = 2, 24, cfg.d_model, 64
    x = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32)
    head = jnp.asarray(rng.standard_normal((d, v)) * 0.1, jnp.float32)
    labels = jnp.asarray(rng.integers(0, v, (b, s)), jnp.int32)
    labels = labels.at[0, :3].set(IGNORE)

    def full(x, head):
        logits = (x @ head).astype(jnp.float32)
        lp = jax.nn.log_softmax(logits)
        ok = labels != IGNORE
        tgt = jnp.take_along_axis(lp, jnp.maximum(labels, 0)[..., None], -1)[..., 0]
        return jnp.where(ok, -tgt, 0.0).sum(), ok.sum()

    want, count_w = full(x, head)
    for n in (1, 2, 4, 8):
        got, count = softmax_xent_chunked(x, head, labels, n)
        np.testing.assert_allclose(float(got), float(want), rtol=1e-5)
        assert int(count) == int(count_w)
    # gradients too
    gw = jax.grad(lambda h: full(x, h)[0])(head)
    gc = jax.grad(lambda h: softmax_xent_chunked(x, h, labels, 4)[0])(head)
    np.testing.assert_allclose(np.asarray(gc), np.asarray(gw), rtol=1e-4, atol=1e-4)


def test_auto_chunks_rule(cfg):
    n = auto_chunks(cfg, 4096)
    assert 4096 % n == 0
    assert n <= max(1, 2 * cfg.padded_vocab // cfg.d_model) or n == 1
