"""Fused mixed-step scheduler (`runtime/decode_loop.mixed_segment` +
`ServeEngine`): chunked prefill == whole-prompt prefill (including the
state-at-length gather that admits recurrent layouts into variable-length
continuous batching), engine == solo generation across edge cases, and the
bounded compiled-program set."""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import serve as SV
from repro.models import transformer as T
from repro.runtime import decode_loop as DL


@functools.lru_cache(maxsize=4)
def setup(name):
    cfg = dataclasses.replace(reduced(get_config(name)), param_dtype="float32",
                              remat="none")
    return cfg, T.init_params(cfg, jax.random.PRNGKey(0))


def chunked_prefill(cfg, params, toks, lengths, cp, max_len):
    """Stream right-padded prompts chunk by chunk through `chunk_step`;
    returns (per-row last-real-token logits, cache) like prefill_step."""
    b = toks.shape[0]
    cache = SV.init_cache(cfg, b, max_len)
    pfill = np.zeros(b, np.int32)
    plen = np.asarray(lengths, np.int32)
    logits = np.zeros((b, cfg.padded_vocab), np.float32)
    while (pfill < plen).any():
        live = np.clip(plen - pfill, 0, cp)
        idx = np.clip(pfill[:, None] + np.arange(cp)[None], 0, toks.shape[1] - 1)
        chunk = np.asarray(toks)[np.arange(b)[:, None], idx]
        lk, cache = SV.chunk_step(cfg, None, params, cache, jnp.asarray(chunk),
                                  jnp.asarray(pfill), jnp.asarray(live))
        fin = (pfill + live >= plen) & (pfill < plen)
        logits[fin] = np.asarray(lk)[fin]
        pfill = pfill + live
    return logits, cache


def solo_greedy(cfg, params, prompt, max_new, cap=48):
    """Reference: whole-prompt prefill + per-token greedy decode."""
    if max_new <= 0:
        return []
    t = jnp.asarray([list(prompt)], jnp.int32)
    logits, cache = SV.prefill_step(cfg, None, params, {"tokens": t}, max_len=cap)
    out = [int(jnp.argmax(logits[:, : cfg.vocab_size], -1)[0])]
    for i in range(max_new - 1):
        logits, cache = SV.decode_step(
            cfg, None, params, cache,
            {"tokens": jnp.asarray([[out[-1]]], jnp.int32)},
            jnp.int32(len(prompt) + i))
        out.append(int(jnp.argmax(logits[:, : cfg.vocab_size], -1)[0]))
    return out


def test_chunk_step_matches_masked_prefill():
    """Attn layout: chunked prefill == position-masked whole-prompt prefill
    (logits AND cache contents)."""
    cfg, params = setup("llama3.2-1b")
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 9), 0, cfg.vocab_size)
    lengths = [5, 9]
    l_ref, c_ref = SV.prefill_step(cfg, None, params, {"tokens": toks},
                                   max_len=16,
                                   lengths=jnp.asarray(lengths, jnp.int32))
    l_got, c_got = chunked_prefill(cfg, params, toks, lengths, cp=4, max_len=16)
    np.testing.assert_allclose(l_got, np.asarray(l_ref), rtol=2e-4, atol=2e-4)
    kp_ref = np.asarray(c_ref["pos0"]["kpos"])
    kp_got = np.asarray(c_got["pos0"]["kpos"])
    assert ((kp_ref == kp_got) | ((kp_ref < 0) & (kp_got < 0))).all()
    m = kp_ref >= 0
    np.testing.assert_allclose(np.asarray(c_got["pos0"]["k"])[m],
                               np.asarray(c_ref["pos0"]["k"])[m],
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name", ["falcon-mamba-7b", "recurrentgemma-9b"])
def test_chunk_step_state_at_length(name):
    """Recurrent layouts: chunked variable-length prefill == exact per-row
    prefill — logits and every recurrent state leaf (the state-at-length
    gather; whole-prompt `prefill_step` REFUSES these layouts padded)."""
    cfg, params = setup(name)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 9), 0, cfg.vocab_size)
    lengths = [5, 9]
    l_got, c_got = chunked_prefill(cfg, params, toks, lengths, cp=4, max_len=16)

    def state_leaves(c, row):
        out = {}
        for key in c:
            blocks = (enumerate(c["tail"]) if key == "tail"
                      else [(key, c[key])])
            for bk, blk in blocks:
                for n, v in blk.items():
                    if n in ("conv", "ssm", "h"):
                        a = np.asarray(v)
                        out[f"{bk}.{n}"] = a[row] if key == "tail" else a[:, row]
        return out

    for i, n in enumerate(lengths):
        l_ref, c_ref = SV.prefill_step(cfg, None, params,
                                       {"tokens": toks[i:i + 1, :n]}, max_len=16)
        np.testing.assert_allclose(l_got[i], np.asarray(l_ref)[0],
                                   rtol=3e-4, atol=3e-4)
        got, want = state_leaves(c_got, i), state_leaves(c_ref, 0)
        for leaf in want:
            np.testing.assert_allclose(got[leaf], want[leaf], rtol=3e-4,
                                       atol=3e-4, err_msg=f"row {i} {leaf}")


@pytest.mark.parametrize("name", ["falcon-mamba-7b", "recurrentgemma-9b"])
def test_engine_recurrent_mixed_lengths(name):
    """THE new capability: ssm / rglru(+local_attn ring) layouts in
    variable-length continuous batching — impossible under position-masked
    prefill — reproduce solo generation exactly."""
    cfg, params = setup(name)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).tolist()
               for n in (3, 8, 5, 12, 6)]  # 12 > bucket: multi-chunk refill
    max_new = 5
    solos = [solo_greedy(cfg, params, p, max_new) for p in prompts]
    stop = solos[0][2]

    def trunc(g):
        return g[: g.index(stop) + 1] if stop in g else g

    eng = DL.ServeEngine(cfg, params, slots=2, bucket=8, max_new_tokens=max_new,
                         segment=2, prefill_chunk=4, stop_tokens=(stop,))
    assert eng.generate(prompts) == [trunc(g) for g in solos]
    assert eng.compiled_programs()["segment"] == 1


def test_engine_prompts_longer_than_bucket():
    """Prompts longer than the bucket are legal: they stream in over more
    chunks (capacity derives from the longest prompt)."""
    cfg, params = setup("llama3.2-1b")
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).tolist()
               for n in (20, 3, 17)]
    solos = [solo_greedy(cfg, params, p, 4) for p in prompts]
    eng = DL.ServeEngine(cfg, params, slots=2, bucket=8, max_new_tokens=4,
                         segment=3, prefill_chunk=8)
    assert eng.generate(prompts) == solos
    # the blocking baseline rejects them, naming the offender
    blk = DL.BlockingServeEngine(cfg, params, slots=2, bucket=8,
                                 max_new_tokens=4)
    with pytest.raises(ValueError, match="prompt 0 has length 20"):
        blk.generate(prompts)


def test_engine_edge_cases():
    """decode_loop edge cases, each equal to solo generation: zero budget,
    stop token from the prefill logits, every slot finishing in the same
    step, queue longer than slots with mixed lengths."""
    cfg, params = setup("llama3.2-1b")
    rng = np.random.default_rng(2)
    mk = lambda n: rng.integers(0, cfg.vocab_size, size=n).tolist()

    # max_new_tokens = 0: empty budget -> no tokens, engine still drains
    prompts = [mk(3), mk(6), mk(4)]
    eng0 = DL.ServeEngine(cfg, params, slots=2, bucket=8, max_new_tokens=0,
                          segment=2, prefill_chunk=4)
    assert eng0.generate(prompts) == [[] for _ in prompts]

    # stop token sampled from the prefill logits: one-token output
    solos = [solo_greedy(cfg, params, p, 5) for p in prompts]
    stop0 = solos[1][0]

    def trunc(g, s):
        return g[: g.index(s) + 1] if s in g else g

    engs = DL.ServeEngine(cfg, params, slots=2, bucket=8, max_new_tokens=5,
                          segment=2, prefill_chunk=4, stop_tokens=(stop0,))
    got = engs.generate(prompts)
    assert got == [trunc(g, stop0) for g in solos]
    assert len(got[1]) == 1

    # every slot finishes in the same step (same prompt, same budget)
    same = [prompts[0]] * 3
    engf = DL.ServeEngine(cfg, params, slots=3, bucket=8, max_new_tokens=4,
                          segment=4, prefill_chunk=4)
    assert engf.generate(same) == [solo_greedy(cfg, params, prompts[0], 4)] * 3

    # queue longer than slots, mixed prompt lengths
    many = [mk(n) for n in (2, 7, 4, 8, 3, 5, 6, 1)]
    engq = DL.ServeEngine(cfg, params, slots=2, bucket=8, max_new_tokens=3,
                          segment=2, prefill_chunk=4)
    assert engq.generate(many) == [solo_greedy(cfg, params, p, 3) for p in many]

    # empty prompt: rejected with the offending index
    with pytest.raises(ValueError, match="prompt 1 is empty"):
        engq.generate([mk(3), []])

    # decode_tokens with a zero remaining budget emits only pads
    toks = jnp.asarray([mk(4)], jnp.int32)
    logits, cache = SV.prefill_step(cfg, None, params, {"tokens": toks},
                                    max_len=16)
    tok0 = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)[:, None]
    ids, aux = DL.decode_tokens(cfg, None, params, cache, tok0,
                                jnp.full((1,), 4, jnp.int32), num_steps=3,
                                remaining=jnp.zeros((1,), jnp.int32), pad_id=0)
    assert ids.tolist() == [[0, 0, 0]] and bool(aux["done"][0])


@pytest.mark.slow
def test_staggered_program_set():
    """The staggered-arrival workload compiles exactly the bounded program
    set — one mixed segment + one slot reset, no per-bucket or per-length
    specializations — and refill stalls decode far less than the blocking
    baseline."""
    import os
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks import serve_bench as SB

    fused = SB.staggered_workload(blocking=False)
    # the segment cache is engine-private: exactly one mixed program.  The
    # reset cache is shared module-wide (other engines in this process may
    # have contributed entries), so the invariant is NO GROWTH between the
    # warmup pass and the measured pass — re-running the workload compiles
    # nothing new, i.e. no per-bucket / per-length specializations.
    assert fused["programs"]["segment"] == 1, fused["programs"]
    assert fused["programs"] == fused["programs_before"], fused
    blocking = SB.staggered_workload(blocking=True)
    # median refill-active step vs median steady step: the blocking engine
    # stalls every other slot for a full-bucket prefill (>>3x); the fused
    # scheduler streams the prompt under the live decodes (<3x)
    assert fused["stall_factor_p50"] < 3 < blocking["stall_factor_p50"], (
        fused, blocking)
    assert fused["refill_over_steady"] < blocking["refill_over_steady"], (
        fused, blocking)
    assert fused["tokens"] == blocking["tokens"]  # same greedy workload
