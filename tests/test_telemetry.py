"""Unified telemetry layer (`runtime/telemetry.py`): registry primitives
stay bounded, the `last_stats` facade keeps the old dict contract while
mirroring scalars into the registry, exporters emit valid Chrome
trace-event JSON / Prometheus text, and — the golden acceptance — the
same seed (plus the same fault plan) reproduces the IDENTICAL lifecycle
event sequence on the deterministic step clock, wall-clock excluded:

* registry/StepRing/StatsView/timed_dispatch unit behavior (no jax);
* per-request summaries reconstructed from synthetic lifecycle events;
* compile counting through the `per_engine` jit wrapper, including the
  bounded-program-set alert when a program recompiles past its limit;
* router failover telemetry on fake replicas: per-call vs lifetime
  counter views (the regression `test_failover_per_call_vs_lifetime`
  referenced from `launch/router.py`) and the pinned
  retry -> death -> recover -> re-home event order, byte-identical
  across two runs of the same scripted fault;
* the SLO engine golden: two fresh engines, same seed, identical
  deterministic trace views through admit/preempt/resume/emit.
"""
import dataclasses
import functools
import json

import pytest

from repro.runtime import telemetry as TM


# ---------------------------------------------------------------------------
# registry primitives (no jax)
# ---------------------------------------------------------------------------


def test_registry_create_on_first_use_and_value():
    reg = TM.MetricsRegistry()
    assert reg.counter("a") is reg.counter("a")
    reg.counter("a").inc(3)
    reg.gauge("g").set(2.5)
    reg.histogram("h").observe(1.0)
    assert reg.value("a") == 3
    assert reg.value("g") == 2.5
    assert reg.value("missing", default=-1) == -1
    snap = reg.snapshot()
    assert snap["a"] == 3 and snap["h"]["count"] == 1


def test_histogram_exact_aggregates_bounded_reservoir():
    h = TM.Histogram(reservoir=8)
    for v in range(100):
        h.observe(float(v))
    # aggregates are exact over the full stream ...
    assert h.count == 100 and h.total == sum(range(100))
    assert h.vmin == 0.0 and h.vmax == 99.0
    # ... percentiles over the most recent window only, drops counted
    assert len(h.window) == 8 and h.dropped == 92
    assert h.percentile(50) >= 92.0
    s = h.summary()
    assert s["count"] == 100 and s["dropped"] == 92


def test_step_ring_bounds_like_a_list():
    ring = TM.StepRing(cap=4)
    for i in range(6):
        ring.append({"ms": float(i)})
    assert len(ring) == 4 and ring.dropped == 2
    assert ring[0]["ms"] == 2.0 and ring[-1]["ms"] == 5.0
    assert [r["ms"] for r in ring[1:]] == [3.0, 4.0, 5.0]  # slicing
    assert [r["ms"] for r in ring] == [2.0, 3.0, 4.0, 5.0]  # iteration
    assert bool(ring) and not bool(TM.StepRing())


def test_stats_view_mirrors_scalars_into_registry():
    tel = TM.Telemetry(component="t")
    st = tel.stats_view({"dispatches": 0, "policy": "slo", "radix": True})
    st["dispatches"] += 3
    st["prefix_hit_tokens"] = 7
    # dict contract intact for existing consumers
    assert st["dispatches"] == 3 and st.get("missing", 5) == 5
    assert "policy" in st and dict(st)["prefix_hit_tokens"] == 7
    # scalars live in the registry (single source of truth for BENCH) ...
    assert tel.registry.value("dispatches") == 3
    assert tel.registry.value("prefix_hit_tokens") == 7
    # ... but strings/bools/lists stay local (BENCH values must be numeric)
    assert "policy" not in tel.registry.gauges
    assert "radix" not in tel.registry.gauges
    assert st["radix"] is True


def test_timed_dispatch_record_shape_and_registry():
    tel = TM.Telemetry(component="t")
    stats = tel.stats_view({"steps": tel.steps_ring(), "dispatches": 0})
    with TM.timed_dispatch(tel, stats, prefilling=1) as td:
        td.emitted = 4
    with TM.timed_dispatch(tel, stats, step=9) as td:
        td.emitted = 2
        td.prefilling = 3
    assert stats["dispatches"] == 2
    rec0, rec1 = stats["steps"][0], stats["steps"][1]
    assert set(rec0) == {"ms", "prefilling", "emitted"}
    assert rec0["prefilling"] == 1 and rec0["emitted"] == 4
    assert rec1["step"] == 9 and rec1["prefilling"] == 3
    assert tel.registry.value("emitted_tokens") == 6
    assert tel.registry.histograms["dispatch_ms"].count == 2
    assert tel.tracer.kinds() == ["engine.dispatch", "engine.dispatch"]


def test_tracer_deterministic_view_excludes_wall_clock():
    t = TM.Tracer()
    t.event("x", step=1, request=0, dur_ms=3.5, lat_ms=9.9, n=2)
    (ev,) = t.deterministic_view()
    assert ev == ("x", 1, 0, None, None, None, (("n", 2),))
    flat = repr(ev)
    assert "3.5" not in flat and "9.9" not in flat


def test_tracer_buffer_bounded():
    t = TM.Tracer(max_events=3)
    for i in range(5):
        t.event("e", step=i)
    assert len(t.events) == 3 and t.dropped == 2
    assert [e["step"] for e in t.events] == [2, 3, 4]


def test_set_tracing_off_stops_events_not_counters():
    tel = TM.Telemetry(component="t").set_tracing(False)
    tel.event("request.admit", request=0)
    tel.compile_event("segment")
    assert len(tel.tracer.events) == 0
    assert tel.registry.value("compiles_segment") == 1  # still counted


def test_compile_event_alert_past_program_limit():
    tel = TM.Telemetry(component="t", program_limit=1)
    tel.compile_event("segment")
    assert tel.alerts() == 0
    tel.compile_event("segment")  # second compile of the same program
    assert tel.alerts() == 1
    assert "alert.programs" in tel.tracer.kinds()


def test_request_summaries_from_synthetic_events():
    t = TM.Tracer()
    t.event("request.queued", request=0, session="s", step=2)
    t.event("request.admit", request=0, step=5, prefix_hit=8)
    t.event("request.emit", request=0, step=7, n=2)
    t.event("request.preempt", request=0, step=8)
    t.event("request.resume", request=0, step=10, prefix_hit=4)
    t.event("request.emit", request=0, step=11, n=1)
    t.event("request.emit", request=0, step=12, n=1)
    s = TM.request_summaries(t)[0]
    assert s["queued_step"] == 2 and s["admit_step"] == 5
    assert s["queue_wait"] == 3
    assert s["ttft"] == 5 and s["first_emit"] == 7 and s["last_emit"] == 12
    assert s["n_emitted"] == 4 and s["preemptions"] == 1
    assert s["prefix_hit_tokens"] == 12
    assert s["itl_p50"] == 1 and s["max_gap"] == 4


# ---------------------------------------------------------------------------
# exporters (no jax)
# ---------------------------------------------------------------------------


def _sample_telemetry():
    tel = TM.Telemetry(component="engine", replica=1)
    tel.registry.counter("emitted_tokens").inc(10)
    tel.registry.gauge("capacity").set(4)
    tel.registry.histogram("dispatch_ms").observe(2.0)
    tel.event("request.admit", request=0, slot=1, step=3)
    tel.event("engine.dispatch", step=4, dur_ms=2.0)
    return tel


def test_chrome_trace_round_trips_as_json():
    doc = json.loads(json.dumps(TM.chrome_trace([_sample_telemetry()])))
    evs = doc["traceEvents"]
    names = {e["name"] for e in evs}
    assert {"process_name", "thread_name",
            "request.admit", "engine.dispatch"} <= names
    meta = [e for e in evs if e["name"] == "process_name"]
    assert meta[0]["args"]["name"] == "engine[1]"  # replica-labeled pid
    span = next(e for e in evs if e["name"] == "engine.dispatch")
    assert span["ph"] == "X" and span["dur"] == pytest.approx(2000.0)
    inst = next(e for e in evs if e["name"] == "request.admit")
    assert inst["ph"] == "i" and inst["tid"] == 2  # slot 1 -> track 2
    tracks = {e["tid"]: e["args"]["name"] for e in evs
              if e["name"] == "thread_name"}
    assert tracks[0] == "scheduler" and tracks[2] == "slot 1"


def test_prometheus_text_exposition():
    text = TM.prometheus_text([_sample_telemetry()])
    assert '# TYPE repro_emitted_tokens counter' in text
    assert ('repro_emitted_tokens{component="engine",replica="1"} 10'
            in text)
    assert '# TYPE repro_capacity gauge' in text
    assert 'repro_dispatch_ms{component="engine",replica="1",quantile="0.5"}' \
        in text
    assert 'repro_dispatch_ms_count{component="engine",replica="1"} 1' in text
    # every sample line parses as 'name{labels} value'
    for line in text.strip().splitlines():
        if line.startswith("#"):
            continue
        name, rest = line.split("{", 1)
        labels, value = rest.rsplit("} ", 1)
        assert name.startswith("repro_") and float(value) is not None


def test_write_exporters(tmp_path):
    tel = _sample_telemetry()
    TM.write_chrome_trace(str(tmp_path / "t.json"), tel)
    TM.write_prometheus(str(tmp_path / "m.prom"), tel)
    assert json.load(open(tmp_path / "t.json"))["traceEvents"]
    assert "repro_" in open(tmp_path / "m.prom").read()


# ---------------------------------------------------------------------------
# router failover telemetry on fakes (no jax)
# ---------------------------------------------------------------------------


class StoreEcho:
    """Echo replica with a fake (but file-backed) prefix-cache store, so
    SharedKVStore's publish/recover path runs for real."""

    def __init__(self):
        self.last_stats = {"prompt_tokens": 0, "prefix_hit_tokens": 0}

    def generate(self, prompts):
        toks = [list(getattr(p, "tokens", p)) for p in prompts]
        self.last_stats = {"prompt_tokens": sum(len(t) for t in toks),
                           "prefix_hit_tokens": 0}
        return [[t[0], len(t)] for t in toks]

    def save_kv_store(self, path):
        with open(path, "w") as f:
            f.write("pages")
        return 3

    def restore_kv_store(self, path):
        return 3


def quiet(msg):
    pass


def _crash_router(tmp_path, fault_kind="raise", max_retries=1):
    from repro.launch.faults import Fault, FaultyReplica
    from repro.launch.kvstore import SharedKVStore
    from repro.launch.router import ReplicaRouter

    prompts = [[i, i + 1, i + 2] for i in range(8)]
    store = SharedKVStore(str(tmp_path))
    reps = [FaultyReplica(StoreEcho()) for _ in range(2)]
    rt = ReplicaRouter(reps, max_retries=max_retries, kv_store=store,
                       warn=quiet)
    victim = rt.home_of(prompts[0])
    reps[victim].faults.append(Fault(fault_kind, 0))
    return rt, store, prompts, victim


ROUTER_LIFECYCLE = {"router.retry", "router.death", "router.recover",
                    "router.rehome", "router.rejoin"}


def test_failover_trace_golden_identical_and_pinned_order(tmp_path):
    """Same fault plan, two fresh routers: identical deterministic views,
    and the failover events land in the pinned order
    retry -> death -> recover -> re-home (one re-home per orphaned
    request)."""
    views, kvviews = [], []
    for run in range(2):
        rt, store, prompts, victim = _crash_router(tmp_path / str(run))
        outs = rt.generate(prompts)
        assert all(len(o) == 2 for o in outs)
        views.append(rt.telemetry.tracer.deterministic_view())
        kvviews.append(store.telemetry.tracer.deterministic_view())
        kinds = [k for k in rt.telemetry.tracer.kinds()
                 if k in ROUTER_LIFECYCLE]
        n_rehomed = rt.last_stats["failover"]["rehomed_requests"]
        assert n_rehomed > 0
        assert kinds == (["router.retry", "router.death", "router.recover"]
                         + ["router.rehome"] * n_rehomed)
        assert {"kvstore.publish", "kvstore.recover"} <= \
            set(store.telemetry.tracer.kinds())
    assert views[0] == views[1], "router trace must be seed-deterministic"
    assert kvviews[0] == kvviews[1]


def test_rejoin_emits_recovery_event(tmp_path):
    rt, store, prompts, victim = _crash_router(tmp_path)
    rt.generate(prompts)
    rt.replicas[victim].heal()
    restored = rt.rejoin(victim)
    assert restored == 3  # StoreEcho's own published file reloads
    ev = next(e for e in rt.telemetry.tracer.events
              if e["kind"] == "router.rejoin")
    assert ev["replica"] == victim and ev["args"]["pages"] == 3
    assert "kvstore.restore_self" in store.telemetry.tracer.kinds()


def test_failover_per_call_vs_lifetime(tmp_path):
    """Satellite 6 regression: `last_stats["failover"]` counters are
    PER-CALL deltas (existing consumers rely on that); the lifetime
    totals live in `failover["lifetime"]` and in the registry's
    `router_*` counters, while the `failover_*` gauges mirror the last
    call's deltas."""
    from repro.launch.faults import Fault, FaultyReplica
    from repro.launch.router import ReplicaRouter

    prompts = [[i, i + 1] for i in range(6)]
    reps = [FaultyReplica(StoreEcho()) for _ in range(2)]
    rt = ReplicaRouter(reps, max_retries=2, warn=quiet)
    victim = rt.home_of(prompts[0])
    reps[victim].faults.append(Fault("transient", 0))  # one-shot fault

    rt.generate(prompts)
    fo1 = rt.last_stats["failover"]
    assert fo1["retries"] == 1 and fo1["deaths"] == 0
    assert fo1["lifetime"]["retries"] == 1

    rt.generate(prompts)  # clean second call
    fo2 = rt.last_stats["failover"]
    assert fo2["retries"] == 0, "per-call view must reset between calls"
    assert fo2["lifetime"]["retries"] == 1, "lifetime view must not"
    reg = rt.telemetry.registry
    assert reg.value("router_retries") == 1          # lifetime counter
    assert reg.value("failover_retries") == 0        # last-call gauge


def test_router_dispatch_spans_on_step_clock():
    from repro.launch.router import ReplicaRouter

    rt = ReplicaRouter([StoreEcho(), StoreEcho()], warn=quiet)
    rt.generate([[1, 2], [3, 4], [5, 6]])
    spans = [e for e in rt.telemetry.tracer.events
             if e["kind"] == "router.dispatch"]
    assert spans and all(e["dur_ms"] is not None for e in spans)
    assert [e["step"] for e in spans] == \
        list(range(1, len(spans) + 1))  # monotone dispatch-seq clock


# ---------------------------------------------------------------------------
# engine golden: same seed => identical deterministic trace (jax)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=1)
def _setup():
    import jax

    from repro.configs import get_config, reduced
    from repro.models import transformer as T

    cfg = dataclasses.replace(reduced(get_config("llama3.2-1b")),
                              param_dtype="float32", remat="none")
    return cfg, T.init_params(cfg, jax.random.PRNGKey(0))


def _slo_run():
    """A tiny preempting SLO workload on a fresh engine; returns the
    engine after one generate."""
    import numpy as np

    from repro.runtime import decode_loop as DL
    from repro.runtime import paged as PG

    cfg, params = _setup()
    rng = np.random.default_rng(0)
    V = cfg.vocab_size
    long_p = tuple(int(t) for t in rng.integers(0, V, 13))
    short_p = tuple(int(t) for t in rng.integers(0, V, 5))
    eng = PG.SLOPagedServeEngine(cfg, params, slots=1, bucket=16,
                                 max_new_tokens=8, page_size=4, segment=1,
                                 spill_pages=8)
    outs = eng.generate([
        DL.Request(tokens=long_p, priority=1, arrival=0, session="batch"),
        DL.Request(tokens=short_p, priority=0, arrival=6, session="chat")])
    return eng, outs


def test_slo_trace_golden_deterministic():
    """THE tentpole golden: two fresh engines, same seed, byte-identical
    deterministic trace views through a preempt/resume cycle — and the
    trace carries the full lifecycle taxonomy."""
    eng1, outs1 = _slo_run()
    eng2, outs2 = _slo_run()
    assert outs1 == outs2
    v1 = eng1.telemetry.tracer.deterministic_view()
    v2 = eng2.telemetry.tracer.deterministic_view()
    assert v1 == v2, "same seed must reproduce the identical trace"
    kinds = set(eng1.telemetry.tracer.kinds())
    assert {"request.queued", "request.admit", "request.preempt",
            "request.resume", "request.emit", "request.complete",
            "engine.dispatch", "compile.segment"} <= kinds
    # trace-derived summaries agree with the scheduler's own accounting
    summ = eng1.telemetry.request_summaries()
    st = eng1.last_stats
    for ridx, rs in enumerate(st["requests"]):
        assert summ[ridx]["n_emitted"] == rs["n_emitted"]
        assert summ[ridx]["preemptions"] == rs["preemptions"]
        assert summ[ridx]["first_emit"] == rs["first_emit"]
    assert sum(s["preemptions"] for s in summ.values()) == st["preemptions"]


def test_compile_counters_match_program_cache():
    """The per_engine wrapper's compile events count exactly what the
    jit caches hold: registry compiles_* == compiled_programs(), and a
    clean run raises no bounded-program-set alert."""
    eng, _ = _slo_run()
    progs = eng.compiled_programs()
    for name, cached in progs.items():
        assert eng.telemetry.registry.value(f"compiles_{name}") == cached, \
            name
    assert eng.telemetry.alerts() == 0
