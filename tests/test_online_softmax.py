"""Property tests (hypothesis) for the online-softmax merge — the invariant
the whole FPDT schedule rests on.  Falls back to a fixed-seed grid when
hypothesis isn't installed (see tests/_hypothesis_compat.py)."""
import jax.numpy as jnp
import numpy as np

from _hypothesis_compat import given, settings, strategies as st

from repro.core.online_softmax import SoftmaxState, finalize, merge, zero_state
from repro.kernels.flash_attention import ref as R


def _state(rng, sq, d, scale):
    acc = jnp.asarray(rng.standard_normal((sq, d)) * scale, jnp.float32)
    m = jnp.asarray(rng.standard_normal(sq) * scale, jnp.float32)
    l = jnp.asarray(rng.uniform(0.1, 2.0, sq), jnp.float32)
    return SoftmaxState(acc=acc, m=m, l=l)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), scale=st.floats(0.1, 20.0))
def test_merge_associative_commutative(seed, scale):
    rng = np.random.default_rng(seed)
    a, b, c = (_state(rng, 4, 8, scale) for _ in range(3))
    left = merge(merge(a, b), c)
    right = merge(a, merge(b, c))
    for u, w in zip(left, right):
        np.testing.assert_allclose(np.asarray(u), np.asarray(w), rtol=1e-5, atol=1e-5)
    ab, ba = merge(a, b), merge(b, a)
    for u, w in zip(ab, ba):
        np.testing.assert_allclose(np.asarray(u), np.asarray(w), rtol=1e-6, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_merge_identity(seed):
    rng = np.random.default_rng(seed)
    a = _state(rng, 4, 8, 1.0)
    z = zero_state((4, 8))
    out = merge(z, a)
    for u, w in zip(out, a):
        np.testing.assert_allclose(np.asarray(u), np.asarray(w), rtol=1e-6, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), n_chunks=st.sampled_from([1, 2, 4, 8]))
def test_chunked_attention_equals_full(seed, n_chunks):
    """Any chunk schedule of online merges == exact softmax attention."""
    rng = np.random.default_rng(seed)
    b, h, s, d = 1, 2, 16, 8
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    full = R.mha(q, k, v, causal=True)
    chunked = R.mha_chunked(q, k, v, n_chunks, causal=True)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(full), rtol=1e-5, atol=1e-5)
